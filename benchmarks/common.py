"""Shared benchmark harness: corpus build, timed search runs, CSV output.

Methodology follows the paper §III.E: each measurement is repeated
``--runs`` times and the *median* wall time is reported.  The container is
CPU-only, so absolute times are not TPU times — the quantities that transfer
are the *ratios* (progressive vs truncated at matched accuracy) and the
accuracy columns; the dry-run roofline (benchmarks/roofline.py) covers the
TPU-side performance story.
"""

from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    make_schedule,
    progressive_search,
    top1_accuracy,
    truncated_search,
)
from repro.rag import make_corpus


def std_args(desc: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=desc)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--queries", type=int, default=250)
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 1M docs, full dims (hours on CPU)")
    return ap


def load_corpus(args, *, dim: Optional[int] = None, **kw):
    if args.full:
        n_docs, n_queries = 1_000_000, 2470
        d = dim or 3584
    else:
        n_docs, n_queries = args.docs, args.queries
        d = dim or args.dim
    c = make_corpus(n_docs=n_docs, dim=d, n_queries=n_queries,
                    seed=args.seed, **kw)
    return (jnp.asarray(c.db), jnp.asarray(c.queries),
            jnp.asarray(c.ground_truth))


def timed_median(fn: Callable, runs: int) -> Tuple[float, object]:
    """Median wall-seconds over ``runs`` executions (post-warmup)."""
    out = fn()
    jax.block_until_ready(out)      # warmup / compile
    ts = []
    for _ in range(runs):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def truncated_row(q, db, gt, dim: int, runs: int, block_n: int = 16384):
    t, (s, i) = timed_median(
        lambda: truncated_search(q, db, dim=dim, k=1, block_n=block_n), runs)
    return {"dim": dim, "acc": float(top1_accuracy(i, gt)) * 100,
            "runtime_s": t}


def progressive_row(q, db, gt, d_start: int, d_max: int, k0: int,
                    runs: int, *, index=None, dims=None,
                    block_n: int = 16384):
    sched = make_schedule(d_start, d_max, k0)
    kw = {}
    if index is not None:
        kw = {"sq_prefix": index["sq_prefix"], "index_dims": dims}
    t, (s, i) = timed_median(
        lambda: progressive_search(q, db, sched, block_n=block_n, **kw), runs)
    return {"d_start": d_start, "d_max": d_max, "k0": k0,
            "acc": float(top1_accuracy(i, gt)) * 100, "runtime_s": t}


def print_csv(name: str, rows: List[Dict], cols: List[str]):
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    print()


def clamp_configs(grid, d_full: int):
    """Clamp (trunc_dim, (d_start, d_max, k0)) rows to a dim budget and dedupe.

    Small corpora (CI smoke runs) have fewer dims than the paper-scaled
    grids assume; clamping keeps every config runnable and deduping drops
    the rows clamping made identical.
    """
    out, seen = [], set()
    for trunc_dim, (ds, dm, k0) in grid:
        cfg = (min(trunc_dim, d_full),
               (min(ds, d_full), min(dm, d_full), k0))
        if cfg[1][0] <= cfg[1][1] and cfg not in seen:
            seen.add(cfg)
            out.append(cfg)
    return out

"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--docs N] [--dim D]

Order: Table II (truncated, gte) -> Table III (progressive vs truncated,
gte) -> Table IV (truncated, openai) -> Table V (progressive, openai) ->
Fig 3/4 scatter -> kernel micro-validation -> roofline summary (if the
dry-run sweep has produced results/dryrun/*.json).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import std_args


def main() -> None:
    args = std_args(__doc__).parse_args()
    t0 = time.time()

    from benchmarks import (fig3_scatter, table2_truncated_gte,
                            table3_progressive_gte, table4_truncated_openai,
                            table5_progressive_openai)

    print(f"=== corpus: docs={args.docs} dim={args.dim} "
          f"queries={args.queries} runs={args.runs} full={args.full} ===\n")

    table2_truncated_gte.run(args)
    table3_progressive_gte.run(args)
    table4_truncated_openai.run(args)
    table5_progressive_openai.run(args)
    fig3_scatter.run(args)

    # kernel validation micro-bench (interpret mode: correctness + call cost)
    print("# kernel_validation (interpret mode, CPU)")
    print("name,us_per_call,max_err_vs_ref")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.kernels.distance_topk import l2_topk
    from repro.kernels import ref as kref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    db = jnp.asarray(rng.normal(size=(2048, 64)), jnp.float32)
    t1 = time.perf_counter()
    s, i = l2_topk(q, db, k=8, block_q=32, block_n=256, interpret=True)
    jax.block_until_ready(s)
    us = (time.perf_counter() - t1) * 1e6
    rs, ri = kref.l2_topk_ref(q, db, 8)
    err = float(jnp.abs(s - rs).max())
    print(f"distance_topk,{us:.0f},{err:.2e}")
    print()

    # roofline summary from the dry-run artifacts, if present
    outdir = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if os.path.isdir(outdir) and os.listdir(outdir):
        print("# roofline (single-pod 16x16, from dry-run artifacts)")
        from benchmarks import roofline
        roofline.report(outdir, "single")

    print(f"\n=== benchmarks done in {time.time() - t0:.1f}s ===")


if __name__ == "__main__":
    main()

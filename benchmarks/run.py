"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--docs N] [--dim D]
    PYTHONPATH=src python -m benchmarks.run --list-bench

Order: Table II (truncated, gte) -> Table III (progressive vs truncated,
gte) -> Table IV (truncated, openai) -> Table V (progressive, openai) ->
Fig 3/4 scatter -> kernel micro-validation -> roofline summary (if the
dry-run sweep has produced results/dryrun/*.json).

``run.py`` itself prints paper tables; the committed ``results/BENCH_*.json``
perf records are refreshed by the sibling modules listed in
``BENCH_MANIFEST`` (printed at the end of every run, or alone with
``--list-bench``).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import std_args

# Which committed perf record each benchmark module refreshes.  CI's
# bench-smoke job runs every one of these with --smoke and uploads
# results/BENCH_*.json as artifacts; committed copies track the perf
# trajectory in-repo.
BENCH_MANIFEST = (
    ("results/BENCH_engine.json",
     "python -m benchmarks.engine_throughput"),
    ("results/BENCH_driver.json",
     "python -m benchmarks.engine_throughput  (same run)"),
    ("results/BENCH_backends.json",
     "python -m benchmarks.backend_comparison"),
    ("results/BENCH_ivf_kernel.json",
     "python -m benchmarks.backend_comparison --ivf-kernel"),
    ("results/BENCH_pq.json",
     "python -m benchmarks.backend_comparison --pq"),
    ("results/BENCH_http.json",
     "python -m benchmarks.http_load"),
    ("results/BENCH_obs.json",
     "python -m benchmarks.obs_overhead"),
)


def print_bench_manifest() -> None:
    root = os.path.join(os.path.dirname(__file__), "..")
    print("# BENCH records refreshed by the benchmark suite "
          "(all accept --smoke):")
    for rel, cmd in BENCH_MANIFEST:
        present = "present" if os.path.exists(os.path.join(root, rel)) \
            else "MISSING"
        print(f"#   {rel:<32} <- {cmd}   [{present}]")


def main() -> None:
    ap = std_args(__doc__)
    ap.add_argument("--list-bench", action="store_true",
                    help="list the BENCH_*.json records the suite refreshes "
                         "(and which module writes each), then exit")
    args = ap.parse_args()
    if args.list_bench:
        print_bench_manifest()
        return
    t0 = time.time()

    from benchmarks import (fig3_scatter, table2_truncated_gte,
                            table3_progressive_gte, table4_truncated_openai,
                            table5_progressive_openai)

    print(f"=== corpus: docs={args.docs} dim={args.dim} "
          f"queries={args.queries} runs={args.runs} full={args.full} ===\n")

    table2_truncated_gte.run(args)
    table3_progressive_gte.run(args)
    table4_truncated_openai.run(args)
    table5_progressive_openai.run(args)
    fig3_scatter.run(args)

    # kernel validation micro-bench (interpret mode: correctness + call cost)
    print("# kernel_validation (interpret mode, CPU)")
    print("name,us_per_call,max_err_vs_ref")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.kernels.distance_topk import l2_topk
    from repro.kernels import ref as kref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    db = jnp.asarray(rng.normal(size=(2048, 64)), jnp.float32)
    t1 = time.perf_counter()
    s, i = l2_topk(q, db, k=8, block_q=32, block_n=256, interpret=True)
    jax.block_until_ready(s)
    us = (time.perf_counter() - t1) * 1e6
    rs, ri = kref.l2_topk_ref(q, db, 8)
    err = float(jnp.abs(s - rs).max())
    print(f"distance_topk,{us:.0f},{err:.2e}")
    print()

    # roofline summary from the dry-run artifacts, if present
    outdir = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if os.path.isdir(outdir) and os.listdir(outdir):
        print("# roofline (single-pod 16x16, from dry-run artifacts)")
        from benchmarks import roofline
        roofline.report(outdir, "single")

    print()
    print_bench_manifest()
    print(f"\n=== benchmarks done in {time.time() - t0:.1f}s ===")


if __name__ == "__main__":
    main()

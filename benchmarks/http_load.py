"""Open-loop HTTP load benchmark for the `repro.serve` front-end.

Boots the HTTP server over a fresh engine (or targets a running one with
``--url``), seeds ``--tenants`` isolated namespaces with metadata-tagged
documents, then drives them concurrently:

* per tenant, ``--clients`` open-loop threads submit ``--requests``
  searches (half of them metadata-filtered) and record status + latency;
* per tenant, one churn thread adds and deletes documents over HTTP the
  whole time, so the measurement covers the mutation path racing the
  search path.

Every returned doc id is checked against the requesting tenant's own
id universe after the run — cross-tenant leakage is a hard failure, as is
any response outside {2xx, 429} (429 is the admission-control contract,
not an error).  The run also exercises the observability surface:
``/metrics`` is scraped mid-run (the exposition must parse) and again at
quiescence (every histogram's ``_count`` must agree with its paired
counter), and every sampled 200 search response must carry a queue-wait
span.  Writes per-tenant QPS / p50 / p95 (computed through the shared
``repro.obs`` histogram buckets, so they are directly comparable to
``/metrics`` percentiles) and the global summary to
``results/BENCH_http.json`` alongside ``BENCH_driver.json``.

    PYTHONPATH=src python -m benchmarks.http_load --smoke
    PYTHONPATH=src python -m benchmarks.http_load \
        --tenants 4 --docs 2000 --requests 256 --clients 8 --backend ivf
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.serve import http_json
from repro.obs import parse_prometheus, summarize_latency

N_SHARDS = 4                       # metadata cardinality for filtered queries


def scrape_metrics(url, timeout=30.0):
    """GET /metrics and parse the exposition (raises on malformed text)."""
    with urllib.request.urlopen(url.rstrip("/") + "/metrics",
                                timeout=timeout) as resp:
        text = resp.read().decode()
    return parse_prometheus(text), text


def check_histogram_counter_pairs(metrics):
    """Every histogram ``_count`` must agree with its paired counter.

    Only meaningful at quiescence: a histogram and its counter are updated
    under one lock, but a scrape renders families one at a time, so a
    mid-run snapshot can legally catch them apart.  Returns failure
    strings (empty = all invariants hold).
    """
    problems = []
    # engine: the latency histogram observes every completed request
    completed = metrics.get(
        "repro_engine_requests_completed_total", {}).get((), 0.0)
    lat_count = metrics.get(
        "repro_engine_request_latency_ms_count", {}).get((), 0.0)
    if completed != lat_count:
        problems.append(
            f"latency histogram count {lat_count} != "
            f"requests_completed_total {completed}")
    # http: per route, the latency histogram count == sum over statuses
    http_hist = metrics.get("repro_http_request_ms_count", {})
    http_total = metrics.get("repro_http_requests_total", {})
    by_route = {}
    for key, v in http_total.items():
        route = dict(key).get("route")
        by_route[route] = by_route.get(route, 0.0) + v
    for key, v in http_hist.items():
        route = dict(key).get("route")
        # the scrape currently being rendered hasn't counted itself yet
        if route == "/metrics":
            continue
        if by_route.get(route, 0.0) != v:
            problems.append(
                f"http histogram count {v} != status-counter sum "
                f"{by_route.get(route, 0.0)} for route {route}")
    return problems


def boot_server(args):
    """In-process server: engine + driver + HTTP listener on a free port."""
    from repro.engine import EngineConfig, EngineDriver, RetrievalEngine
    from repro.serve import TenantQuotas, serve_in_thread

    config = EngineConfig.from_flags(
        args, d_emb=args.dim,
        capacity=max(1024, args.tenants * args.docs * 2))
    # the isolation check tracks doc ids across the run; compaction remaps
    # them mid-flight, which is covered by the in-process hypothesis suite —
    # here we keep ids stable so leakage is exactly set membership
    config = dataclasses.replace(config, compact_dead_frac=None)
    engine = RetrievalEngine(config=config)
    driver = EngineDriver(engine, max_wait_ms=args.max_wait_ms,
                          max_queue=args.max_queue).start()
    quotas = TenantQuotas(
        max_inflight=args.max_inflight if args.max_inflight > 0 else None)
    handle = serve_in_thread(engine, driver, quotas=quotas)
    return handle, driver


def run_tenant_searches(url, tenant, queries, n_clients, k, results, qps):
    """Open-loop search threads for one tenant; appends per-request records
    ``(status, latency_s, ids, filtered_shard, spans)`` to ``results``."""
    shards = np.array_split(np.arange(len(queries)), n_clients)
    period = n_clients / qps if qps > 0 else 0.0
    lock = threading.Lock()
    rng = np.random.default_rng(abs(hash(tenant)) % (2 ** 31))
    filter_plan = rng.integers(-1, N_SHARDS, len(queries))  # -1 = unfiltered

    def client(shard):
        t_next = time.perf_counter()
        for i in shard:
            if period:
                now = time.perf_counter()
                if now < t_next:
                    time.sleep(t_next - now)
                t_next += period
            body = {"query": queries[i].tolist(), "tenant": tenant, "k": k}
            shard_tag = int(filter_plan[i])
            if shard_tag >= 0:
                body["filter"] = {"shard": {"$eq": shard_tag}}
            t0 = time.perf_counter()
            status, payload = http_json(url, "/v1/search", body)
            dt = time.perf_counter() - t0
            ids = payload.get("ids", []) if status == 200 else []
            spans = payload.get("spans") if status == 200 else None
            with lock:
                results.append((status, dt, ids, shard_tag, spans))

    threads = [threading.Thread(target=client, args=(s,), daemon=True)
               for s in shards if len(s)]
    for t in threads:
        t.start()
    return threads


def run_churn(url, tenant, dim, universe, universe_lock, stop, rng,
              statuses):
    """Add/delete loop for one tenant, racing the search traffic."""
    my_ids = []
    while not stop.is_set():
        vecs = rng.standard_normal((2, dim)).astype(np.float32)
        status, payload = http_json(url, "/v1/docs", {
            "vectors": vecs.tolist(), "tenant": tenant,
            "metadata": [{"shard": int(rng.integers(N_SHARDS)),
                          "churn": True} for _ in range(2)]})
        statuses.append(status)
        if status == 200:
            with universe_lock:
                universe[tenant].update(payload["ids"])
            my_ids.extend(payload["ids"])
        if len(my_ids) >= 4:
            victims, my_ids = my_ids[:2], my_ids[2:]
            status, _ = http_json(url, "/v1/docs/delete", {
                "ids": victims, "tenant": tenant})
            statuses.append(status)
        time.sleep(0.002)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=2,
                    help="isolated namespaces driven concurrently (>= 2)")
    ap.add_argument("--docs", type=int, default=1000,
                    help="seeded docs per tenant")
    ap.add_argument("--requests", type=int, default=128,
                    help="searches per tenant")
    ap.add_argument("--clients", type=int, default=4,
                    help="open-loop search threads per tenant")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="per-tenant open-loop rate (0 = full speed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--url", type=str, default="",
                    help="target a running server instead of self-hosting")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="per-tenant in-flight quota (0 = unlimited)")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON (default results/BENCH_http.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    from repro.engine import EngineConfig
    EngineConfig.add_flags(ap)
    args = ap.parse_args()

    if args.smoke:
        args.tenants, args.docs, args.requests = 2, 200, 48
        args.clients, args.dim = 4, 64
        args.d_start, args.k0, args.final_k = 16, 16, 4
        args.buckets = "1,2,4,8"
    if args.tenants < 2:
        raise SystemExit("--tenants must be >= 2 (isolation is the point)")

    handle = driver = None
    if args.url:
        url = args.url
    else:
        handle, driver = boot_server(args)
        url = handle.url
    tenants = [f"tenant-{i}" for i in range(args.tenants)]
    rng = np.random.default_rng(args.seed)
    failures = []

    try:
        status, health = http_json(url, "/healthz")
        if status != 200:
            raise SystemExit(f"server unhealthy: {status} {health}")
        print(f"# http_load url={url} tenants={args.tenants} "
              f"docs/tenant={args.docs} requests/tenant={args.requests} "
              f"clients/tenant={args.clients} smoke={args.smoke}")

        # --- seed: metadata-tagged docs per tenant -------------------------
        universe = {t: set() for t in tenants}
        universe_lock = threading.Lock()
        for t in tenants:
            vecs = rng.standard_normal((args.docs, args.dim)).astype(
                np.float32)
            meta = [{"shard": j % N_SHARDS} for j in range(args.docs)]
            status, payload = http_json(url, "/v1/docs", {
                "vectors": vecs.tolist(), "tenant": t, "metadata": meta})
            if status != 200:
                raise SystemExit(f"seed failed for {t}: {status} {payload}")
            universe[t].update(payload["ids"])

        # --- measurement: searches + churn, all tenants at once ------------
        per_tenant_results = {t: [] for t in tenants}
        churn_statuses = {t: [] for t in tenants}
        stop_churn = threading.Event()
        churn_threads = [
            threading.Thread(
                target=run_churn,
                args=(url, t, args.dim, universe, universe_lock, stop_churn,
                      np.random.default_rng(args.seed + 100 + i),
                      churn_statuses[t]),
                daemon=True)
            for i, t in enumerate(tenants)]
        for ct in churn_threads:
            ct.start()
        search_threads = []
        t0 = time.perf_counter()
        for t in tenants:
            queries = rng.standard_normal(
                (args.requests, args.dim)).astype(np.float32)
            search_threads += run_tenant_searches(
                url, t, queries, max(1, min(args.clients, args.requests)),
                args.final_k, per_tenant_results[t], args.qps)
        # mid-run observability check: the exposition must parse while the
        # search/churn traffic is in full flight (parse_prometheus raises
        # on a malformed line, which lands in failures below)
        midrun_metric_names = 0
        try:
            time.sleep(0.05)
            midrun, _ = scrape_metrics(url)
            midrun_metric_names = len(midrun)
        except Exception as e:
            failures.append(f"mid-run /metrics scrape failed: {e}")
        for st in search_threads:
            st.join()
        wall = time.perf_counter() - t0
        stop_churn.set()
        for ct in churn_threads:
            ct.join(timeout=30)

        # --- verdicts ------------------------------------------------------
        records = []
        total_ok = total_429 = total_bad = total_leaks = 0
        print("tenant,requests,ok,throttled,bad,qps,p50_ms,p95_ms,leaks")
        for t in tenants:
            rows = per_tenant_results[t]
            lat_ms = [dt * 1e3 for s, dt, _, _, _ in rows if s == 200]
            # shared bucket ladder: same percentile math as /metrics
            pct = summarize_latency(lat_ms)
            n_ok = sum(1 for s, _, _, _, _ in rows if 200 <= s < 300)
            n_429 = sum(1 for s, _, _, _, _ in rows if s == 429)
            bad = [s for s, _, _, _, _ in rows
                   if not (200 <= s < 300 or s == 429)]
            bad += [s for s in churn_statuses[t]
                    if not (200 <= s < 300 or s == 429)]
            # isolation: every id ever returned to t was added under t
            # (universes only grow, so checking after the join is race-free)
            leaks = sum(1 for s, _, ids, _, _ in rows if s == 200
                        for i in ids if i not in universe[t])
            # trace spans: every served response must decompose its
            # latency, with the queue-wait span always present
            no_span = sum(
                1 for s, _, _, _, spans in rows if s == 200
                and (spans is None or spans.get("queue_ms") is None))
            rec = {
                "tenant": t,
                "requests": len(rows),
                "n_ok": n_ok,
                "n_throttled": n_429,
                "n_bad_status": len(bad),
                "qps": len(rows) / wall,
                "latency_ms_p50": pct["p50"],
                "latency_ms_p95": pct["p95"],
                "isolation_violations": leaks,
                "churn_ops": len(churn_statuses[t]),
                "n_missing_spans": no_span,
            }
            records.append(rec)
            total_ok += n_ok
            total_429 += n_429
            total_bad += len(bad)
            total_leaks += leaks
            if bad:
                failures.append(
                    f"{t}: {len(bad)} non-2xx/429 responses "
                    f"(e.g. {bad[:3]})")
            if leaks:
                failures.append(f"{t}: {leaks} cross-tenant ids returned")
            if no_span:
                failures.append(
                    f"{t}: {no_span} responses missing queue-wait spans")
            print(f"{t},{rec['requests']},{n_ok},{n_429},{len(bad)},"
                  f"{rec['qps']:.1f},{rec['latency_ms_p50']:.2f},"
                  f"{rec['latency_ms_p95']:.2f},{leaks}")

        # quiescent scrape: histogram/_count-vs-counter invariants only
        # hold once traffic stops (families render one at a time)
        n_metric_names = 0
        try:
            final_metrics, _ = scrape_metrics(url)
            n_metric_names = len(final_metrics)
            failures.extend(check_histogram_counter_pairs(final_metrics))
        except Exception as e:
            failures.append(f"final /metrics scrape failed: {e}")

        out_path = args.out or os.path.join(
            os.path.dirname(__file__), "..", "results", "BENCH_http.json")
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({
                "benchmark": "http_load",
                "smoke": args.smoke,
                "tenants": args.tenants,
                "docs_per_tenant": args.docs,
                "requests_per_tenant": args.requests,
                "clients_per_tenant": args.clients,
                "dim": args.dim,
                "wall_s": wall,
                "qps_total": total_ok / wall if wall else 0.0,
                "n_ok": total_ok,
                "n_throttled": total_429,
                "n_bad_status": total_bad,
                "isolation_violations": total_leaks,
                "metric_families_midrun": midrun_metric_names,
                "metric_families_final": n_metric_names,
                "records": records,
            }, f, indent=2)
        print(f"# wrote {os.path.normpath(out_path)}")
    finally:
        if handle is not None:
            handle.stop()
        if driver is not None:
            driver.stop()

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print(f"# OK: {total_ok} served, {total_429} throttled, "
          f"0 bad statuses, 0 isolation violations")


if __name__ == "__main__":
    main()

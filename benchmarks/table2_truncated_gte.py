"""Paper Table II: accuracy + runtime of Truncated Retrieval vs dimension
(gte-Qwen2-7B-instruct regime: synthetic corpus calibrated to its curve).

Also reproduces the §III.C PCA-vs-truncation comparison that led the paper
to choose truncation.
"""


from benchmarks.common import load_corpus, print_csv, std_args, truncated_row

PAPER_GTE = {16: 6.56, 32: 39.55, 64: 78.42, 128: 88.79, 256: 92.79,
             512: 93.81, 1024: 94.49, 2048: 94.82, 3072: 94.98, 3584: 95.02}


def run(args=None):
    args = args or std_args(__doc__).parse_args([])
    db, q, gt = load_corpus(args)
    d_full = db.shape[1]
    dims = [d for d in (16, 32, 64, 128, 256, 512, 1024, 2048, 3584)
            if d <= d_full]
    rows = []
    for d in dims:
        r = truncated_row(q, db, gt, d, args.runs)
        r["paper_acc"] = PAPER_GTE.get(d, float("nan"))
        rows.append(r)
    print_csv("table2_truncated_gte (synthetic corpus, gte-calibrated)",
              rows, ["dim", "acc", "runtime_s", "paper_acc"])

    # runtime must grow ~linearly in dim (paper: "Run-Time ... is linear")
    ts = [r["runtime_s"] for r in rows]
    assert ts[-1] > ts[0], "runtime should grow with dim"

    # PCA vs truncation (paper §III.C: truncation slightly better, cheaper)
    from repro.core import fit_pca_power, pca_transform, truncated_search, top1_accuracy
    k = min(128, d_full)
    st = fit_pca_power(db, k, n_iter=6)
    db_p, q_p = pca_transform(st, db), pca_transform(st, q)
    pca_rows = []
    for d in [x for x in (32, 64, 128) if x <= k]:
        _, it = truncated_search(q, db, dim=d, k=1)
        _, ip = truncated_search(q_p, db_p, dim=d, k=1)
        pca_rows.append({
            "dim": d,
            "trunc_acc": float(top1_accuracy(it, gt)) * 100,
            "pca_acc": float(top1_accuracy(ip, gt)) * 100,
        })
    print_csv("table2b_pca_vs_truncation", pca_rows,
              ["dim", "trunc_acc", "pca_acc"])
    return rows


if __name__ == "__main__":
    run(std_args(__doc__).parse_args())

"""Chaos soak: the fault-tolerance layer exercised end to end.

Five phases, all driven by the deterministic `repro.engine.faults` harness
or explicit file surgery (never racing real hardware faults), recorded to
``results/BENCH_chaos.json``:

1. **sigkill durability** — a child process acknowledges WAL-backed
   mutations and is SIGKILLed mid-churn; the parent recovers the state
   directory and must hold every acknowledged add, resurrect no tombstone,
   and serve the recovered corpus.  Records recovery + replay timings.
2. **torn checkpoint** — the newest snapshot's manifest is corrupted on
   disk; recovery must detect the damage via checksums, fall back to the
   previous snapshot, and replay the WAL tail so no acknowledged mutation
   is lost.
3. **crash storm** — a supervised driver whose dispatches crash with
   probability p; the supervisor must restart the thread (capped backoff)
   and the service must keep answering between crashes and after the storm.
4. **rebuild retry** — background index rebuilds fail transiently; the
   engine must keep serving the old index, retry, and adopt the rebuilt
   index once a build succeeds.
5. **poison isolation** — a batch carrying poison requests; bisection must
   quarantine exactly the offenders while every clean request is served.
6. **replica kill** — a primary + two WAL-tailing followers behind an
   in-process `ReplicaRouter`; one follower is SIGKILLed under open-loop
   search load.  Zero non-429 search failures are tolerated, the router
   must open the dead replica's breaker within the probe window, and the
   restarted follower must rejoin via snapshot + WAL catch-up and serve
   again.  Includes a read-your-writes sub-check (a ``min_seq`` token from
   a mutation is honoured on every replica) and a deterministic
   ``replica_apply`` fault-injection sub-check.

Exit status is non-zero if any check fails.  ``--smoke`` (CI) shrinks the
corpus and the storm but enforces every check — all six phases are
deterministic, so nothing is skipped:

    PYTHONPATH=src python -m benchmarks.chaos_soak --smoke
    PYTHONPATH=src python -m benchmarks.chaos_soak
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

WAIT = 60.0
FAST_FT = dict(heartbeat_timeout_s=0.2, backoff_initial_s=0.01,
               backoff_max_s=0.05)


def make_engine(args, *, n_docs=None, fault=None, **kw):
    from repro.engine import RetrievalEngine

    kw.setdefault("d_start", 8)
    kw.setdefault("k0", 16)
    kw.setdefault("buckets", (1, 2, 4, 8))
    kw.setdefault("capacity", max(args.docs * 2, 128))
    kw.setdefault("block_n", 64)
    eng = RetrievalEngine(args.dim, fault=fault, **kw)
    rng = np.random.default_rng(args.seed)
    n = args.docs if n_docs is None else n_docs
    db = rng.normal(size=(max(n, 1), args.dim)).astype(np.float32)
    if n:
        eng.add_docs(db)
    return eng, db


def wait_until(pred, timeout=WAIT, msg="condition"):
    deadline = time.perf_counter() + timeout
    while not pred():
        if time.perf_counter() >= deadline:
            raise TimeoutError(f"timed out waiting: {msg}")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# phase 1: SIGKILL a churning child, recover in-process
# ---------------------------------------------------------------------------
CHILD = r"""
import os, sys, numpy as np
sys.path.insert(0, {src!r})
from repro.engine import RetrievalEngine

eng = RetrievalEngine({d}, d_start=8, k0=16, buckets=(1,), capacity=4096,
                      block_n=64)
eng.enable_durability({state!r})
rng = np.random.default_rng(7)
ack = open(os.path.join({state!r}, "acked.log"), "a")
os.write(1, b"ready\n")
i = 0
while True:
    vecs = rng.normal(size=(2, {d})).astype(np.float32) + i
    ids = eng.add_docs(vecs)
    if i % 5 == 4:
        eng.delete_docs(ids[:1])
        note = f"del {{ids[0]}}\n"
    else:
        note = ""
    if i == {snap_at}:
        eng.save_snapshot()
    # ack AFTER the engine returned: the WAL record is already fsync'd
    ack.write(f"add {{ids[0]}} {{ids[1]}}\n" + note)
    ack.flush(); os.fsync(ack.fileno())
    i += 1
"""


def phase_sigkill(args, state: str) -> dict:
    from repro.engine import RetrievalEngine

    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    code = CHILD.format(src=src, d=args.dim, state=state,
                        snap_at=args.churn_snapshot_at)
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE)
    try:
        assert proc.stdout.readline().strip() == b"ready"
        time.sleep(args.churn_s)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=WAIT)
    finally:
        if proc.poll() is None:
            proc.kill()

    acked_adds, acked_dels = set(), set()
    with open(os.path.join(state, "acked.log")) as f:
        for line in f:
            kind, *ids = line.split()
            if kind == "add":
                acked_adds.update(int(x) for x in ids)
            else:
                acked_dels.add(int(ids[0]))

    eng = RetrievalEngine(args.dim, d_start=8, k0=16, buckets=(1,),
                          capacity=4096, block_n=64)
    t0 = time.perf_counter()
    report = eng.recover(state)
    recover_s = time.perf_counter() - t0
    live = acked_adds - acked_dels
    lost = [i for i in sorted(live) if not eng.store.is_live(i)]
    resurrected = [i for i in sorted(acked_dels) if eng.store.is_live(i)]
    some = sorted(live)[:4]
    q = np.stack([np.asarray(eng.store.db[i]) for i in some])
    _, idx = eng.search(q)
    serves = bool(np.array_equal(idx[:, 0], some))
    eng.wal.close()
    return {
        "acked_adds": len(acked_adds),
        "acked_deletes": len(acked_dels),
        "lost": lost,
        "resurrected": resurrected,
        "serves_recovered_docs": serves,
        "recover_wall_s": recover_s,
        "report": report,
    }


# ---------------------------------------------------------------------------
# phase 2: corrupt the newest snapshot, fall back + replay
# ---------------------------------------------------------------------------
def phase_torn_checkpoint(args, state: str) -> dict:
    from repro.engine import RetrievalEngine

    eng, _ = make_engine(args, n_docs=0)
    eng.enable_durability(state)
    rng = np.random.default_rng(args.seed + 1)
    a = rng.normal(size=(args.docs, args.dim)).astype(np.float32)
    eng.add_docs(a)
    eng.save_snapshot()
    b = rng.normal(size=(16, args.dim)).astype(np.float32)
    eng.add_docs(b)
    eng.save_snapshot()                    # newest — about to be torn
    c = rng.normal(size=(8, args.dim)).astype(np.float32)
    ids_c = eng.add_docs(c)                # WAL-only tail
    eng.wal.close()

    snaps = sorted(d for d in os.listdir(state) if d.startswith("step_"))
    manifest = os.path.join(state, snaps[-1], "manifest.msgpack")
    with open(manifest, "wb") as f:
        f.write(b"\xc1 torn mid-write")

    eng2, _ = make_engine(args, n_docs=0)
    report = eng2.recover(state)
    _, idx = eng2.search(c[:1])
    eng2.wal.close()
    return {
        "report": report,
        "tail_doc_served": bool(idx[0, 0] == ids_c[0]),
        "n_docs_recovered": eng2.n_docs,
        "n_docs_expected": args.docs + 16 + 8,
    }


# ---------------------------------------------------------------------------
# phase 3: probabilistic crash storm under supervision
# ---------------------------------------------------------------------------
def phase_crash_storm(args) -> dict:
    from repro.engine import (DriverStopped, EngineDriver,
                              FaultToleranceConfig, Supervisor)

    eng, db = make_engine(args, fault=FaultToleranceConfig(
        inject=f"dispatch:crash@p={args.crash_p}",
        inject_seed=args.seed, max_restarts=10 ** 6, **FAST_FT))
    driver = EngineDriver(eng, max_wait_ms=0.0, max_queue=256)
    driver.start(supervised=True)
    sup = Supervisor(driver).start()
    served = failed = 0
    t0 = time.perf_counter()
    try:
        for i in range(args.storm_requests):
            try:
                res = driver.retrieve(db[i % len(db)], timeout=WAIT)
                served += 1
                assert res.doc_ids[0] == i % len(db)
            except DriverStopped:
                failed += 1               # our chunk crashed; storm goes on
                wait_until(lambda: driver.health()["thread_alive"],
                           msg="supervisor restart mid-storm")
        # calm after the storm: disarm and require clean service
        eng.faults = type(eng.faults)()
        wait_until(lambda: driver.health()["thread_alive"],
                   msg="driver alive post-storm")
        final = driver.retrieve(db[0], timeout=WAIT)
        survived = bool(final.doc_ids[0] == 0)
    finally:
        sup.stop()
        driver.stop()
    return {
        "requests": args.storm_requests,
        "served": served,
        "crash_failed": failed,
        "crashes": driver.stats.n_driver_crashes,
        "restarts": driver.stats.n_restarts,
        "survived_storm": survived,
        "wall_s": time.perf_counter() - t0,
        "supervisor": sup.summary(),
    }


# ---------------------------------------------------------------------------
# phase 4: transient background-rebuild failures retried to adoption
# ---------------------------------------------------------------------------
def phase_rebuild_retry(args) -> dict:
    from repro.engine import FaultPlan, FaultToleranceConfig, RetrievalEngine

    rng = np.random.default_rng(args.seed + 2)
    eng = RetrievalEngine(
        args.dim, d_start=8, k0=16, buckets=(1, 2), capacity=args.docs * 4,
        block_n=64, backend="quantized",
        backend_opts={"min_rebuild_rows": 8}, rebuild_mode="background",
        fault=FaultToleranceConfig(rebuild_retries=5))
    db = rng.normal(size=(args.docs, args.dim)).astype(np.float32)
    eng.add_docs(db)
    eng.search(db[:1])                     # warm (sync) build, clean
    eng.faults = FaultPlan.parse("rebuild:error@first=2")
    eng.add_docs(rng.normal(
        size=(args.docs, args.dim)).astype(np.float32))
    deadline = time.perf_counter() + WAIT
    while eng.stats.n_rebuilds < 2:
        eng.maybe_rebuild()
        if time.perf_counter() >= deadline:
            break
        time.sleep(0.01)
    _, idx = eng.search(db[:4])
    return {
        "rebuilds": eng.stats.n_rebuilds,
        "rebuild_failures": eng.stats.n_rebuild_failures,
        "adopted_after_retries": eng.stats.n_rebuilds >= 2,
        "serves_after_adoption": bool(
            np.array_equal(idx[:, 0], np.arange(4))),
    }


# ---------------------------------------------------------------------------
# phase 5: poison isolation by batch bisection
# ---------------------------------------------------------------------------
def phase_poison(args) -> dict:
    from repro.engine import (EngineDriver, FaultToleranceConfig,
                              RequestFailed)

    eng, db = make_engine(args, fault=FaultToleranceConfig(
        inject="dispatch:poison@v=777.0"))
    n = min(16, len(db))
    queries = [db[i].copy() for i in range(n)]
    poison_at = {1, n - 2}
    for i in poison_at:
        queries[i][0] = 777.0
    driver = EngineDriver(eng, max_wait_ms=60_000)   # unstarted: inline
    futs = [driver.submit(q) for q in queries]
    driver.stop(drain=True)
    isolated, clean_ok = 0, 0
    for i, f in enumerate(futs):
        exc = f.exception(0)
        if i in poison_at:
            isolated += isinstance(exc, RequestFailed)
        elif exc is None and f.result(0).doc_ids[0] == i:
            clean_ok += 1
    return {
        "batch": n,
        "poisoned": len(poison_at),
        "isolated": isolated,
        "clean_served": clean_ok,
        "quarantined": driver.stats.n_quarantined,
        "bisections": driver.stats.n_bisections,
    }


# ---------------------------------------------------------------------------
# phase 6: SIGKILL a replica under load; failover, rejoin, read-your-writes
# ---------------------------------------------------------------------------
def _free_ports(n: int):
    import socket

    socks = [socket.socket() for _ in range(n)]
    try:
        for s in socks:
            s.bind(("127.0.0.1", 0))
        return [s.getsockname()[1] for s in socks]
    finally:
        for s in socks:
            s.close()


def _spawn_server(role: str, state: str, port: int, dim: int, log_path: str,
                  snapshot_every_s: float = 0.0) -> subprocess.Popen:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    cmd = [sys.executable, "-m", "repro.launch.serve", "--serve-http",
           f"--role={role}", "--state-dir", state, "--port", str(port),
           "--allow-anonymous", "--docs", "0", "--d-emb", str(dim)]
    if snapshot_every_s > 0:
        cmd += ["--snapshot-every-s", str(snapshot_every_s)]
    env = dict(os.environ, PYTHONPATH=src)
    log = open(log_path, "ab")
    return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                            env=env)


def phase_replica_kill(args, state: str) -> dict:
    from repro.serve import ReplicaRouter, http_call

    os.makedirs(state, exist_ok=True)
    p_prim, p_f1, p_f2 = _free_ports(3)
    urls = [f"http://127.0.0.1:{p}" for p in (p_prim, p_f1, p_f2)]
    procs: dict = {}

    def boot(name, role, port):
        procs[name] = _spawn_server(
            role, state, port, args.dim,
            os.path.join(state, f"{name}.log"),
            snapshot_every_s=1.0 if role == "primary" else 0.0)

    def wait_ready_url(url, timeout=WAIT):
        wait_until(lambda: http_call(url, "/healthz?ready=1",
                                     timeout=2.0)[0] == 200,
                   timeout=timeout, msg=f"{url} ready")

    router = None
    try:
        boot("primary", "primary", p_prim)
        wait_ready_url(urls[0])
        boot("f1", "follower", p_f1)
        boot("f2", "follower", p_f2)
        wait_ready_url(urls[1])
        wait_ready_url(urls[2])

        router = ReplicaRouter(urls, probe_interval_s=0.1,
                               failure_threshold=2, breaker_open_s=0.2,
                               request_timeout_s=WAIT).start()
        router.wait_ready(3, timeout=WAIT)

        rng = np.random.default_rng(args.seed + 6)
        docs = rng.normal(size=(args.docs, args.dim)).astype(np.float32)
        status, payload, _ = router.mutate("/v1/docs", {
            "vectors": docs.tolist(), "tenant": "chaos"})
        assert status == 200, f"seed add failed: {status} {payload}"

        # read-your-writes: a fresh mutation's seq token must be honoured
        # on EVERY replica — no replica may serve a pre-mutation view
        marker = (rng.normal(size=(1, args.dim)) + 50.0).astype(np.float32)
        status, payload, _ = router.mutate("/v1/docs", {
            "vectors": marker.tolist(), "tenant": "chaos"})
        assert status == 200, f"marker add failed: {status} {payload}"
        marker_id, marker_seq = payload["ids"][0], payload["seq"]
        ryw = {}
        for url in urls:
            s, p = http_call(url, "/v1/search", {
                "query": marker[0].tolist(), "tenant": "chaos", "k": 1,
                "min_seq": marker_seq, "deadline_ms": 30_000}, timeout=WAIT)
            ryw[url] = bool(s == 200 and p["ids"][0] == marker_id)

        # open-loop load; SIGKILL one follower a third of the way in
        n_req = args.replica_requests
        queries = rng.normal(size=(n_req, args.dim)).astype(np.float32)
        codes = []
        kill_at = n_req // 3
        t_kill = t_detect = None
        f1_ep = next(ep for ep in router.replicas if ep.url == urls[1])
        for i in range(n_req):
            if i == kill_at:
                os.kill(procs["f1"].pid, signal.SIGKILL)
                procs["f1"].wait(timeout=WAIT)
                t_kill = time.perf_counter()
            s, _, _ = router.search({
                "query": queries[i].tolist(), "tenant": "chaos", "k": 1,
                "deadline_ms": 30_000})
            codes.append(s)
            if t_kill is not None and t_detect is None \
                    and not (f1_ep.alive and f1_ep.breaker.allow()):
                t_detect = time.perf_counter()
        if t_detect is None and not (f1_ep.alive and f1_ep.breaker.allow()):
            t_detect = time.perf_counter()
        bad = [c for c in codes if c not in (200, 429)]
        detect_s = (t_detect - t_kill) if t_detect else None

        # rejoin: wait for a primary snapshot so the restart exercises the
        # snapshot + WAL-tail bootstrap path, then bring f1 back
        wait_until(lambda: any(d.startswith("step_")
                               for d in os.listdir(state)),
                   msg="primary snapshot on disk")
        boot("f1", "follower", p_f1)
        wait_ready_url(urls[1])
        s, deep = http_call(urls[1], "/healthz?deep=1", timeout=WAIT)
        repl = (deep.get("deep") or {}).get("replication") or {}
        boot_report = repl.get("last_bootstrap") or {}
        prim_seq = http_call(urls[0], "/healthz",
                             timeout=WAIT)[1]["applied_seq"]
        wait_until(lambda: http_call(
            urls[1], "/healthz",
            timeout=2.0)[1].get("applied_seq", -1) >= prim_seq,
            msg="restarted follower catches up")
        s, p = http_call(urls[1], "/v1/search", {
            "query": marker[0].tolist(), "tenant": "chaos", "k": 1,
            "min_seq": marker_seq, "deadline_ms": 30_000}, timeout=WAIT)
        rejoined_serves = bool(s == 200 and p["ids"][0] == marker_id)

        return {
            "requests": n_req,
            "codes": {str(c): codes.count(c) for c in sorted(set(codes))},
            "non_retryable_failures": len(bad),
            "failover_detect_s": detect_s,
            "read_your_writes": ryw,
            "rejoin_bootstrap_snapshot": boot_report.get("snapshot_step"),
            "rejoined_serves_min_seq": rejoined_serves,
            "router": router.status(),
        }
    finally:
        if router is not None:
            router.stop()
        for proc in procs.values():
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=WAIT)


def phase_replica_faults(args) -> dict:
    """In-process ``wal_ship``/``replica_apply`` fault-site sub-check: the
    applier counts and retries injected faults, then converges."""
    import tempfile

    from repro.engine import FaultPlan, ReplicaApplier, RetrievalEngine

    rng = np.random.default_rng(args.seed + 7)
    with tempfile.TemporaryDirectory() as td:
        prim = RetrievalEngine(args.dim, d_start=8, k0=16, buckets=(1,),
                               capacity=1024, block_n=64)
        prim.enable_durability(td)
        prim.add_docs(rng.normal(size=(32, args.dim)).astype(np.float32))
        want = prim.wal.last_seq

        foll = RetrievalEngine(args.dim, d_start=8, k0=16, buckets=(1,),
                               capacity=1024, block_n=64)
        foll.faults = FaultPlan.parse(
            "wal_ship:error@first=1;replica_apply:error@first=2",
            seed=args.seed)
        applier = ReplicaApplier(foll, td, poll_s=0.01)
        applier.bootstrap()
        applier.start()
        try:
            wait_until(lambda: applier.applied_seq >= want,
                       msg="applier converges through injected faults")
        finally:
            applier.stop()
            prim.wal.close()
        st = applier.status()
        return {
            "applied_seq": st["applied_seq"],
            "want_seq": want,
            "n_poll_errors": st["n_poll_errors"],
            "n_apply_errors": st["n_apply_errors"],
            "n_docs": foll.n_docs,
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--churn-s", type=float, default=2.0,
                    help="how long the SIGKILL child churns mutations")
    ap.add_argument("--churn-snapshot-at", type=int, default=40,
                    help="child iteration that cuts a mid-churn snapshot")
    ap.add_argument("--storm-requests", type=int, default=200)
    ap.add_argument("--crash-p", type=float, default=0.2)
    ap.add_argument("--replica-requests", type=int, default=120,
                    help="open-loop searches driven through the router "
                         "while a replica is SIGKILLed")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; every check still enforced")
    args = ap.parse_args()

    if args.smoke:
        args.docs, args.dim = 128, 32
        args.churn_s, args.churn_snapshot_at = 0.6, 15
        args.storm_requests = 60
        args.replica_requests = 60

    import tempfile

    print(f"# chaos_soak docs={args.docs} dim={args.dim} "
          f"churn_s={args.churn_s} storm={args.storm_requests} "
          f"smoke={args.smoke}")

    with tempfile.TemporaryDirectory() as td:
        sigkill = phase_sigkill(args, os.path.join(td, "sigkill"))
    print(f"sigkill: acked={sigkill['acked_adds']} lost={sigkill['lost']} "
          f"recover_s={sigkill['recover_wall_s']:.3f} "
          f"replayed={sigkill['report']['replayed']}")

    with tempfile.TemporaryDirectory() as td:
        torn = phase_torn_checkpoint(args, os.path.join(td, "torn"))
    print(f"torn: fallbacks={torn['report']['fallbacks']} "
          f"replayed={torn['report']['replayed']} "
          f"docs={torn['n_docs_recovered']}/{torn['n_docs_expected']}")

    storm = phase_crash_storm(args)
    print(f"storm: served={storm['served']}/{storm['requests']} "
          f"crashes={storm['crashes']} restarts={storm['restarts']} "
          f"wall_s={storm['wall_s']:.2f}")

    rebuild = phase_rebuild_retry(args)
    print(f"rebuild: failures={rebuild['rebuild_failures']} "
          f"adopted={rebuild['adopted_after_retries']}")

    poison = phase_poison(args)
    print(f"poison: isolated={poison['isolated']}/{poison['poisoned']} "
          f"clean={poison['clean_served']}/{poison['batch'] - 2}")

    with tempfile.TemporaryDirectory() as td:
        replica = phase_replica_kill(args, os.path.join(td, "replica"))
    print(f"replica: codes={replica['codes']} "
          f"detect_s={replica['failover_detect_s']} "
          f"ryw={sum(replica['read_your_writes'].values())}/"
          f"{len(replica['read_your_writes'])} "
          f"rejoined={replica['rejoined_serves_min_seq']}")

    rfaults = phase_replica_faults(args)
    print(f"replica-faults: poll_errors={rfaults['n_poll_errors']} "
          f"apply_errors={rfaults['n_apply_errors']} "
          f"applied={rfaults['applied_seq']}/{rfaults['want_seq']}")

    checks = {
        # 1: every fsync-acked mutation survives SIGKILL
        "sigkill_child_did_real_work": sigkill["acked_adds"] > 4,
        "sigkill_no_acked_loss": not sigkill["lost"],
        "sigkill_no_resurrection": not sigkill["resurrected"],
        "sigkill_recovered_corpus_serves":
            sigkill["serves_recovered_docs"],
        # 2: checksum catches the torn snapshot; fallback + replay is exact
        "torn_fallback_taken": torn["report"]["fallbacks"] >= 1,
        "torn_status_ok": torn["report"]["status"] == "ok",
        "torn_tail_replayed": torn["report"]["replayed"] > 0
            and torn["tail_doc_served"],
        "torn_no_doc_lost":
            torn["n_docs_recovered"] == torn["n_docs_expected"],
        # 3: the storm is survived, not merely endured
        "storm_crashed_and_restarted": storm["crashes"] >= 1
            and storm["restarts"] >= 1,
        "storm_service_continued": storm["served"] > 0,
        "storm_survived": storm["survived_storm"],
        # 4: rebuild retries converge and the new index serves
        "rebuild_retried_to_adoption": rebuild["adopted_after_retries"]
            and rebuild["rebuild_failures"] == 2,
        "rebuild_serves": rebuild["serves_after_adoption"],
        # 5: exactly the poisons quarantined, every clean request served
        "poison_exact_isolation":
            poison["isolated"] == poison["poisoned"]
            and poison["quarantined"] == poison["poisoned"],
        "poison_clean_unharmed":
            poison["clean_served"] == poison["batch"] - poison["poisoned"],
        # 6: a SIGKILLed replica never surfaces as a non-429 failure; the
        #    breaker opens within the probe window; the restarted follower
        #    rejoins (snapshot + WAL tail) and honours old min_seq tokens
        "replica_zero_nonretryable_failures":
            replica["non_retryable_failures"] == 0,
        "replica_failover_within_probe_window":
            replica["failover_detect_s"] is not None
            and replica["failover_detect_s"] < 5.0,
        "replica_read_your_writes":
            all(replica["read_your_writes"].values()),
        "replica_rejoined_from_snapshot":
            replica["rejoin_bootstrap_snapshot"] is not None,
        "replica_rejoined_serves": replica["rejoined_serves_min_seq"],
        "replica_fault_sites_retried":
            rfaults["n_poll_errors"] >= 1 and rfaults["n_apply_errors"] >= 1
            and rfaults["applied_seq"] == rfaults["want_seq"],
    }

    record = {
        "bench": "chaos_soak",
        "smoke": args.smoke,
        "config": {
            "docs": args.docs, "dim": args.dim, "churn_s": args.churn_s,
            "storm_requests": args.storm_requests, "crash_p": args.crash_p,
            "replica_requests": args.replica_requests, "seed": args.seed,
        },
        "sigkill": sigkill,
        "torn_checkpoint": torn,
        "crash_storm": storm,
        "rebuild_retry": rebuild,
        "poison": poison,
        "replica_kill": replica,
        "replica_faults": rfaults,
        "checks": checks,
    }

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_chaos.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {os.path.normpath(out)}")

    failed = [k for k, ok in checks.items() if not ok]
    if failed:
        print(f"FAILED checks: {failed}", file=sys.stderr)
        sys.exit(1)
    print("all checks passed")


if __name__ == "__main__":
    main()

"""Paper Table III: Truncated vs Progressive Retrieval at matched accuracy
(gte regime).  The claim under test: progressive reaches the same accuracy
as truncated-at-d_max with substantially lower runtime (2x at mid dims,
~5x at full dims).
"""


from benchmarks.common import (clamp_configs, load_corpus, print_csv,
                               progressive_row, std_args, truncated_row)
from repro.core import build_index, stage_dims, make_schedule

# (trunc_dim, (d_start, d_max, k0)) pairs; scaled from the paper's
# (256,(128,512,128)), (512,(128,2048,16)), (1024,(128,3584,64)),
# (2048,(256,3584,16)), (3584,(512,3584,16)) by the dim budget.
def configs_for(d_full: int):
    if d_full >= 3584:
        return [(256, (128, 512, 128)), (512, (128, 2048, 16)),
                (1024, (128, 3584, 64)), (2048, (256, 3584, 16)),
                (3584, (512, 3584, 16))]
    # scaled grid mirrors the paper's selection logic: fast aggressive
    # configs AND a generous matched-accuracy one ((Ds=Dm/2, K=128) plays
    # the role of the paper's (512, 3584, 16) row)
    grid = [(128, (64, 128, 128)), (256, (64, 256, 128)),
            (d_full // 2, (128, d_full // 2, 128)),
            (d_full, (128, d_full, 128)),
            (d_full, (d_full // 2, d_full, 64))]
    return clamp_configs(grid, d_full)


def run(args=None):
    args = args or std_args(__doc__).parse_args([])
    db, q, gt = load_corpus(args)
    d_full = db.shape[1]

    rows = []
    for trunc_dim, (ds, dm, k0) in configs_for(d_full):
        tr = truncated_row(q, db, gt, trunc_dim, args.runs)
        sched = make_schedule(ds, dm, k0)
        idx = build_index(db, stage_dims(sched))
        pr = progressive_row(q, db, gt, ds, dm, k0, args.runs,
                             index=idx, dims=stage_dims(sched))
        rows.append({
            "trunc_dim": trunc_dim, "trunc_acc": tr["acc"],
            "trunc_runtime_s": tr["runtime_s"],
            "prog_config": f"({ds};{dm};{k0})",
            "prog_acc": pr["acc"], "prog_runtime_s": pr["runtime_s"],
            "speedup": tr["runtime_s"] / max(pr["runtime_s"], 1e-9),
        })
    print_csv("table3_trunc_vs_progressive_gte", rows,
              ["trunc_dim", "trunc_acc", "trunc_runtime_s", "prog_config",
               "prog_acc", "prog_runtime_s", "speedup"])

    # the paper's headline: full-dim accuracy at a fraction of the time
    # (generous-K row; small-K rows trade a little accuracy for speed,
    # exactly the paper's Fig. 3 spread)
    best = min(rows, key=lambda r: abs(r["prog_acc"] - r["trunc_acc"]))
    assert abs(best["prog_acc"] - best["trunc_acc"]) < 2.0, \
        "progressive must match truncated accuracy at d_max"
    return rows


if __name__ == "__main__":
    run(std_args(__doc__).parse_args())

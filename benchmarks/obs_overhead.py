"""Observability-overhead benchmark: instrumented vs ``obs.enabled=False``.

PR 7's contract is that the telemetry spine (registry counters/histograms
mirrored under ``engine.lock``, per-request trace contexts, the trace
ring) costs <= ``--tolerance`` (default 5%) of queued-path QPS, and that
``obs.enabled=False`` restores the uninstrumented fast path (no-op
instruments, no TraceContext allocation).  This benchmark measures both
modes on the same corpus/schedule and fails the run when the gap exceeds
the tolerance.

Methodology: the two engines are driven in alternating repetitions (so a
machine-load drift hits both modes, not one), with the within-pair order
flipped every repetition (so a systematic order effect — cache warming,
CPU frequency ramp — cancels instead of biasing one mode).  The reported
overhead compares the *median QPS of each mode* across its repetitions:
medians reject the one slow outlier rep (GC pause, CI neighbour), and
because the modes' samples interleave in time, slow drift moves both
medians together instead of biasing the difference.  Per-pair estimates
and per-mode best-of QPS are recorded alongside for reference.

    PYTHONPATH=src python -m benchmarks.obs_overhead --smoke
    PYTHONPATH=src python -m benchmarks.obs_overhead \
        --docs 20000 --dim 256 --requests 512 --reps 5
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def build_engine(db, args, *, enabled):
    from repro.engine import RetrievalEngine
    from repro.engine.config import ObsConfig

    eng = RetrievalEngine(
        db.shape[1], d_start=args.d_start, k0=args.k0,
        buckets=tuple(int(x) for x in args.buckets.split(",")),
        capacity=db.shape[0],
        obs=ObsConfig(enabled=enabled),
    )
    eng.add_docs(db)
    eng.warmup()
    return eng


def run_once(eng, queries) -> float:
    """One queued-path repetition; returns QPS."""
    t0 = time.perf_counter()
    rids = [eng.submit(q) for q in queries]
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    for rid in rids:
        assert eng.poll(rid) is not None
    return len(queries) / wall


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--reps", type=int, default=3,
                    help="alternating repetitions per mode (best-of wins)")
    ap.add_argument("--d-start", type=int, default=32)
    ap.add_argument("--k0", type=int, default=32)
    ap.add_argument("--buckets", type=str, default="1,2,4,8,16,32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="max allowed fractional QPS loss when instrumented")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON (default results/BENCH_obs.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    args = ap.parse_args()

    if args.smoke:
        # compute-representative but CI-small: per-request dispatch work
        # must dominate Python per-request cost, or the percentage gate
        # measures the corpus size instead of the instrumentation
        args.docs, args.dim, args.requests = 16384, 256, 512
        args.d_start, args.k0 = 32, 32
        args.buckets = "1,2,4,8"
        args.reps = max(args.reps, 7)

    from repro.rag import make_corpus

    corpus = make_corpus(n_docs=args.docs, dim=args.dim,
                         n_queries=args.requests, seed=args.seed)

    print(f"# obs_overhead docs={args.docs} dim={args.dim} "
          f"requests={args.requests} reps={args.reps} smoke={args.smoke}")
    eng_on = build_engine(corpus.db, args, enabled=True)
    eng_off = build_engine(corpus.db, args, enabled=False)

    qps_on, qps_off, pair_overheads = [], [], []
    for rep in range(max(1, args.reps)):
        if rep % 2 == 0:
            a = run_once(eng_on, corpus.queries)
            b = run_once(eng_off, corpus.queries)
        else:
            b = run_once(eng_off, corpus.queries)
            a = run_once(eng_on, corpus.queries)
        qps_on.append(a)
        qps_off.append(b)
        pair_overheads.append((b - a) / b if b > 0 else 0.0)

    def median(xs):
        ranked = sorted(xs)
        n = len(ranked)
        return (ranked[n // 2] if n % 2
                else (ranked[n // 2 - 1] + ranked[n // 2]) / 2)

    best_on, best_off = max(qps_on), max(qps_off)
    med_on, med_off = median(qps_on), median(qps_off)
    overhead = (med_off - med_on) / med_off if med_off > 0 else 0.0
    # sanity: the instrumented engine really recorded, the bare one didn't
    scrape = eng_on.metrics.render_prometheus()
    instrumented_ok = (
        "repro_engine_requests_completed_total" in scrape
        and eng_on.metrics.enabled and not eng_off.metrics.enabled)

    print("mode,qps_median,qps_best,qps_all")
    print(f"obs_on,{med_on:.1f},{best_on:.1f},"
          f"\"{','.join(f'{q:.1f}' for q in qps_on)}\"")
    print(f"obs_off,{med_off:.1f},{best_off:.1f},"
          f"\"{','.join(f'{q:.1f}' for q in qps_off)}\"")
    print(f"# overhead={overhead * 100:.2f}% (mode medians over "
          f"{len(pair_overheads)} alternating reps; tolerance "
          f"{args.tolerance * 100:.0f}%)")

    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", "BENCH_obs.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({
            "benchmark": "obs_overhead",
            "smoke": args.smoke,
            "docs": args.docs,
            "dim": args.dim,
            "requests": args.requests,
            "reps": args.reps,
            "qps_instrumented": med_on,
            "qps_disabled": med_off,
            "qps_instrumented_best": best_on,
            "qps_disabled_best": best_off,
            "qps_instrumented_all": qps_on,
            "qps_disabled_all": qps_off,
            "overhead_pairs": pair_overheads,
            "overhead_frac": overhead,
            "tolerance": args.tolerance,
            "instrumented_registry_ok": instrumented_ok,
        }, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}")

    if not instrumented_ok:
        raise SystemExit("FAIL: instrumented registry did not record "
                         "(or the disabled one did)")
    if overhead > args.tolerance:
        raise SystemExit(
            f"FAIL: instrumentation overhead {overhead * 100:.2f}% "
            f"(mode medians) exceeds {args.tolerance * 100:.0f}% "
            f"tolerance (on={med_on:.1f} qps, off={med_off:.1f} qps)")
    print("# OK: instrumentation overhead within tolerance")


if __name__ == "__main__":
    main()

"""Index-backend comparison: QPS / latency / recall per backend and corpus size.

For each corpus size, replays a single-query request stream through
``RetrievalEngine`` once per backend (``flat`` / ``ivf`` / ``quantized``)
and reports build time, steady-state QPS, p50/p95 request latency, and
recall@k against exact full-dimensional search.  The corpus is the
*clustered* synthetic workload (`repro.rag.make_clustered_corpus`) — the
topical structure real document embeddings carry and the prior an IVF
coarse quantizer exploits; `benchmarks/engine_throughput.py` covers the
unclustered truncation-profile corpus.

Writes ``results/BENCH_backends.json`` for CI/regression tracking.

``--ivf-kernel`` switches to the fused-kernel comparison: the ``ivf``
backend runs once per stage-0 path (XLA gather+rescore, fused Pallas
kernel, fused int8 member slabs) and each record carries the *modeled*
stage-0 HBM bytes/query from `repro.kernels.ivf_scan.stage0_bytes_model`
alongside measured QPS and recall — the acceptance check is that the fused
paths model strictly fewer bytes.  On CPU the kernel runs in interpret
mode, so its *measured* QPS understates real-TPU throughput (the modeled
bytes are the hardware-relevant number); writes
``results/BENCH_ivf_kernel.json``.

``--pq`` compares the product-quantized stage-0 paths against their int8
counterparts: the ``quantized`` backend per codec (int8 XLA, PQ ADC XLA,
PQ fused LUT kernel) plus the fused IVF int8/PQ pairs, each record
carrying modeled stage-0 bytes/query.  Acceptance: every PQ path must
model strictly fewer stage-0 bytes than its int8 counterpart, and (full
runs) the PQ backend must reach recall@k >= 0.95 vs exact at < 0.5x the
int8 bytes at the largest corpus.  Fused (interpret-mode) runs are
skipped on CPU past 4096 docs — the interpreter is minutes/query there
and the modeled bytes are the hardware-relevant number; parity is pinned
by `tests/test_kernels.py` instead.  Writes ``results/BENCH_pq.json``.

    PYTHONPATH=src python -m benchmarks.backend_comparison [--smoke]
    PYTHONPATH=src python -m benchmarks.backend_comparison \
        --sizes 8192,65536 --dim 256 --requests 256
    PYTHONPATH=src python -m benchmarks.backend_comparison --smoke --ivf-kernel
    PYTHONPATH=src python -m benchmarks.backend_comparison --smoke --pq
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


BACKEND_OPTS = {
    "flat": None,
    "ivf": None,        # backend defaults: n_lists ~ N/64, n_probe=12, bf=2.0
    "quantized": None,
}


def _stage0_bytes(eng):
    """Modeled stage-0 HBM bytes/query for the engine's live index state.

    IVF states use the probe-scan model (`stage0_bytes_model`), quantized
    code-block states the flat-scan model (`flat_stage0_bytes_model`);
    the record carries the byte count of the path the engine actually
    serves (XLA vs fused kernel).
    """
    from repro.kernels.ivf_scan import stage0_bytes_model
    from repro.kernels.pq_scan import flat_stage0_bytes_model

    state = eng.index_state
    if state is None or state.data.get("flat"):
        return None
    d0 = eng.sched.stages[0].dim
    k0 = eng.sched.stages[0].k

    if "codec" in state.data:                  # quantized code-block scan
        idx = state.data["idx"]
        if state.data["codec"] == "pq":
            m, c = idx["codebooks"].shape[0], idx["codebooks"].shape[1]
            row_bytes, lut_bytes = m, m * c * 4
        else:
            row_bytes, lut_bytes = d0, 0.0
        model = flat_stage0_bytes_model(
            n=state.data["n_coded"], k=k0,
            row_bytes=row_bytes, lut_bytes=lut_bytes)
        fused = eng.backend._kernel_enabled()
        return {
            "stage0_path": "fused" if fused else "xla",
            "stage0_hbm_bytes_per_query": (
                model["fused_bytes"] if fused else model["xla_bytes"]),
            "stage0_bytes_model": model,
        }

    if "n_lists" not in state.data:
        return None
    pack = state.data.get("pack")
    max_len = pack["max_len"] if pack else state.data["max_len"]
    row_bytes = lut_bytes = None
    norms = True
    if pack and pack["dtype"] == "pq":
        m, c = pack["codebooks"].shape[0], pack["codebooks"].shape[1]
        row_bytes, lut_bytes, norms = m, m * c * 4, False
    model = stage0_bytes_model(
        n_lists=state.data["n_lists"],
        max_len=max_len,
        n_probe=min(eng.backend.n_probe, state.data["n_lists"]),
        d0=d0,
        k=k0,
        member_bytes=1 if (pack and pack["dtype"] == "int8") else 4,
        row_bytes=row_bytes,
        lut_bytes=lut_bytes or 0.0,
        norms=norms,
    )
    fused = pack is not None
    return {
        "stage0_path": "fused" if fused else "xla",
        "stage0_hbm_bytes_per_query": (
            model["fused_bytes"] if fused else model["xla_bytes"]),
        "stage0_bytes_model": model,
    }


def run_backend(corpus, backend, *, d_start, k0, k, buckets, exact_ids,
                backend_opts=None, label=None):
    import jax.numpy as jnp

    from repro.core import overlap_at_k, recall_at_k
    from repro.engine import RetrievalEngine

    n_docs = corpus.db.shape[0]
    eng = RetrievalEngine(
        corpus.db.shape[1], d_start=d_start, k0=k0, final_k=k,
        buckets=buckets, capacity=n_docs, backend=backend,
        backend_opts=backend_opts,
        # the replay drains the whole stream before polling: no result may
        # be evicted, however large --requests is
        max_unpolled=max(65536, len(corpus.queries)),
    )
    eng.add_docs(corpus.db)
    t0 = time.perf_counter()
    eng.maybe_rebuild(force=True)         # isolate the index build cost
    build_s = time.perf_counter() - t0
    eng.warmup()

    t0 = time.perf_counter()
    rids = [eng.submit(q) for q in corpus.queries]
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    results = [eng.poll(r) for r in rids]
    ids = np.stack([r.doc_ids for r in results])

    s = eng.stats.summary()
    state = eng.index_state
    bytes_info = _stage0_bytes(eng)
    return {
        "backend": backend,
        "label": label or backend,
        **(bytes_info or {}),
        "docs": n_docs,
        "build_s": build_s,
        "qps": len(rids) / wall,
        "latency_ms_p50": s["latency_ms_p50"],
        "latency_ms_p95": s["latency_ms_p95"],
        "recall_at_k_vs_exact": float(
            overlap_at_k(jnp.asarray(ids), jnp.asarray(exact_ids), k)),
        "recall_at_k_gt": float(
            recall_at_k(jnp.asarray(ids),
                        jnp.asarray(corpus.ground_truth), k)),
        "state_shape_key": list(map(str, state.shape_key)) if state else None,
    }


def _check_pq(records, by, largest, args) -> None:
    """--pq acceptance: every PQ path models strictly fewer stage-0 bytes
    than its int8 counterpart; full (non-smoke) runs additionally demand
    recall@k >= 0.95 vs exact at < 0.5x the int8 bytes at the largest
    corpus (the tentpole's acceptance numbers)."""
    pairs = [("quantized-pq", "quantized-int8"),
             ("quantized-pq-fused", "quantized-int8"),
             ("ivf-pq-fused", "ivf-int8-fused")]
    checked = 0
    for pq_label, int8_label in pairs:
        # compare at the largest size where BOTH paths ran (fused runs are
        # size-gated on CPU)
        common = [r["docs"] for r in records if r["label"] == pq_label
                  if any(o["label"] == int8_label and o["docs"] == r["docs"]
                         for o in records)]
        if not common:
            continue
        docs = max(common)
        pq = next(r for r in records
                  if r["label"] == pq_label and r["docs"] == docs)
        i8 = next(r for r in records
                  if r["label"] == int8_label and r["docs"] == docs)
        pq_b = pq.get("stage0_hbm_bytes_per_query")
        i8_b = i8.get("stage0_hbm_bytes_per_query")
        if pq_b is None or i8_b is None:
            raise SystemExit(
                f"{pq_label} @ {docs} docs has no stage-0 bytes model "
                f"(flat fallback served?); use sizes >= 64")
        ok = pq_b < i8_b
        print(f"# {pq_label} @ {docs} docs: modeled stage-0 "
              f"{pq_b/1e3:.1f} kB/q vs {int8_label} {i8_b/1e3:.1f} kB/q "
              f"({pq_b/i8_b:.3f}x) recall@{args.k}="
              f"{pq['recall_at_k_vs_exact']:.3f} {'OK' if ok else 'FAIL'}")
        if not ok:
            raise SystemExit(
                f"{pq_label} models >= {int8_label} stage-0 bytes "
                f"({pq_b} >= {i8_b})")
        checked += 1
    if not checked:
        raise SystemExit("--pq ran no comparable int8/PQ pairs")
    if not args.smoke:
        pq = by.get("quantized-pq")
        i8 = by.get("quantized-int8")
        if pq and i8:
            ratio = (pq["stage0_hbm_bytes_per_query"]
                     / i8["stage0_hbm_bytes_per_query"])
            recall = pq["recall_at_k_vs_exact"]
            print(f"# acceptance @ {largest} docs: recall@{args.k}="
                  f"{recall:.3f} (need >= 0.95), bytes ratio={ratio:.3f} "
                  f"(need < 0.5)")
            if recall < 0.95:
                raise SystemExit(
                    f"quantized-pq recall@{args.k}={recall:.3f} < 0.95 "
                    f"at {largest} docs")
            if ratio >= 0.5:
                raise SystemExit(
                    f"quantized-pq models {ratio:.3f}x of int8 stage-0 "
                    f"bytes at {largest} docs (need < 0.5)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=str, default="8192,24576,65536",
                    help="comma-separated corpus sizes")
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--d-start", type=int, default=64)
    ap.add_argument("--k0", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", type=str, default="32")
    ap.add_argument("--backends", type=str, default="flat,ivf,quantized")
    ap.add_argument("--ivf-kernel", action="store_true",
                    help="compare the ivf backend's stage-0 paths (XLA vs "
                         "fused Pallas kernel vs fused int8) instead of the "
                         "backend sweep; writes BENCH_ivf_kernel.json")
    ap.add_argument("--pq", action="store_true",
                    help="compare the product-quantized stage-0 paths "
                         "against their int8 counterparts (quantized "
                         "backend per codec + fused IVF int8/PQ); fails "
                         "unless every PQ path models strictly fewer "
                         "stage-0 bytes than int8; writes BENCH_pq.json")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON (default results/BENCH_backends.json; "
                         "BENCH_ivf_kernel.json with --ivf-kernel; "
                         "BENCH_pq.json with --pq)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    args = ap.parse_args()

    if args.smoke:
        args.sizes, args.dim, args.requests = "512,1024", 64, 48
        args.d_start, args.k0, args.k = 8, 32, 5

    from repro.core import truncated_search
    from repro.rag import make_clustered_corpus
    import jax.numpy as jnp

    sizes = [int(x) for x in args.sizes.split(",")]
    buckets = tuple(int(x) for x in args.buckets.split(","))
    if args.ivf_kernel and args.pq:
        raise SystemExit("--ivf-kernel and --pq are mutually exclusive")

    import jax

    # interpret-mode (CPU) fused runs past this corpus size take
    # minutes/query; the modeled bytes are the hardware-relevant number
    # and kernel parity is pinned by the tier-1 tests
    fused_ok_docs = float("inf") if jax.default_backend() == "tpu" else 4096

    def runs_for(n_docs):
        if args.ivf_kernel:
            # one ivf run per stage-0 path; use_kernel=True is interpret
            # mode on CPU (parity-true, slow) and the real kernel on TPU
            return [
                ("ivf-xla", "ivf", {"use_kernel": False}),
                ("ivf-fused", "ivf", {"use_kernel": True}),
                ("ivf-fused-int8", "ivf",
                 {"use_kernel": True, "stage0_dtype": "int8"}),
            ]
        if args.pq:
            runs = [
                ("quantized-int8", "quantized", {"codec": "int8"}),
                ("quantized-pq", "quantized", {"codec": "pq"}),
            ]
            if n_docs <= fused_ok_docs:
                runs += [
                    ("quantized-pq-fused", "quantized",
                     {"codec": "pq", "use_kernel": True}),
                    ("ivf-int8-fused", "ivf",
                     {"use_kernel": True, "stage0_dtype": "int8"}),
                    ("ivf-pq-fused", "ivf",
                     {"use_kernel": True, "stage0_dtype": "pq"}),
                ]
            else:
                print(f"# skipping fused (interpret-mode) runs at {n_docs} "
                      f"docs on {jax.default_backend()}")
            return runs
        return [(b, b, BACKEND_OPTS.get(b)) for b in args.backends.split(",")]

    print(f"# backend_comparison dim={args.dim} requests={args.requests} "
          f"k={args.k} smoke={args.smoke} ivf_kernel={args.ivf_kernel} "
          f"pq={args.pq}")
    print("docs,label,build_s,qps,p50_ms,p95_ms,recall@k_vs_exact")
    records = []
    for n_docs in sizes:
        corpus = make_clustered_corpus(
            n_docs=n_docs, dim=args.dim, n_queries=args.requests,
            seed=args.seed)
        _, exact_ids = truncated_search(
            jnp.asarray(corpus.queries), jnp.asarray(corpus.db),
            dim=args.dim, k=args.k, block_n=min(n_docs, 65536))
        exact_ids = np.asarray(exact_ids)
        for label, backend, opts in runs_for(n_docs):
            rec = run_backend(
                corpus, backend, d_start=args.d_start, k0=args.k0, k=args.k,
                buckets=buckets, exact_ids=exact_ids,
                backend_opts=opts, label=label,
            )
            records.append(rec)
            print(f"{n_docs},{label},{rec['build_s']:.2f},"
                  f"{rec['qps']:.1f},{rec['latency_ms_p50']:.2f},"
                  f"{rec['latency_ms_p95']:.2f},"
                  f"{rec['recall_at_k_vs_exact']:.3f}")

    largest = sizes[-1]
    by = {r["label"]: r for r in records if r["docs"] == largest}
    if args.pq:
        _check_pq(records, by, largest, args)
    elif args.ivf_kernel:
        # acceptance: every fused path must model strictly fewer stage-0
        # HBM bytes than the XLA lowering (the fusion's whole point)
        if any(r.get("stage0_hbm_bytes_per_query") is None
               for r in by.values()):
            raise SystemExit(
                f"corpus of {largest} docs is below the ivf backend's "
                f"min_index_rows (flat fallback served, no stage-0 model); "
                f"use --sizes with at least 64 docs")
        xla = by["ivf-xla"]["stage0_hbm_bytes_per_query"]
        for label in ("ivf-fused", "ivf-fused-int8"):
            fused = by[label]["stage0_hbm_bytes_per_query"]
            ok = fused < xla
            print(f"# {label} @ {largest} docs: modeled stage-0 "
                  f"{fused/1e3:.1f} kB/q vs xla {xla/1e3:.1f} kB/q "
                  f"({fused/xla:.3f}x) recall@{args.k}="
                  f"{by[label]['recall_at_k_vs_exact']:.3f} "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                raise SystemExit(
                    f"{label} models >= XLA stage-0 bytes ({fused} >= {xla})")
    elif "ivf" in by and "flat" in by:
        speedup = by["ivf"]["qps"] / max(by["flat"]["qps"], 1e-9)
        print(f"# ivf vs flat @ {largest} docs: {speedup:.2f}x QPS, "
              f"ivf recall@{args.k}={by['ivf']['recall_at_k_vs_exact']:.3f}")

    default_name = ("BENCH_ivf_kernel.json" if args.ivf_kernel
                    else "BENCH_pq.json" if args.pq
                    else "BENCH_backends.json")
    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    payload = {
        "benchmark": ("backend_comparison/ivf_kernel" if args.ivf_kernel
                      else "backend_comparison/pq" if args.pq
                      else "backend_comparison"),
        "dim": args.dim,
        "requests": args.requests,
        "k": args.k,
        "d_start": args.d_start,
        "k0": args.k0,
        "sizes": sizes,
        "smoke": args.smoke,
        "records": records,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}")


if __name__ == "__main__":
    main()

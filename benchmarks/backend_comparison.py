"""Index-backend comparison: QPS / latency / recall per backend and corpus size.

For each corpus size, replays a single-query request stream through
``RetrievalEngine`` once per backend (``flat`` / ``ivf`` / ``quantized``)
and reports build time, steady-state QPS, p50/p95 request latency, and
recall@k against exact full-dimensional search.  The corpus is the
*clustered* synthetic workload (`repro.rag.make_clustered_corpus`) — the
topical structure real document embeddings carry and the prior an IVF
coarse quantizer exploits; `benchmarks/engine_throughput.py` covers the
unclustered truncation-profile corpus.

Writes ``results/BENCH_backends.json`` for CI/regression tracking.

    PYTHONPATH=src python -m benchmarks.backend_comparison [--smoke]
    PYTHONPATH=src python -m benchmarks.backend_comparison \
        --sizes 8192,65536 --dim 256 --requests 256
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


BACKEND_OPTS = {
    "flat": None,
    "ivf": None,        # backend defaults: n_lists ~ N/64, n_probe=12, bf=2.0
    "quantized": None,
}


def run_backend(corpus, backend, *, d_start, k0, k, buckets, exact_ids,
                backend_opts=None):
    import jax.numpy as jnp

    from repro.core import overlap_at_k, recall_at_k
    from repro.engine import RetrievalEngine

    n_docs = corpus.db.shape[0]
    eng = RetrievalEngine(
        corpus.db.shape[1], d_start=d_start, k0=k0, final_k=k,
        buckets=buckets, capacity=n_docs, backend=backend,
        backend_opts=backend_opts,
        # the replay drains the whole stream before polling: no result may
        # be evicted, however large --requests is
        max_unpolled=max(65536, len(corpus.queries)),
    )
    eng.add_docs(corpus.db)
    t0 = time.perf_counter()
    eng.maybe_rebuild(force=True)         # isolate the index build cost
    build_s = time.perf_counter() - t0
    eng.warmup()

    t0 = time.perf_counter()
    rids = [eng.submit(q) for q in corpus.queries]
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    results = [eng.poll(r) for r in rids]
    ids = np.stack([r.doc_ids for r in results])

    s = eng.stats.summary()
    state = eng.index_state
    return {
        "backend": backend,
        "docs": n_docs,
        "build_s": build_s,
        "qps": len(rids) / wall,
        "latency_ms_p50": s["latency_ms_p50"],
        "latency_ms_p95": s["latency_ms_p95"],
        "recall_at_k_vs_exact": float(
            overlap_at_k(jnp.asarray(ids), jnp.asarray(exact_ids), k)),
        "recall_at_k_gt": float(
            recall_at_k(jnp.asarray(ids),
                        jnp.asarray(corpus.ground_truth), k)),
        "state_shape_key": list(map(str, state.shape_key)) if state else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=str, default="8192,24576,65536",
                    help="comma-separated corpus sizes")
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--d-start", type=int, default=64)
    ap.add_argument("--k0", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", type=str, default="32")
    ap.add_argument("--backends", type=str, default="flat,ivf,quantized")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON (default results/BENCH_backends.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    args = ap.parse_args()

    if args.smoke:
        args.sizes, args.dim, args.requests = "512,1024", 64, 48
        args.d_start, args.k0, args.k = 8, 32, 5

    from repro.core import truncated_search
    from repro.rag import make_clustered_corpus
    import jax.numpy as jnp

    sizes = [int(x) for x in args.sizes.split(",")]
    buckets = tuple(int(x) for x in args.buckets.split(","))
    backends = args.backends.split(",")

    print(f"# backend_comparison dim={args.dim} requests={args.requests} "
          f"k={args.k} smoke={args.smoke}")
    print("docs,backend,build_s,qps,p50_ms,p95_ms,recall@k_vs_exact")
    records = []
    for n_docs in sizes:
        corpus = make_clustered_corpus(
            n_docs=n_docs, dim=args.dim, n_queries=args.requests,
            seed=args.seed)
        _, exact_ids = truncated_search(
            jnp.asarray(corpus.queries), jnp.asarray(corpus.db),
            dim=args.dim, k=args.k, block_n=min(n_docs, 65536))
        exact_ids = np.asarray(exact_ids)
        for backend in backends:
            rec = run_backend(
                corpus, backend, d_start=args.d_start, k0=args.k0, k=args.k,
                buckets=buckets, exact_ids=exact_ids,
                backend_opts=BACKEND_OPTS.get(backend),
            )
            records.append(rec)
            print(f"{n_docs},{backend},{rec['build_s']:.2f},"
                  f"{rec['qps']:.1f},{rec['latency_ms_p50']:.2f},"
                  f"{rec['latency_ms_p95']:.2f},"
                  f"{rec['recall_at_k_vs_exact']:.3f}")

    # acceptance summary: ivf vs flat at the largest corpus size
    largest = sizes[-1]
    by = {r["backend"]: r for r in records if r["docs"] == largest}
    if "ivf" in by and "flat" in by:
        speedup = by["ivf"]["qps"] / max(by["flat"]["qps"], 1e-9)
        print(f"# ivf vs flat @ {largest} docs: {speedup:.2f}x QPS, "
              f"ivf recall@{args.k}={by['ivf']['recall_at_k_vs_exact']:.3f}")

    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", "BENCH_backends.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    payload = {
        "benchmark": "backend_comparison",
        "dim": args.dim,
        "requests": args.requests,
        "k": args.k,
        "d_start": args.d_start,
        "k0": args.k0,
        "sizes": sizes,
        "smoke": args.smoke,
        "records": records,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}")


if __name__ == "__main__":
    main()

"""Paper Table IV: truncated retrieval, text-embedding-3-large regime
(3072 dims; steeper Matryoshka-style spectrum: OpenAI trains explicit
truncation points, so low dims carry relatively more signal)."""

from benchmarks.common import load_corpus, print_csv, std_args, truncated_row

PAPER_OPENAI = {16: 3.32, 32: 29.35, 64: 70.73, 128: 88.18, 256: 92.02,
                512: 93.40, 1024: 93.85, 2048: 94.17, 3072: 94.45}


def run(args=None):
    args = args or std_args(__doc__).parse_args([])
    d = 3072 if args.full else max(args.dim * 3 // 4, 128)
    db, q, gt = load_corpus(args, dim=d, alpha=0.28, sigma=1.45,
                            sigma_spread=0.5)
    dims = [x for x in (16, 32, 64, 128, 256, 512, 1024, 2048, 3072)
            if x <= d]
    rows = []
    for dim in dims:
        r = truncated_row(q, db, gt, dim, args.runs)
        r["paper_acc"] = PAPER_OPENAI.get(dim, float("nan"))
        rows.append(r)
    print_csv("table4_truncated_openai (synthetic, openai-calibrated)",
              rows, ["dim", "acc", "runtime_s", "paper_acc"])
    return rows


if __name__ == "__main__":
    run(std_args(__doc__).parse_args())

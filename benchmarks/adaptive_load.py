"""Adaptive-serving benchmark: recall vs load with and without the policy.

Four phases over one clustered corpus, all recorded to
``results/BENCH_adaptive.json``:

1. **bit-for-bit** — an engine with the adaptive sections constructed but
   idle (level 0) must reproduce the static engine's top-k ids exactly
   (acceptance (c): enabling the subsystem cannot perturb results).
2. **degradation curve** — recall@10 measured per pressure level by
   dispatching the full eval set through ``overrides_for_level``:
   the recall-vs-degradation trade the policy moves along.
3. **overload** — the same open-loop burst (clients submitting far faster
   than the service rate) against a static driver and an adaptive driver.
   The policy must shed knobs (escalations > 0) and cut client p95
   while keeping delivered recall@10 near the idle value
   (acceptance (a): p95 <= 0.7x static at recall >= 0.95x idle).
4. **cache replay** — a hot query set replayed through the driver's
   query cache must hit >= 90%; one store mutation must drop the next
   replay's scrape-delta hit rate to exactly 0 (acceptance (b)).

Exit status is non-zero if any enforced check fails.  ``--smoke``
(CI) enforces the deterministic checks — bit-for-bit, zero-load recall
equality, escalation-under-overload, cache replay — and skips only the
wall-clock p95 ratio, which needs the full-size run to be meaningful.

    PYTHONPATH=src python -m benchmarks.adaptive_load --smoke
    PYTHONPATH=src python -m benchmarks.adaptive_load
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

K = 10          # recall@10 throughout


def build_engine(db, *, adaptive, cache, args):
    from repro.engine import AdaptiveConfig, CacheConfig, RetrievalEngine

    acfg = AdaptiveConfig(
        enabled=adaptive, levels=2,
        depth_high=args.depth_high, wait_high_ms=None,
        hysteresis_s=30.0,                    # never recover mid-burst
        n_probe_scale=args.n_probe_scale, oversample_scale=0.5,
        d_start_shift=1, min_d_start=max(16, args.d_start // 4))
    eng = RetrievalEngine(
        db.shape[1], d_start=args.d_start, k0=args.k0, final_k=K,
        buckets=(1, 2, 4, 8), capacity=len(db), block_n=len(db),
        backend="ivf",
        backend_opts=dict(n_lists=args.n_lists, n_probe=args.n_probe),
        adaptive=acfg if adaptive else None,
        cache=CacheConfig(enabled=True, capacity=args.cache_capacity)
        if cache else None,
    )
    eng.add_docs(db)
    eng.warmup()                              # all buckets x all levels
    return eng


def exact_topk(db, queries, k=K):
    """Ground-truth L2 top-k ids, blockwise numpy."""
    out = np.empty((len(queries), k), np.int64)
    for i, q in enumerate(queries):
        d = ((db - q[None, :]) ** 2).sum(axis=1)
        idx = np.argpartition(d, k)[:k]
        out[i] = idx[np.argsort(d[idx])]
    return out


def recall_at_k(ids, truth):
    """Mean |retrieved ∩ exact| / k."""
    hits = sum(len(set(map(int, a)) & set(map(int, b)))
               for a, b in zip(ids, truth))
    return hits / (len(truth) * truth.shape[1])


def level_recall_curve(eng, queries, truth):
    """Phase 2: recall@10 dispatched at each pressure level."""
    from repro.engine import SearchRequest

    curve = []
    for lvl in range(0, eng.config.adaptive.levels + 1):
        ov = eng.overrides_for_level(lvl)
        ids = []
        for q in queries:
            reqs = [eng.check_request(SearchRequest(q))]
            (res,) = eng.execute_batch(reqs, overrides=ov)
            ids.append(res.doc_ids)
        curve.append({"level": lvl, "recall_at_10":
                      recall_at_k(np.asarray(ids), truth)})
    return curve


def overload_run(db, queries, truth, *, adaptive, args):
    """Phase 3: open-loop burst; returns client-side p95 + recall."""
    from repro.engine import EngineDriver
    from repro.launch.serve import run_clients

    eng = build_engine(db, adaptive=adaptive, cache=False, args=args)
    driver = EngineDriver(eng, max_wait_ms=2.0,
                          max_queue=max(4096, len(queries))).start()
    try:
        results, wall = run_clients(driver, queries, args.clients,
                                    qps=0.0, timeout=600.0)
    finally:
        summary = (driver.adaptive.summary() if driver.adaptive is not None
                   else {"enabled": False})
        driver.stop()
    lat = np.array([r.stats.latency_ms for r in results])
    ids = np.stack([r.doc_ids for r in results])
    levels = np.array([r.degraded_level for r in results])
    return {
        "adaptive": adaptive,
        "requests": len(queries),
        "clients": args.clients,
        "qps": len(queries) / wall,
        "latency_ms_p50": float(np.percentile(lat, 50)),
        "latency_ms_p95": float(np.percentile(lat, 95)),
        "recall_at_10": recall_at_k(ids, truth),
        "degraded_requests": int((levels > 0).sum()),
        "policy": summary,
    }


def cache_replay(db, hot, *, args):
    """Phase 4: hot-set replay hit rate, then a mutation -> zero hits."""
    from repro.engine import EngineDriver
    from repro.obs import parse_prometheus

    eng = build_engine(db, adaptive=False, cache=True, args=args)
    driver = EngineDriver(eng, max_wait_ms=0.0).start()

    def scrape():
        m = parse_prometheus(eng.metrics.render_prometheus())
        hits = (m.get("repro_qcache_hits_total", {}).get(
                    (("kind", "exact"),), 0.0)
                + m.get("repro_qcache_hits_total", {}).get(
                    (("kind", "near"),), 0.0))
        misses = m.get("repro_qcache_misses_total", {}).get((), 0.0)
        return hits, misses

    try:
        for _ in range(args.replays):
            for q in hot:
                driver.retrieve(q, timeout=120)
        hits, misses = scrape()
        total = hits + misses
        hit_rate = hits / total if total else 0.0

        # one store mutation: the very next scrape window must be all
        # misses — the stamp flush makes a stale hit structurally
        # impossible
        eng.add_docs(np.random.default_rng(5).normal(
            size=(1, db.shape[1])).astype(np.float32))
        h0, m0 = scrape()
        for q in hot:
            driver.retrieve(q, timeout=120)
        h1, m1 = scrape()
        post_rate = ((h1 - h0) / ((h1 - h0) + (m1 - m0))
                     if (h1 - h0) + (m1 - m0) else 0.0)
        inval = driver.cache.summary()["invalidations"]
    finally:
        driver.stop()
    return {
        "hot_queries": len(hot),
        "replays": args.replays,
        "hit_rate": hit_rate,
        "post_mutation_hit_rate": post_rate,
        "invalidations": inval,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--overload-requests", type=int, default=512)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--d-start", type=int, default=64)
    ap.add_argument("--k0", type=int, default=256)
    ap.add_argument("--n-lists", type=int, default=32)
    ap.add_argument("--n-probe", type=int, default=16)
    ap.add_argument("--n-probe-scale", type=float, default=0.7)
    ap.add_argument("--alpha", type=float, default=0.6,
                    help="corpus spectrum decay: steeper = more signal in "
                         "the truncated dims the degraded schedules keep")
    ap.add_argument("--depth-high", type=int, default=8)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--replays", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; skips the wall-clock p95 check")
    args = ap.parse_args()

    if args.smoke:
        args.docs, args.dim, args.queries = 3000, 64, 48
        args.overload_requests, args.clients = 192, 8
        args.d_start, args.k0 = 32, 128
        args.n_lists, args.n_probe = 16, 8
        args.n_probe_scale = 0.85
        args.cache_capacity, args.replays = 64, 16

    from repro.rag import make_corpus

    corpus = make_corpus(n_docs=args.docs, dim=args.dim,
                         n_queries=max(args.queries,
                                       args.overload_requests),
                         seed=args.seed, alpha=args.alpha)
    db = np.asarray(corpus.db, np.float32)
    all_q = np.asarray(corpus.queries, np.float32)
    eval_q = all_q[:args.queries]
    load_q = all_q[:args.overload_requests]
    truth_eval = exact_topk(db, eval_q)
    truth_load = exact_topk(db, load_q)

    print(f"# adaptive_load docs={args.docs} dim={args.dim} "
          f"smoke={args.smoke}")

    # -- phase 1: bit-for-bit with the subsystem idle -------------------
    static_eng = build_engine(db, adaptive=False, cache=False, args=args)
    adaptive_eng = build_engine(db, adaptive=True, cache=False, args=args)
    _, ids_static = static_eng.search(eval_q)
    _, ids_idle = adaptive_eng.search(eval_q)
    bit_for_bit = bool(np.array_equal(ids_static, ids_idle))
    recall_static = recall_at_k(ids_static, truth_eval)
    recall_idle = recall_at_k(ids_idle, truth_eval)
    print(f"bit_for_bit={bit_for_bit} recall_idle={recall_idle:.4f}")

    # -- phase 2: recall per degradation level --------------------------
    curve = level_recall_curve(adaptive_eng, eval_q, truth_eval)
    for c in curve:
        print(f"level={c['level']} recall@10={c['recall_at_10']:.4f}")
    del static_eng, adaptive_eng

    # -- phase 3: overload with/without the policy ----------------------
    static_run = overload_run(db, load_q, truth_load,
                              adaptive=False, args=args)
    adaptive_run = overload_run(db, load_q, truth_load,
                                adaptive=True, args=args)
    p95_ratio = (adaptive_run["latency_ms_p95"]
                 / max(static_run["latency_ms_p95"], 1e-9))
    recall_ratio = adaptive_run["recall_at_10"] / max(recall_idle, 1e-9)
    print(f"overload: static p95={static_run['latency_ms_p95']:.1f}ms "
          f"adaptive p95={adaptive_run['latency_ms_p95']:.1f}ms "
          f"ratio={p95_ratio:.3f} recall_ratio={recall_ratio:.4f} "
          f"escalations={adaptive_run['policy'].get('n_escalations')}")

    # -- phase 4: cache replay + mutation -------------------------------
    hot = all_q[:20]
    cache = cache_replay(db, hot, args=args)
    print(f"cache: hit_rate={cache['hit_rate']:.4f} "
          f"post_mutation={cache['post_mutation_hit_rate']:.4f}")

    checks = {
        # (c): enabling the subsystem at level 0 is invisible
        "bit_for_bit": bit_for_bit,
        # smoke condition: zero-load recall identical to the baseline
        "idle_recall_matches_static": recall_idle == recall_static,
        # smoke condition: the policy actually shed knobs under overload
        "policy_escalated": (
            adaptive_run["policy"].get("n_escalations", 0) > 0
            and adaptive_run["degraded_requests"] > 0),
        # (b): hot replay >= 90% hit, mutation zeroes the next window
        "cache_hit_rate_ge_90": cache["hit_rate"] >= 0.90,
        "mutation_drops_hit_rate_to_0":
            cache["post_mutation_hit_rate"] == 0.0,
        # (a): the wall-clock trade, meaningful only at full size
        "overload_p95_le_0.7x_static": p95_ratio <= 0.70,
        "overload_recall_ge_0.95x_idle": recall_ratio >= 0.95,
    }
    enforced = [k for k in checks
                if not (args.smoke and k == "overload_p95_le_0.7x_static")]

    record = {
        "bench": "adaptive_load",
        "smoke": args.smoke,
        "config": {
            "docs": args.docs, "dim": args.dim,
            "d_start": args.d_start, "k0": args.k0, "k": K,
            "n_lists": args.n_lists, "n_probe": args.n_probe,
            "depth_high": args.depth_high,
            "overload_requests": args.overload_requests,
            "clients": args.clients,
        },
        "bit_for_bit": bit_for_bit,
        "recall_idle": recall_idle,
        "recall_static": recall_static,
        "level_recall": curve,
        "overload": {"static": static_run, "adaptive": adaptive_run,
                     "p95_ratio": p95_ratio, "recall_ratio": recall_ratio},
        "cache": cache,
        "checks": checks,
    }

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_adaptive.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {os.path.normpath(out)}")

    failed = [k for k in enforced if not checks[k]]
    if failed:
        print(f"FAILED checks: {failed}", file=sys.stderr)
        sys.exit(1)
    print("all checks passed")


if __name__ == "__main__":
    main()

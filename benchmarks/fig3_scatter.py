"""Paper Fig. 3/4: full accuracy-vs-runtime scatter of both methods.

Emits a CSV of (method, config, acc, runtime) points and the headline
statistic: the fraction of progressive configurations that dominate the
truncated frontier (above the accuracy-for-time curve), plus the pooled
(paper-faithful) vs per-query variant comparison."""


from benchmarks.common import (load_corpus, print_csv, progressive_row,
                               std_args, timed_median, truncated_row)
from repro.core import (make_schedule, progressive_search_pooled,
                        top1_accuracy)


def run(args=None):
    args = args or std_args(__doc__).parse_args([])
    db, q, gt = load_corpus(args)
    d_full = db.shape[1]

    trunc_dims = [d for d in (16, 32, 64, 96, 128, 192, 256, 384, 512,
                              768, 1024, 2048, 3584) if d <= d_full]
    rows = []
    for d in trunc_dims:
        r = truncated_row(q, db, gt, d, args.runs)
        rows.append({"method": "truncated", "config": f"d={d}",
                     "acc": r["acc"], "runtime_s": r["runtime_s"]})

    d_starts = [d for d in (32, 64, 128, 256) if d < d_full]
    k0s = (4, 16, 64, 128)
    d_maxes = [d for d in (128, 256, 512, 1024, 3584) if d <= d_full]
    prog_rows = []
    for ds in d_starts:
        for dm in d_maxes:
            if dm <= ds:
                continue
            for k0 in k0s:
                r = progressive_row(q, db, gt, ds, dm, k0, args.runs)
                prog_rows.append({
                    "method": "progressive", "config": f"({ds};{dm};{k0})",
                    "acc": r["acc"], "runtime_s": r["runtime_s"]})
    rows += prog_rows
    print_csv("fig3_scatter_points", rows,
              ["method", "config", "acc", "runtime_s"])

    # dominance statistic: progressive point dominates if some truncated
    # point is both slower and less accurate... we report the paper's
    # reading: for each progressive point, accuracy vs the truncated point
    # of equal-or-greater runtime.
    tr = [(r["runtime_s"], r["acc"]) for r in rows if r["method"] == "truncated"]
    tr.sort()
    def frontier_acc(t):
        best = 0.0
        for rt, acc in tr:
            if rt <= t:
                best = max(best, acc)
        return best
    above = sum(1 for r in prog_rows if r["acc"] >= frontier_acc(r["runtime_s"]))
    print(f"# progressive points at-or-above the truncated frontier: "
          f"{above}/{len(prog_rows)}")

    # pooled (paper-faithful) vs per-query variant at one config
    ds, dm, k0 = d_starts[0], d_maxes[-1], 16
    sched = make_schedule(ds, dm, k0)
    t_pool, (s, c) = timed_median(
        lambda: progressive_search_pooled(q, db, sched), args.runs)
    acc_pool = float(top1_accuracy(c, gt)) * 100
    pq = [r for r in prog_rows if r["config"] == f"({ds};{dm};{k0})"][0]
    print(f"# pooled-vs-perquery @({ds};{dm};{k0}): pooled acc={acc_pool:.2f} "
          f"t={t_pool:.3f}s | per-query acc={pq['acc']:.2f} "
          f"t={pq['runtime_s']:.3f}s")
    return rows


if __name__ == "__main__":
    run(std_args(__doc__).parse_args())

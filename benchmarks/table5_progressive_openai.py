"""Paper Table V: truncated vs progressive, text-embedding-3-large regime."""

from benchmarks.common import (clamp_configs, load_corpus, print_csv,
                               progressive_row, std_args, truncated_row)
from repro.core import build_index, make_schedule, stage_dims


def configs_for(d_full: int):
    if d_full >= 3072:
        return [(256, (128, 256, 128)), (512, (256, 512, 16)),
                (1024, (128, 2048, 32)), (2048, (128, 3072, 64)),
                (3072, (256, 3072, 64))]
    grid = [(96, (48, 96, 128)), (192, (96, 192, 64)),
            (d_full // 2, (96, d_full // 2, 128)),
            (d_full, (96, d_full, 128)),
            (d_full, (d_full // 2, d_full, 64))]
    return clamp_configs(grid, d_full)


def run(args=None):
    args = args or std_args(__doc__).parse_args([])
    d = 3072 if args.full else max(args.dim * 3 // 4, 128)
    db, q, gt = load_corpus(args, dim=d, alpha=0.28, sigma=1.45,
                            sigma_spread=0.5)
    rows = []
    for trunc_dim, (ds, dm, k0) in configs_for(d):
        tr = truncated_row(q, db, gt, trunc_dim, args.runs)
        sched = make_schedule(ds, dm, k0)
        idx = build_index(db, stage_dims(sched))
        pr = progressive_row(q, db, gt, ds, dm, k0, args.runs,
                             index=idx, dims=stage_dims(sched))
        rows.append({
            "trunc_dim": trunc_dim, "trunc_acc": tr["acc"],
            "trunc_runtime_s": tr["runtime_s"],
            "prog_config": f"({ds};{dm};{k0})",
            "prog_acc": pr["acc"], "prog_runtime_s": pr["runtime_s"],
            "speedup": tr["runtime_s"] / max(pr["runtime_s"], 1e-9),
        })
    print_csv("table5_trunc_vs_progressive_openai", rows,
              ["trunc_dim", "trunc_acc", "trunc_runtime_s", "prog_config",
               "prog_acc", "prog_runtime_s", "speedup"])
    return rows


if __name__ == "__main__":
    run(std_args(__doc__).parse_args())

"""Retrieval-engine throughput benchmark: caller-paced bucket ladders and the
async driver's deadline/concurrency trade-off.

Two measurement modes, two JSON records:

* **Ladder sweep** (caller-paced, as in PR 1): replays single-query requests
  through ``RetrievalEngine``'s queue for several bucket configurations and
  reports per-config QPS / p50 / p95 / padding waste
  -> ``results/BENCH_engine.json``.
* **Driver sweep** (async serving path): N concurrent client threads submit
  through ``EngineDriver`` for each (``max_wait_ms``, clients) combination —
  QPS vs latency percentiles as the deadline knob and offered concurrency
  move -> ``results/BENCH_driver.json``.

    PYTHONPATH=src python -m benchmarks.engine_throughput [--smoke]
    PYTHONPATH=src python -m benchmarks.engine_throughput \
        --docs 20000 --dim 256 --requests 512 --configs "1|8|32|1,2,4,8,16,32" \
        --driver-wait-ms 0,2,8 --driver-clients 1,8
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def make_engine(db, buckets, *, d_start, k0, capacity):
    from repro.engine import RetrievalEngine

    eng = RetrievalEngine(
        db.shape[1], d_start=d_start, k0=k0,
        buckets=buckets, capacity=capacity,
    )
    eng.add_docs(db)
    # Warm every bucket so steady-state numbers exclude XLA compiles.
    eng.warmup()
    return eng


def latency_summary(eng):
    """p50/p95 through the shared ``repro.obs`` histogram buckets — the
    same resolution a ``/metrics`` scrape of the live engine reports, so
    BENCH records and online percentiles are directly comparable."""
    from repro.obs import summarize_latency

    lat = summarize_latency(eng.stats.latency_ms)
    queue = summarize_latency(eng.stats.queue_ms, pcts=(50.0,))
    return lat["p50"], lat["p95"], queue["p50"]


def run_config(db, queries, buckets, *, d_start, k0, capacity):
    eng = make_engine(db, buckets, d_start=d_start, k0=k0, capacity=capacity)

    t0 = time.perf_counter()
    rids = [eng.submit(q) for q in queries]
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    for rid in rids:
        assert eng.poll(rid) is not None
    s = eng.stats.summary()
    p50, p95, q50 = latency_summary(eng)
    return {
        "buckets": list(buckets),
        "requests": len(queries),
        "qps": len(queries) / wall,
        "wall_s": wall,
        "latency_ms_p50": p50,
        "latency_ms_p95": p95,
        "queue_ms_p50": q50,
        "n_batches": s["n_batches"],
        "n_padded_slots": s["n_padded_slots"],
        "n_compiles_steady": s["n_compiles"],   # 0 expected after warmup
    }


def run_driver_config(db, queries, buckets, *, max_wait_ms, clients,
                      d_start, k0, capacity, timeout=300.0):
    """One driver-path measurement: ``clients`` threads racing submits."""
    from repro.engine import EngineDriver
    from repro.launch.serve import run_clients

    eng = make_engine(db, buckets, d_start=d_start, k0=k0, capacity=capacity)
    driver = EngineDriver(eng, max_wait_ms=max_wait_ms,
                          max_queue=max(len(queries), 1)).start()
    try:
        _, wall = run_clients(driver, queries, clients, qps=0.0,
                              timeout=timeout)
    finally:
        driver.stop()

    s = eng.stats.summary()
    ds = driver.stats.summary()
    p50, p95, q50 = latency_summary(eng)
    return {
        "max_wait_ms": max_wait_ms,
        "clients": clients,
        "buckets": list(buckets),
        "requests": len(queries),
        "qps": len(queries) / wall,
        "wall_s": wall,
        "latency_ms_p50": p50,
        "latency_ms_p95": p95,
        "queue_ms_p50": q50,
        "n_batches": s["n_batches"],
        "n_padded_slots": s["n_padded_slots"],
        "n_flush_full": ds["n_flush_full"],
        "n_flush_deadline": ds["n_flush_deadline"],
        "queue_peak": ds["queue_peak"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--d-start", type=int, default=32)
    ap.add_argument("--k0", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--configs", type=str,
                    default="1|8|32|1,2,4,8,16,32",
                    help="'|'-separated bucket ladders, each comma-separated")
    ap.add_argument("--driver-buckets", type=str, default="1,2,4,8,16,32",
                    help="bucket ladder for the driver sweep")
    ap.add_argument("--driver-wait-ms", type=str, default="0,2,8",
                    help="comma-separated max_wait_ms values to sweep")
    ap.add_argument("--driver-clients", type=str, default="1,8",
                    help="comma-separated concurrent-client counts to sweep")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON path (default results/BENCH_engine.json;"
                         " driver records go next to it as BENCH_driver.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    args = ap.parse_args()

    if args.smoke:
        args.docs, args.dim, args.requests = 512, 64, 48
        args.d_start, args.k0 = 8, 16
        args.configs = "4|1,2,4,8"
        args.driver_buckets = "1,2,4,8"
        args.driver_wait_ms = "0,4"
        args.driver_clients = "4"

    from repro.rag import make_corpus

    corpus = make_corpus(n_docs=args.docs, dim=args.dim,
                         n_queries=args.requests, seed=args.seed)
    configs = [tuple(int(x) for x in c.split(","))
               for c in args.configs.split("|")]

    print(f"# engine_throughput docs={args.docs} dim={args.dim} "
          f"requests={args.requests} smoke={args.smoke}")
    print("buckets,qps,p50_ms,p95_ms,batches,padded_slots")
    records = []
    for buckets in configs:
        rec = run_config(
            corpus.db, corpus.queries, buckets,
            d_start=args.d_start, k0=args.k0, capacity=args.docs,
        )
        records.append(rec)
        print(f"\"{','.join(map(str, buckets))}\","
              f"{rec['qps']:.1f},{rec['latency_ms_p50']:.2f},"
              f"{rec['latency_ms_p95']:.2f},{rec['n_batches']},"
              f"{rec['n_padded_slots']}")

    driver_buckets = tuple(
        int(x) for x in args.driver_buckets.split(","))
    wait_values = [float(x) for x in args.driver_wait_ms.split(",")]
    client_values = [int(x) for x in args.driver_clients.split(",")]
    print("# driver sweep (async path)")
    print("max_wait_ms,clients,qps,p50_ms,p95_ms,batches,"
          "flush_full,flush_deadline")
    driver_records = []
    for clients in client_values:
        for wait_ms in wait_values:
            rec = run_driver_config(
                corpus.db, corpus.queries, driver_buckets,
                max_wait_ms=wait_ms, clients=min(clients, args.requests),
                d_start=args.d_start, k0=args.k0, capacity=args.docs,
            )
            driver_records.append(rec)
            print(f"{wait_ms:g},{rec['clients']},{rec['qps']:.1f},"
                  f"{rec['latency_ms_p50']:.2f},{rec['latency_ms_p95']:.2f},"
                  f"{rec['n_batches']},{rec['n_flush_full']},"
                  f"{rec['n_flush_deadline']}")

    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", "BENCH_engine.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    common = {
        "docs": args.docs,
        "dim": args.dim,
        "requests": args.requests,
        "smoke": args.smoke,
    }
    with open(out_path, "w") as f:
        json.dump({"benchmark": "engine_throughput", **common,
                   "records": records}, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}")

    driver_path = os.path.join(os.path.dirname(out_path),
                               "BENCH_driver.json")
    with open(driver_path, "w") as f:
        json.dump({"benchmark": "engine_driver", **common,
                   "buckets": list(driver_buckets),
                   "records": driver_records}, f, indent=2)
    print(f"# wrote {os.path.normpath(driver_path)}")


if __name__ == "__main__":
    main()

"""Retrieval-engine throughput benchmark: QPS and latency percentiles as a
function of the bucket ladder.

Replays a stream of single-query requests through ``RetrievalEngine``'s
queue for several bucket configurations (the static batch shapes the engine
pads to).  Reports per-config QPS, p50/p95 request latency, batch count, and
padding waste, and writes a ``results/BENCH_engine.json`` record for CI/
regression tracking.

    PYTHONPATH=src python -m benchmarks.engine_throughput [--smoke]
    PYTHONPATH=src python -m benchmarks.engine_throughput \
        --docs 20000 --dim 256 --requests 512 --configs "1|8|32|1,2,4,8,16,32"
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def run_config(db, queries, buckets, *, d_start, k0, capacity):
    from repro.engine import RetrievalEngine

    eng = RetrievalEngine(
        db.shape[1], d_start=d_start, k0=k0,
        buckets=buckets, capacity=capacity,
    )
    eng.add_docs(db)
    # Warm every bucket so steady-state numbers exclude XLA compiles.
    eng.warmup()

    t0 = time.perf_counter()
    rids = [eng.submit(q) for q in queries]
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    for rid in rids:
        assert eng.poll(rid) is not None
    s = eng.stats.summary()
    return {
        "buckets": list(buckets),
        "requests": len(queries),
        "qps": len(queries) / wall,
        "wall_s": wall,
        "latency_ms_p50": s["latency_ms_p50"],
        "latency_ms_p95": s["latency_ms_p95"],
        "queue_ms_p50": s["queue_ms_p50"],
        "n_batches": s["n_batches"],
        "n_padded_slots": s["n_padded_slots"],
        "n_compiles_steady": s["n_compiles"],   # 0 expected after warmup
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--d-start", type=int, default=32)
    ap.add_argument("--k0", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--configs", type=str,
                    default="1|8|32|1,2,4,8,16,32",
                    help="'|'-separated bucket ladders, each comma-separated")
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON path (default results/BENCH_engine.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (overrides sizes)")
    args = ap.parse_args()

    if args.smoke:
        args.docs, args.dim, args.requests = 512, 64, 48
        args.d_start, args.k0 = 8, 16
        args.configs = "4|1,2,4,8"

    from repro.rag import make_corpus

    corpus = make_corpus(n_docs=args.docs, dim=args.dim,
                         n_queries=args.requests, seed=args.seed)
    configs = [tuple(int(x) for x in c.split(","))
               for c in args.configs.split("|")]

    print(f"# engine_throughput docs={args.docs} dim={args.dim} "
          f"requests={args.requests} smoke={args.smoke}")
    print("buckets,qps,p50_ms,p95_ms,batches,padded_slots")
    records = []
    for buckets in configs:
        rec = run_config(
            corpus.db, corpus.queries, buckets,
            d_start=args.d_start, k0=args.k0, capacity=args.docs,
        )
        records.append(rec)
        print(f"\"{','.join(map(str, buckets))}\","
              f"{rec['qps']:.1f},{rec['latency_ms_p50']:.2f},"
              f"{rec['latency_ms_p95']:.2f},{rec['n_batches']},"
              f"{rec['n_padded_slots']}")

    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", "BENCH_engine.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    payload = {
        "benchmark": "engine_throughput",
        "docs": args.docs,
        "dim": args.dim,
        "requests": args.requests,
        "smoke": args.smoke,
        "records": records,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}")


if __name__ == "__main__":
    main()

"""Roofline report: aggregates results/dryrun/*.json into the per-cell
three-term table (EXPERIMENTS.md §Roofline reads from this).

``--ivf-kernel`` instead reports the fused IVF stage-0 kernel's modeled
HBM traffic (results/BENCH_ivf_kernel.json, written by
``benchmarks.backend_comparison --ivf-kernel``): per path, the modeled
bytes/query, the memory-roofline time those bytes cost at the reference
HBM bandwidth, and the fused/XLA ratio — the "how much of the stage-0
memory wall did the fusion remove" number that CPU-measured QPS can't show.

    PYTHONPATH=src python -m benchmarks.roofline [--outdir results/dryrun]
    PYTHONPATH=src python -m benchmarks.roofline --ivf-kernel
"""

import argparse
import glob
import json
import os

from repro.configs import get_arch, family_of
from repro.launch.hlo_analysis import HBM_BW


def model_flops_per_device(arch: str, shape_name: str, n_chips: int):
    """6·N·D (dense) / 6·N_active·D (MoE) per device — the 'useful' FLOPs.
    Train counts fwd+bwd (3x forward); inference counts 2·N·D.
    """
    fam = family_of(arch)
    mod = get_arch(arch)
    shape = mod.SHAPES[shape_name]
    if fam != "lm":
        return None
    cfg = mod.CONFIG
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch          # one decode step
    return 2.0 * n_active * tokens / n_chips


def load_results(outdir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def merge_exact(recs, costs_dir: str):
    """Overlay exact per-layer-composed costs (results/costs/*.json) onto
    dry-run records: scanned-program cost analysis counts loop bodies once,
    the exact pass composes true trip counts (see launch/costs.py)."""
    if not os.path.isdir(costs_dir):
        return recs
    exact = {}
    for path in glob.glob(os.path.join(costs_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        exact[(r.get("arch"), r.get("shape"))] = r
    out = []
    for r in recs:
        e = exact.get((r.get("arch"), r.get("shape")))
        if e and r.get("status") == "ok":
            r = dict(r)
            r["flops"] = e["flops"]
            r["hbm_bytes"] = e["hbm_bytes"]
            r["collective_total_bytes"] = e["coll_total"]
            r["collective_bytes"] = e["coll"]
            r["roofline"] = e["roofline"]
            r["exact"] = True
        out.append(r)
    return out


def fmt_seconds(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def report(outdir: str = "results/dryrun", mesh: str = "single",
           costs_dir: str = "results/costs"):
    recs = [r for r in load_results(outdir)
            if r.get("mesh") == mesh]
    if mesh == "single":
        recs = merge_exact(recs, costs_dir)
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append({"cell": f"{r['arch']} x {r['shape']}",
                         "status": "SKIP (" + r["reason"][:40] + "...)"})
            continue
        if r["status"] != "ok":
            rows.append({"cell": f"{r['arch']} x {r['shape']}",
                         "status": "ERROR"})
            continue
        rf = r["roofline"]
        mf = model_flops_per_device(r["arch"], r["shape"], r["n_chips"])
        ratio = (mf / r["flops"]) if (mf and r["flops"]) else None
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound else 0.0
        rows.append({
            "cell": f"{r['arch']} x {r['shape']}",
            "status": "ok" + ("*" if r.get("exact") else ""),
            "compute": fmt_seconds(rf["compute_s"]),
            "memory": fmt_seconds(rf["memory_s"]),
            "collective": fmt_seconds(rf["collective_s"]),
            "dominant": rf["dominant"].replace("_s", ""),
            "roofline_frac": f"{frac:.3f}",
            "useful_ratio": f"{ratio:.2f}" if ratio else "-",
        })
    cols = ["cell", "status", "compute", "memory", "collective",
            "dominant", "roofline_frac", "useful_ratio"]
    widths = {c: max(len(c), *(len(str(row.get(c, ""))) for row in rows))
              for c in cols}
    print(" | ".join(c.ljust(widths[c]) for c in cols))
    print("-+-".join("-" * widths[c] for c in cols))
    for row in rows:
        print(" | ".join(str(row.get(c, "")).ljust(widths[c]) for c in cols))
    return rows


def _print_table(rows, cols):
    widths = {c: max(len(c), *(len(str(row.get(c, ""))) for row in rows))
              for c in cols}
    print(" | ".join(c.ljust(widths[c]) for c in cols))
    print("-+-".join("-" * widths[c] for c in cols))
    for row in rows:
        print(" | ".join(str(row.get(c, "")).ljust(widths[c]) for c in cols))


def ivf_kernel_report(path: str = "results/BENCH_ivf_kernel.json"):
    """Fused-vs-XLA IVF stage-0 table from the backend_comparison records."""
    if not os.path.exists(path):
        print(f"no {path}; run "
              f"`python -m benchmarks.backend_comparison --ivf-kernel` first")
        return []
    with open(path) as f:
        payload = json.load(f)
    recs = [r for r in payload["records"]
            if r.get("stage0_hbm_bytes_per_query") is not None]
    xla_by_docs = {r["docs"]: r["stage0_hbm_bytes_per_query"]
                   for r in recs if r.get("stage0_path") == "xla"}
    rows = []
    for r in recs:
        b = r["stage0_hbm_bytes_per_query"]
        xla = xla_by_docs.get(r["docs"])
        rows.append({
            "cell": f"{r['label']} x {r['docs']} docs",
            "path": r.get("stage0_path", "?"),
            "bytes/q": f"{b/1e3:.1f}kB",
            "mem_s/q": fmt_seconds(b / HBM_BW),
            "vs_xla": f"{b/xla:.3f}x" if xla else "-",
            "qps_meas": f"{r['qps']:.1f}",
            "recall@k": f"{r['recall_at_k_vs_exact']:.3f}",
        })
    cols = ["cell", "path", "bytes/q", "mem_s/q", "vs_xla", "qps_meas",
            "recall@k"]
    _print_table(rows, cols)
    return rows


# each PQ path's int8 counterpart in the --pq records (same scan shape,
# coarser codes): the "how much of the int8 stage-0 memory wall does PQ
# remove" denominator
_PQ_BASELINE = {
    "quantized-pq": "quantized-int8",
    "quantized-pq-fused": "quantized-int8",
    "ivf-pq-fused": "ivf-int8-fused",
}


def pq_report(path: str = "results/BENCH_pq.json"):
    """PQ-vs-int8 stage-0 table from the --pq backend_comparison records.

    Per path: modeled bytes/query, the memory-roofline time those bytes
    cost at the reference HBM bandwidth, and the PQ/int8 ratio at the same
    corpus size — CPU-measured QPS can't show the bandwidth win, the model
    can.
    """
    if not os.path.exists(path):
        print(f"no {path}; run "
              f"`python -m benchmarks.backend_comparison --pq` first")
        return []
    with open(path) as f:
        payload = json.load(f)
    recs = [r for r in payload["records"]
            if r.get("stage0_hbm_bytes_per_query") is not None]
    by = {(r["label"], r["docs"]): r["stage0_hbm_bytes_per_query"]
          for r in recs}
    rows = []
    for r in recs:
        b = r["stage0_hbm_bytes_per_query"]
        base = by.get((_PQ_BASELINE.get(r["label"], ""), r["docs"]))
        rows.append({
            "cell": f"{r['label']} x {r['docs']} docs",
            "path": r.get("stage0_path", "?"),
            "bytes/q": f"{b/1e3:.1f}kB",
            "mem_s/q": fmt_seconds(b / HBM_BW),
            "vs_int8": f"{b/base:.3f}x" if base else "-",
            "qps_meas": f"{r['qps']:.1f}",
            "recall@k": f"{r['recall_at_k_vs_exact']:.3f}",
        })
    cols = ["cell", "path", "bytes/q", "mem_s/q", "vs_int8", "qps_meas",
            "recall@k"]
    _print_table(rows, cols)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--ivf-kernel", action="store_true",
                    help="report the fused IVF stage-0 kernel's modeled HBM "
                         "bytes (reads results/BENCH_ivf_kernel.json)")
    ap.add_argument("--ivf-kernel-json",
                    default="results/BENCH_ivf_kernel.json")
    ap.add_argument("--pq", action="store_true",
                    help="report the PQ stage-0 paths' modeled HBM bytes vs "
                         "their int8 counterparts (reads "
                         "results/BENCH_pq.json)")
    ap.add_argument("--pq-json", default="results/BENCH_pq.json")
    args = ap.parse_args()
    if args.ivf_kernel:
        ivf_kernel_report(args.ivf_kernel_json)
        return
    if args.pq:
        pq_report(args.pq_json)
        return
    report(args.outdir, args.mesh)


if __name__ == "__main__":
    main()

"""HTTP serving front-end: multi-tenant, metadata-filtered search over the
engine driver.

  RetrievalHTTPServer — stdlib asyncio HTTP/1.1 server (health, search,
                        add/delete docs, stats) mapping the engine's error
                        taxonomy onto status codes (429 backpressure,
                        504 deadline, 400 bad filter, 403 cross-tenant)
  serve_in_thread,
  ServerHandle        — boot the server on its own event-loop thread;
                        used by tests, the launcher, and the load bench
  TenantQuotas,
  QuotaExceeded       — per-tenant admission control (in-flight + doc
                        caps) in front of the driver's bounded queue

Tenancy and filtering live in the engine (`repro.engine.SearchRequest`,
``DocStore`` tenant/metadata columns); this package only speaks HTTP.
"""

from repro.serve.http import (
    RetrievalHTTPServer,
    ServerHandle,
    serve_in_thread,
)
from repro.serve.quota import QuotaExceeded, TenantQuotas

__all__ = [
    "QuotaExceeded", "RetrievalHTTPServer", "ServerHandle",
    "TenantQuotas", "serve_in_thread",
]

"""HTTP serving front-end: multi-tenant, metadata-filtered search over the
engine driver.

  RetrievalHTTPServer — stdlib asyncio HTTP/1.1 server (health, search,
                        add/delete docs, stats) mapping the engine's error
                        taxonomy onto status codes (429 backpressure,
                        504 deadline, 400 bad filter, 403 cross-tenant);
                        liveness vs readiness split (``/healthz?ready=1``),
                        replication deep-health, read-only follower mode,
                        and ``min_seq`` read-your-writes waits
  ReplicaRouter,
  RouterHTTPServer    — replicated serving front door: health-probed
                        failover, per-replica circuit breakers, bounded
                        retries, request hedging, consistency-token
                        routing (see `repro.serve.router`)
  RetryPolicy,
  CircuitBreaker      — the shared failure-handling primitives (also used
                        by the ``--connect`` CLI client)
  serve_in_thread,
  run_server_in_thread,
  ServerHandle        — boot a server on its own event-loop thread;
                        used by tests, the launcher, and the load bench
  TenantQuotas,
  QuotaExceeded       — per-tenant admission control (in-flight + doc
                        caps) in front of the driver's bounded queue

Tenancy and filtering live in the engine (`repro.engine.SearchRequest`,
``DocStore`` tenant/metadata columns); this package only speaks HTTP.
"""

from repro.serve.http import (
    AsyncHTTPBase,
    RetrievalHTTPServer,
    ServerHandle,
    run_server_in_thread,
    serve_in_thread,
)
from repro.serve.quota import QuotaExceeded, TenantQuotas
from repro.serve.router import (
    CircuitBreaker,
    ReplicaRouter,
    RetryPolicy,
    RouterHTTPServer,
    http_call,
)

__all__ = [
    "AsyncHTTPBase", "CircuitBreaker", "QuotaExceeded", "ReplicaRouter",
    "RetrievalHTTPServer", "RetryPolicy", "RouterHTTPServer",
    "ServerHandle", "TenantQuotas", "http_call", "run_server_in_thread",
    "serve_in_thread",
]

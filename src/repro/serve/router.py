"""Replica routing front-end: health-checked failover over N serving
replicas, with circuit breakers, bounded retries, hedging, and
read-your-writes consistency tokens.

`ReplicaRouter` fronts a set of replica base URLs (one primary + any
number of followers, each a `RetrievalHTTPServer`):

* **Probes** — a background thread hits each replica's
  ``/healthz?deep=1`` every ``probe_interval_s``, recording liveness,
  readiness (recovery/catch-up done), role, and ``applied_seq``/lag.
* **Circuit breaker** — per replica, the `Supervisor` discipline:
  ``failure_threshold`` consecutive failures open it; while open the
  replica gets no traffic; after a capped-exponential backoff one
  half-open probe is allowed through, success closes, failure re-opens
  with a doubled (capped) backoff.
* **Retries** — `RetryPolicy`: bounded attempts with jittered capped
  backoff, only on retryable failures (connection errors, 503, 504) and
  NEVER on 4xx (a 400/403/429 means the request itself, or the tenant's
  quota, is the problem — another replica would answer the same).
  Searches fail over to the next healthy replica immediately; mutations
  retry only on 503/504, never on a connection error (the primary may
  have applied the mutation before the socket died, and a blind resend
  would double-apply).
* **Hedging** — optionally fire a second attempt at a different replica
  once the first has been in flight ``hedge_ms`` (or, at ``hedge_ms=0``,
  an adaptive p95 of recent search latencies); first response wins, the
  loser is cancelled (abandoned if already on the wire — the losing
  replica still finishes serving it, which is the standard cost of
  tail-latency hedging).
* **Read-your-writes** — mutations return the primary's WAL ``seq``;
  a client passing it back as ``min_seq`` is routed to a replica whose
  probed ``applied_seq`` covers it (falling back to the most caught-up
  replica, whose serving path then *blocks* until the seq applies or the
  deadline passes — the guarantee holds even when probe data is stale).

`RouterHTTPServer` exposes the same ``/v1/*`` surface over the router so
clients keep speaking one protocol; its ``/metrics`` carries per-replica
lag/breaker gauges plus hedge/failover/retry counters.
"""

from __future__ import annotations

import json
import random
import threading
import time
import urllib.error
import urllib.request
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from concurrent.futures import wait as futures_wait
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs import MetricsRegistry
from repro.serve.http import AsyncHTTPBase, _HTTPError, _Raw

__all__ = ["CircuitBreaker", "ReplicaRouter", "RetryPolicy",
           "RouterHTTPServer", "http_call"]


def http_call(url: str, path: str, body: Optional[Dict] = None, *,
              method: Optional[str] = None,
              timeout: float = 30.0) -> Tuple[int, Dict]:
    """One JSON round trip; returns ``(status, payload)``.

    Never raises: connection-level failures (refused, reset, DNS, socket
    timeout) come back as status ``0`` — the retry policies treat 0 like
    a 503.  Non-JSON bodies degrade to ``{"error": ...}``.
    """
    data = None if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        url + path, data=data,
        headers={"Content-Type": "application/json"} if data else {},
        method=method or ("POST" if data is not None else "GET"))
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read() or b"{}")
    except urllib.error.HTTPError as e:
        try:
            payload = json.loads(e.read() or b"{}")
        except Exception:
            payload = {"error": str(e)}
        return e.code, payload
    except Exception as e:
        return 0, {"error": f"connection error: "
                            f"{getattr(e, 'reason', None) or e}"}


class RetryPolicy:
    """Bounded retry with jittered, capped exponential backoff.

    Retryable: connection errors (status 0), 503, 504.  Never 4xx — those
    are the request's (or tenant's) fault and will fail identically
    everywhere.  Shared by the router and the ``--connect`` CLI client so
    both ends of the wire apply the same discipline.
    """

    RETRYABLE = (0, 503, 504)

    def __init__(self, *, max_attempts: int = 3, backoff_s: float = 0.05,
                 backoff_max_s: float = 1.0, jitter: float = 0.5,
                 seed: Optional[int] = None):
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.max_attempts = int(max_attempts)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self.jitter = float(jitter)
        self._rng = random.Random(seed)

    def retryable(self, status: int) -> bool:
        return status in self.RETRYABLE

    def backoff(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (0-based), jittered
        upward by up to ``jitter`` of the base."""
        base = min(self.backoff_s * (2 ** attempt), self.backoff_max_s)
        return base * (1.0 + self.jitter * self._rng.random())

    def run(self, fn, *, sleep=time.sleep, on_retry=None):
        """Drive ``fn(attempt) -> (status, payload)`` through the policy;
        returns the last ``(status, payload)``."""
        status, payload = 0, {"error": "no attempts made"}
        for attempt in range(self.max_attempts):
            status, payload = fn(attempt)
            if not self.retryable(status) \
                    or attempt == self.max_attempts - 1:
                return status, payload
            if on_retry is not None:
                on_retry(attempt, status)
            sleep(self.backoff(attempt))
        return status, payload


class CircuitBreaker:
    """Per-replica consecutive-failure breaker (`Supervisor` discipline).

    closed -> (``threshold`` consecutive failures) -> open ->
    (capped-exponential backoff elapses) -> half-open: exactly one trial
    request goes through; success closes and resets the backoff, failure
    re-opens with the backoff doubled (capped at ``open_max_s``).
    """

    def __init__(self, *, threshold: int = 3, open_s: float = 0.25,
                 open_max_s: float = 2.0, clock=time.monotonic):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.open_s = float(open_s)
        self.open_max_s = float(open_max_s)
        self._clock = clock
        self._lock = threading.Lock()
        self.state = "closed"
        self.consecutive = 0
        self.n_trips = 0
        self._retry_at = 0.0
        self._trial_free = True

    def allow(self) -> bool:
        """Non-consuming admission check (see ``on_attempt``)."""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                return self._clock() >= self._retry_at
            return self._trial_free                    # half-open

    def on_attempt(self) -> None:
        """A request is actually being sent: claim the half-open trial."""
        with self._lock:
            if self.state == "open" and self._clock() >= self._retry_at:
                self.state = "half_open"
                self._trial_free = False
            elif self.state == "half_open":
                self._trial_free = False

    def record_success(self) -> None:
        with self._lock:
            self.state = "closed"
            self.consecutive = 0
            self.n_trips = 0
            self._trial_free = True

    def record_failure(self) -> None:
        with self._lock:
            self.consecutive += 1
            if self.state == "half_open" or (
                    self.state == "closed"
                    and self.consecutive >= self.threshold):
                self.n_trips += 1
                backoff = min(self.open_s * (2 ** (self.n_trips - 1)),
                              self.open_max_s)
                self.state = "open"
                self._retry_at = self._clock() + backoff
                self._trial_free = True
            elif self.state == "open":
                # a straggler failure while already open: push retry out
                pass

    def summary(self) -> Dict:
        with self._lock:
            return {"state": self.state, "consecutive": self.consecutive,
                    "n_trips": self.n_trips,
                    "retry_in_s": max(0.0, self._retry_at - self._clock())
                    if self.state == "open" else 0.0}


class ReplicaEndpoint:
    """Router-side view of one replica."""

    def __init__(self, url: str, breaker: CircuitBreaker):
        self.url = url.rstrip("/")
        self.breaker = breaker
        self.alive = False
        self.ready = False
        self.role = "unknown"
        self.applied_seq = -1
        self.replica_lag = -1
        self.n_probes = 0
        self.n_served = 0
        self.n_errors = 0
        self.last_probe: Optional[Dict] = None

    def status(self) -> Dict:
        return {
            "url": self.url, "alive": self.alive, "ready": self.ready,
            "role": self.role, "applied_seq": self.applied_seq,
            "replica_lag": self.replica_lag, "breaker":
            self.breaker.summary(), "n_probes": self.n_probes,
            "n_served": self.n_served, "n_errors": self.n_errors,
        }


# breaker-state gauge encoding: closed=0, half_open=1, open=2
_BREAKER_CODE = {"closed": 0, "half_open": 1, "open": 2}


class ReplicaRouter:
    """Spreads searches across healthy replicas; mutations to the primary.

    ``search``/``mutate`` return ``(status, payload, served_by_url)`` with
    the same status-code taxonomy the replicas speak, so `RouterHTTPServer`
    (or any embedder) can relay them verbatim.
    """

    def __init__(self, replica_urls: Sequence[str], *,
                 probe_interval_s: float = 0.25,
                 probe_timeout_s: float = 2.0,
                 failure_threshold: int = 3,
                 breaker_open_s: float = 0.25,
                 breaker_open_max_s: float = 2.0,
                 retry: Optional[RetryPolicy] = None,
                 hedge_ms: Optional[float] = None,
                 request_timeout_s: float = 30.0,
                 registry: Optional[MetricsRegistry] = None):
        if not replica_urls:
            raise ValueError("ReplicaRouter needs at least one replica URL")
        self.replicas = [
            ReplicaEndpoint(u, CircuitBreaker(
                threshold=failure_threshold, open_s=breaker_open_s,
                open_max_s=breaker_open_max_s))
            for u in replica_urls]
        self.probe_interval_s = float(probe_interval_s)
        self.probe_timeout_s = float(probe_timeout_s)
        self.retry = retry if retry is not None else RetryPolicy()
        self.hedge_ms = hedge_ms
        self.request_timeout_s = float(request_timeout_s)
        self._rr = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._pool = ThreadPoolExecutor(
            max_workers=max(4, 2 * len(self.replicas)),
            thread_name_prefix="router-attempt")
        self._latencies: List[float] = []      # recent search ms, ring
        self.metrics = registry if registry is not None else MetricsRegistry()
        reg = self.metrics
        self._c_req = reg.counter(
            "repro_router_requests_total",
            "Router responses, by route and status", labels=("route",
                                                             "status"))
        self._c_retries = reg.counter(
            "repro_router_retries_total", "Retried attempts")
        self._c_failovers = reg.counter(
            "repro_router_failovers_total",
            "Attempts moved to a different replica after a failure")
        self._c_hedges = reg.counter(
            "repro_router_hedges_total", "Hedge attempts fired")
        self._c_hedge_wins = reg.counter(
            "repro_router_hedge_wins_total",
            "Hedged requests answered first by the hedge")
        self._c_probe_fail = reg.counter(
            "repro_router_probe_failures_total",
            "Failed health probes", labels=("replica",))
        self._g_up = reg.gauge(
            "repro_router_replica_up", "1 = probe ok", labels=("replica",))
        self._g_ready = reg.gauge(
            "repro_router_replica_ready", "1 = replica ready",
            labels=("replica",))
        self._g_lag = reg.gauge(
            "repro_router_replica_lag",
            "Replica WAL records behind the primary", labels=("replica",))
        self._g_breaker = reg.gauge(
            "repro_router_breaker_state",
            "0 closed / 1 half-open / 2 open", labels=("replica",))
        self._h_latency = reg.histogram(
            "repro_router_attempt_ms", "Per-attempt latency",
            labels=("route",))

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ReplicaRouter":
        """Probe everything once (synchronously), then keep probing in the
        background."""
        self.probe_all()
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._probe_loop, name="router-probe", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self._thread = None
        self._pool.shutdown(wait=False)

    def _probe_loop(self) -> None:
        while not self._stop.wait(self.probe_interval_s):
            self.probe_all()

    # -- probing -------------------------------------------------------------
    def probe_all(self) -> None:
        for ep in self.replicas:
            self._probe(ep)

    def _probe(self, ep: ReplicaEndpoint) -> None:
        status, payload = http_call(ep.url, "/healthz?deep=1",
                                    timeout=self.probe_timeout_s)
        ep.n_probes += 1
        if status == 200:
            ep.alive = True
            ep.ready = bool(payload.get("ready", True))
            ep.role = payload.get("role", "single")
            ep.applied_seq = int(payload.get("applied_seq", -1))
            ep.replica_lag = int(payload.get("replica_lag", -1))
            ep.last_probe = {k: payload.get(k) for k in
                             ("status", "n_docs", "ready", "role",
                              "applied_seq", "replica_lag")}
            ep.breaker.record_success()
        else:
            ep.alive = False
            ep.ready = False
            ep.breaker.record_failure()
            self._c_probe_fail.inc(replica=ep.url)
        self._g_up.set(1.0 if ep.alive else 0.0, replica=ep.url)
        self._g_ready.set(1.0 if ep.ready else 0.0, replica=ep.url)
        self._g_lag.set(float(max(ep.replica_lag, 0)), replica=ep.url)
        self._g_breaker.set(float(_BREAKER_CODE[ep.breaker.state]),
                            replica=ep.url)

    def wait_ready(self, n: Optional[int] = None,
                   timeout: float = 30.0) -> bool:
        """Block until ``n`` replicas (default: all) probe ready."""
        want = len(self.replicas) if n is None else int(n)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.probe_all()
            if sum(1 for ep in self.replicas if ep.ready) >= want:
                return True
            time.sleep(min(0.05, self.probe_interval_s))
        return False

    # -- selection -----------------------------------------------------------
    def _candidates(self, min_seq: Optional[int]) -> List[ReplicaEndpoint]:
        """Healthy replicas in round-robin order; with a ``min_seq`` token,
        caught-up replicas first (stale-probe fallback: the replica itself
        still enforces the token by waiting)."""
        with self._lock:
            i = self._rr
            self._rr += 1
        eps = [ep for ep in self.replicas
               if ep.ready and ep.breaker.allow()]
        if not eps:
            return []
        rot = eps[i % len(eps):] + eps[:i % len(eps)]
        if min_seq is None:
            return rot
        caught = [ep for ep in rot if ep.applied_seq >= min_seq]
        behind = sorted((ep for ep in rot if ep.applied_seq < min_seq),
                        key=lambda ep: -ep.applied_seq)
        return caught + behind

    def _primary(self) -> Optional[ReplicaEndpoint]:
        for ep in self.replicas:
            if ep.role in ("primary", "single") and ep.alive \
                    and ep.breaker.allow():
                return ep
        return None

    # -- attempts ------------------------------------------------------------
    @staticmethod
    def _is_final(status: int) -> bool:
        """Response statuses relayed to the client without failover: any
        success, and every 4xx (including 429 — the tenant's quota follows
        the tenant, not the replica)."""
        return 200 <= status < 500 and status != 0

    def _attempt(self, ep: ReplicaEndpoint, path: str, body: Dict,
                 timeout: float, route: str) -> Tuple[int, Dict]:
        ep.breaker.on_attempt()
        t0 = time.perf_counter()
        status, payload = http_call(ep.url, path, body, timeout=timeout)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._h_latency.observe(dt_ms, route=route)
        if self._is_final(status):
            ep.breaker.record_success()
            ep.n_served += 1
            if route == "search":
                with self._lock:
                    self._latencies.append(dt_ms)
                    if len(self._latencies) > 256:
                        del self._latencies[:128]
        else:
            ep.breaker.record_failure()
            ep.n_errors += 1
        return status, payload

    def _hedge_delay_s(self) -> Optional[float]:
        if self.hedge_ms is None:
            return None
        if self.hedge_ms > 0:
            return self.hedge_ms / 1e3
        with self._lock:                       # hedge_ms == 0: adaptive p95
            lats = list(self._latencies)
        if len(lats) < 8:
            return None
        lats.sort()
        return lats[int(0.95 * (len(lats) - 1))] / 1e3

    # -- client surface ------------------------------------------------------
    def search(self, body: Dict,
               timeout: Optional[float] = None
               ) -> Tuple[int, Dict, Optional[str]]:
        """Route one search; returns ``(status, payload, served_by_url)``."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.request_timeout_s)
        min_seq = body.get("min_seq")
        last: Tuple[int, Dict, Optional[str]] = (
            503, {"error": "no ready replicas"}, None)
        for attempt in range(self.retry.max_attempts):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                st, pl, by = last
                return (504, {"error": "router deadline exhausted",
                              "last": pl}, by)
            cands = self._candidates(
                int(min_seq) if min_seq is not None else None)
            if not cands:
                # nothing healthy right now: wait out a probe tick
                if attempt < self.retry.max_attempts - 1:
                    time.sleep(min(self.probe_interval_s, remaining))
                    continue
                break
            (status, payload), ep = self._attempt_maybe_hedged(
                cands, "/v1/search", body, remaining, "search")
            last = (status, payload, ep.url)
            if self._is_final(status):
                self._count("search", status)
                return last
            if attempt < self.retry.max_attempts - 1:
                self._c_retries.inc()
                if len(cands) > 1:
                    # another replica is healthy: fail over immediately
                    self._c_failovers.inc()
                else:
                    time.sleep(min(self.retry.backoff(attempt),
                                   max(0.0, deadline - time.monotonic())))
        self._count("search", last[0])
        return last

    def _attempt_maybe_hedged(
            self, cands: List[ReplicaEndpoint], path: str, body: Dict,
            remaining: float, route: str
    ) -> Tuple[Tuple[int, Dict], ReplicaEndpoint]:
        ep = cands[0]
        delay = self._hedge_delay_s()
        if delay is None or len(cands) < 2 or delay >= remaining:
            return self._attempt(ep, path, body, remaining, route), ep
        f1 = self._pool.submit(self._attempt, ep, path, body, remaining,
                               route)
        try:
            return f1.result(timeout=delay), ep
        except FutureTimeout:
            pass
        self._c_hedges.inc()                   # primary attempt is slow
        ep2 = cands[1]
        f2 = self._pool.submit(self._attempt, ep2, path, body,
                               max(0.0, remaining - delay), route)
        futs = {f1: ep, f2: ep2}
        result, winner = (0, {"error": "hedge bookkeeping"}), ep
        while futs:
            done, _ = futures_wait(set(futs), return_when=FIRST_COMPLETED)
            for f in done:
                e = futs.pop(f)
                result = f.result()
                winner = e
                if self._is_final(result[0]) or not futs:
                    for straggler in futs:     # loser cancelled/abandoned
                        straggler.cancel()
                    if winner is ep2:
                        self._c_hedge_wins.inc()
                    return result, winner
        return result, winner                  # pragma: no cover

    def mutate(self, path: str, body: Dict,
               timeout: Optional[float] = None
               ) -> Tuple[int, Dict, Optional[str]]:
        """Forward a mutation to the primary; retries ONLY on 503/504 —
        a connection error mid-mutation is ambiguous (the primary may have
        logged it) and a blind resend could double-apply, so it surfaces
        to the caller as status 0."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.request_timeout_s)
        last: Tuple[int, Dict, Optional[str]] = (
            503, {"error": "no live primary"}, None)
        for attempt in range(self.retry.max_attempts):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return (504, {"error": "router deadline exhausted",
                              "last": last[1]}, last[2])
            ep = self._primary()
            if ep is None:
                if attempt < self.retry.max_attempts - 1:
                    time.sleep(min(self.probe_interval_s, remaining))
                    continue
                break
            status, payload = self._attempt(ep, path, body, remaining,
                                            "mutate")
            last = (status, payload, ep.url)
            if status not in (503, 504):
                self._count("mutate", status)
                return last
            if attempt < self.retry.max_attempts - 1:
                self._c_retries.inc()
                time.sleep(min(self.retry.backoff(attempt),
                               max(0.0, deadline - time.monotonic())))
        self._count("mutate", last[0])
        return last

    def _count(self, route: str, status: int) -> None:
        self._c_req.inc(route=route, status=status)

    def status(self) -> Dict:
        return {
            "replicas": [ep.status() for ep in self.replicas],
            "n_ready": sum(1 for ep in self.replicas if ep.ready),
            "hedge_ms": self.hedge_ms,
            "probe_interval_s": self.probe_interval_s,
        }


_ROUTER_ROUTE_PATHS = (
    ("GET", "/healthz"), ("GET", "/metrics"), ("GET", "/v1/replicas"),
    ("POST", "/v1/search"), ("POST", "/v1/docs"),
    ("POST", "/v1/docs/delete"),
)


class RouterHTTPServer(AsyncHTTPBase):
    """HTTP front door over a `ReplicaRouter` — clients speak the exact
    same ``/v1/*`` protocol to the router as to a single replica."""

    route_paths = _ROUTER_ROUTE_PATHS

    def __init__(self, router: ReplicaRouter, *, host: str = "127.0.0.1",
                 port: int = 0, max_body: int = 64 << 20):
        super().__init__(host=host, port=port, max_body=max_body)
        self.router = router

    def _observe(self, route: str, status: int, dt_ms: float) -> None:
        self.router.metrics.counter(
            "repro_router_http_requests_total",
            "Router HTTP responses, by route and status",
            labels=("route", "status")).inc(route=route, status=status)

    def _routes(self) -> Dict[Tuple[str, str], Any]:
        return {
            ("GET", "/healthz"): self._do_health,
            ("GET", "/metrics"): self._do_metrics,
            ("GET", "/v1/replicas"): self._do_replicas,
            ("POST", "/v1/search"): self._do_search,
            ("POST", "/v1/docs"): self._do_add,
            ("POST", "/v1/docs/delete"): self._do_delete,
        }

    # -- handlers ------------------------------------------------------------
    def _do_health(self, body: Dict) -> Dict:
        st = self.router.status()
        out = {"status": "ok", "role": "router",
               "n_ready": st["n_ready"],
               "n_replicas": len(st["replicas"])}
        if str(body.get("ready", "")).lower() in ("1", "true", "yes") \
                and st["n_ready"] == 0:
            raise _HTTPError(503, "no ready replicas behind the router")
        if str(body.get("deep", "")).lower() in ("1", "true", "yes"):
            out["deep"] = st
        return out

    def _do_metrics(self, body: Dict) -> _Raw:
        return _Raw(self.router.metrics.render_prometheus().encode(),
                    "text/plain; version=0.0.4; charset=utf-8")

    def _do_replicas(self, body: Dict) -> Dict:
        return self.router.status()

    def _relay(self, status: int, payload: Dict,
               served_by: Optional[str]) -> Tuple[Dict, Dict[str, str]]:
        if 200 <= status < 300:
            out = dict(payload)
            out["served_by"] = served_by
            return out, {"served-by": served_by or ""}
        headers = {"Retry-After": "1"} if status in (429, 503) else {}
        raise _HTTPError(status if status != 0 else 503,
                         payload.get("error", "replica error"), headers)

    def _do_search(self, body: Dict) -> Tuple[Dict, Dict[str, str]]:
        timeout = None
        if body.get("deadline_ms") is not None:
            timeout = float(body["deadline_ms"]) / 1e3
        return self._relay(*self.router.search(body, timeout=timeout))

    def _do_add(self, body: Dict) -> Tuple[Dict, Dict[str, str]]:
        return self._relay(*self.router.mutate("/v1/docs", body))

    def _do_delete(self, body: Dict) -> Tuple[Dict, Dict[str, str]]:
        return self._relay(*self.router.mutate("/v1/docs/delete", body))

"""Stdlib asyncio HTTP/1.1 front-end over the engine driver.

One small server, zero new search code: every request path below ends in
the primitives the engine already exposes.  Tenancy and metadata filters
ride the ``SearchRequest`` mask-key path (the driver batches same-key
requests together and the dispatch ANDs one bitmask into the validity
mask); admission control is `repro.serve.quota.TenantQuotas` in front of
the driver's bounded queue, so a tenant at its cap gets a fast 429 while
the queue keeps serving everyone else.

Endpoints (JSON in, JSON out — except ``/metrics``, which is Prometheus
text exposition):

  GET  /healthz          liveness: 200 once the driver thread is running;
                         ``?ready=1`` additionally 503s until recovery/WAL
                         replay (and, on followers, catch-up within the
                         lag bound) completes — the router probes this;
                         ``?deep=1`` adds driver heartbeat age, supervisor
                         state, WAL lag, replication status and the last
                         recovery report
  GET  /metrics          Prometheus text exposition of the engine registry
  GET  /v1/stats         engine + driver counters, tenants, config, quotas
  GET  /v1/traces        recent request traces + slow-query records
  POST /v1/search        {"query": [f32...], "k", "tenant", "filter",
                          "deadline_ms", "min_seq"} -> {"ids", "scores",
                          "spans", ...}; ``min_seq`` is a read-your-writes
                          token: the replica waits (bounded) until its
                          applied WAL seq covers it, else a retryable 503
  POST /v1/docs          {"vectors": [[f32...]...], "tenant", "metadata"}
                          -> {"ids": [...], "seq"} (seq = the mutation's
                          WAL position: the consistency token)
  POST /v1/docs/delete   {"ids": [...], "tenant"} -> {"n_deleted", "seq"}

Every response is also counted into the engine's metrics registry
(``repro_http_requests_total{route,status}`` +
``repro_http_request_ms{route}``), so the server observes itself through
the same ``/metrics`` surface it serves.

Status mapping — the error taxonomy the engine grew for exactly this:

  400  malformed JSON / bad filter spec (``FilterError``) / bad shapes
  403  a tenant touching another tenant's documents
  404  unknown path          405  wrong method          413  body too large
  429  ``QuotaExceeded`` (per-tenant cap) or ``DriverQueueFull`` (global
       backpressure) — retryable, with a Retry-After hint
  503  driver stopped, or the request was isolated as the poison member
       of a failing batch (``RequestFailed``)
  504  ``DeadlineExceeded`` / result timeout

``require_tenant=True`` (the default) refuses tenantless searches and
mutations with 400: the tenantless pool is the embedded/admin view, not
something to expose over a network socket.  Blocking driver calls run in
the event loop's default executor so slow searches never stall the
accept loop; ``serve_in_thread`` wraps the whole thing for tests, the
launcher, and the load benchmark.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
import time
import urllib.parse
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.engine import (
    DeadlineExceeded,
    DriverQueueFull,
    DriverStopped,
    EngineDriver,
    FilterError,
    RequestFailed,
    RetrievalEngine,
    SearchRequest,
)
from repro.serve.quota import QuotaExceeded, TenantQuotas

_REASONS = {
    200: "OK", 400: "Bad Request", 403: "Forbidden", 404: "Not Found",
    405: "Method Not Allowed", 413: "Payload Too Large",
    429: "Too Many Requests", 500: "Internal Server Error",
    503: "Service Unavailable", 504: "Gateway Timeout",
}

# (method, path) pairs the server routes — also the bounded label universe
# for the per-route HTTP metrics
_ROUTE_PATHS = (
    ("GET", "/healthz"), ("GET", "/metrics"), ("GET", "/v1/stats"),
    ("GET", "/v1/traces"), ("POST", "/v1/search"), ("POST", "/v1/docs"),
    ("POST", "/v1/docs/delete"),
)


class _HTTPError(Exception):
    """Internal control flow: a handler's early exit with a status code."""

    def __init__(self, status: int, message: str,
                 headers: Optional[Dict[str, str]] = None):
        super().__init__(message)
        self.status = status
        self.headers = headers or {}


def _body_field(body: Dict, field: str) -> Any:
    try:
        return body[field]
    except KeyError:
        raise _HTTPError(400, f"missing required field {field!r}") from None


@dataclasses.dataclass
class _Raw:
    """A handler's non-JSON response body (e.g. Prometheus exposition)."""

    data: bytes
    content_type: str = "text/plain; charset=utf-8"


class AsyncHTTPBase:
    """Connection plumbing shared by every server in the serving tier.

    Owns the listener lifecycle, HTTP/1.1 request framing (keep-alive,
    body limits), response writing, query-string merging, executor
    dispatch of blocking handlers, and the error-taxonomy -> status-code
    mapping.  Subclasses (`RetrievalHTTPServer`, the router's
    `RouterHTTPServer`) provide a route table via ``_routes()`` and may
    override ``_observe`` to count responses into their own registry.
    """

    # (method, path) pairs the subclass routes — also the bounded label
    # universe for per-route metrics (unknown paths collapse together)
    route_paths: Tuple[Tuple[str, str], ...] = ()

    def __init__(self, *, host: str = "127.0.0.1", port: int = 0,
                 max_body: int = 64 << 20):
        self._host = host
        self._port = int(port)
        self.max_body = int(max_body)
        self._server: Optional[asyncio.base_events.Server] = None

    # -- subclass surface ----------------------------------------------------
    def _routes(self) -> Dict[Tuple[str, str], Any]:
        raise NotImplementedError

    def _observe(self, route: str, status: int, dt_ms: float) -> None:
        """Per-response metrics hook (default: none)."""

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle_conn, self._host, self._port)
        self._port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        return self._port

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self._port}"

    # -- connection handling -------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                request = await self._read_request(reader)
                if request is None:
                    break
                method, path, body, keep_alive = request
                status, payload, headers = await self._route(
                    method, path, body)
                await self._write_response(
                    writer, status, payload, headers, keep_alive)
                if not keep_alive:
                    break
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError):
            pass                               # client went away mid-request
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_request(
        self, reader: asyncio.StreamReader
    ) -> Optional[Tuple[str, str, bytes, bool]]:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").strip().split()
        if len(parts) != 3:
            raise asyncio.IncompleteReadError(line, None)
        method, path, version = parts
        headers: Dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            name, _, value = raw.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or "0")
        if length > self.max_body:
            # don't read the body; the 413 response closes the connection
            return method, path, b"__too_large__", False
        body = await reader.readexactly(length) if length else b""
        keep_alive = (headers.get(
            "connection",
            "keep-alive" if version == "HTTP/1.1" else "close",
        ).lower() != "close")
        return method, path, body, keep_alive

    async def _write_response(self, writer: asyncio.StreamWriter,
                              status: int, payload: Dict,
                              headers: Dict[str, str],
                              keep_alive: bool) -> None:
        if isinstance(payload, _Raw):
            data, content_type = payload.data, payload.content_type
        else:
            data, content_type = json.dumps(payload).encode(), \
                "application/json"
        head = [
            f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(data)}",
            f"Connection: {'keep-alive' if keep_alive else 'close'}",
        ]
        head += [f"{k}: {v}" for k, v in headers.items()]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + data)
        await writer.drain()

    # -- routing -------------------------------------------------------------
    async def _route(self, method: str, path: str,
                     body: bytes) -> Tuple[int, Dict, Dict[str, str]]:
        """Instrumented routing: every response lands in the subclass's
        per-route status counter and latency histogram (unknown paths
        collapse into one ``__other__`` route so scans can't explode the
        label space past the registry's own series cap)."""
        t0 = time.perf_counter()
        status, payload, headers = await self._route_inner(
            method, path, body)
        bare = path.split("?", 1)[0]
        route = bare if any(p == bare for (_, p) in self.route_paths) \
            else "__other__"
        self._observe(route, status, (time.perf_counter() - t0) * 1e3)
        return status, payload, headers

    async def _route_inner(self, method: str, path: str,
                           body: bytes) -> Tuple[int, Dict, Dict[str, str]]:
        if body == b"__too_large__":
            return 413, {"error": "request body exceeds "
                                  f"{self.max_body} bytes"}, {}
        path, _, qs = path.partition("?")
        params = dict(urllib.parse.parse_qsl(qs)) if qs else {}
        routes = self._routes()
        handler = routes.get((method, path))
        if handler is None:
            if any(p == path for (_, p) in routes):
                return 405, {"error": f"{method} not allowed on {path}"}, {}
            return 404, {"error": f"no route for {path}"}, {}
        if method == "POST":
            try:
                parsed = json.loads(body.decode() or "null")
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                return 400, {"error": f"malformed JSON body: {e}"}, {}
            if not isinstance(parsed, dict):
                return 400, {"error": "request body must be a JSON "
                                      "object"}, {}
        else:
            parsed = {}
        for key, value in params.items():      # body keys win over the qs
            parsed.setdefault(key, value)
        loop = asyncio.get_event_loop()
        try:
            # handlers are blocking (driver futures, device work): run them
            # on the default executor so the accept loop stays responsive
            payload = await loop.run_in_executor(None, handler, parsed)
            if isinstance(payload, tuple):     # (payload, extra headers)
                payload, headers = payload
                return 200, payload, headers
            return 200, payload, {}
        except _HTTPError as e:
            return e.status, {"error": str(e)}, e.headers
        except (FilterError, ValueError, IndexError, TypeError) as e:
            return 400, {"error": str(e)}, {}
        except QuotaExceeded as e:
            return 429, {"error": str(e), "tenant": e.tenant,
                         "limit": e.limit}, {"Retry-After": "1"}
        except DriverQueueFull as e:
            return 429, {"error": str(e),
                         "limit": "queue"}, {"Retry-After": "1"}
        except RequestFailed as e:
            return 503, {"error": str(e), "isolated": True}, {}
        except DriverStopped as e:
            return 503, {"error": str(e)}, {}
        except (DeadlineExceeded, TimeoutError) as e:
            return 504, {"error": str(e)}, {}
        except Exception as e:                 # pragma: no cover
            return 500, {"error": f"{type(e).__name__}: {e}"}, {}


class RetrievalHTTPServer(AsyncHTTPBase):
    """Asyncio HTTP server over one engine + driver pair.

    Args:
      engine:          the engine (used directly for corpus mutations and
                       stats; its lock makes quota-check + add atomic).
      driver:          the running driver that serves searches.
      quotas:          per-tenant admission limits (default: a permissive
                       ``TenantQuotas()`` — 64 in-flight, unlimited docs).
      require_tenant:  refuse tenantless search/add/delete with 400
                       (default True; turn off for single-tenant or admin
                       deployments).
      host/port:       bind address; port 0 picks a free port (read it
                       back from ``server.port`` after ``start()``).
      submit_timeout:  seconds a search waits for driver-queue space
                       before 429 (small on purpose: shed, don't buffer).
      result_timeout:  hard cap on one search round trip before 504.
      max_body:        request-body byte limit (413 past it).
      replication:     this replica's replication surface
                       (``PrimaryReplication`` / ``ReplicaApplier``):
                       drives ``/healthz?ready=1``, the deep-health
                       ``replication`` section, and ``min_seq``
                       read-your-writes waits.  None = unreplicated.
      read_only:       refuse mutations with 403 (follower replicas: the
                       primary owns the log; a 403 is deliberately
                       non-retryable so a misrouted write fails loudly).
    """

    route_paths = _ROUTE_PATHS

    def __init__(
        self,
        engine: RetrievalEngine,
        driver: EngineDriver,
        *,
        quotas: Optional[TenantQuotas] = None,
        require_tenant: bool = True,
        host: str = "127.0.0.1",
        port: int = 0,
        submit_timeout: float = 0.05,
        result_timeout: float = 60.0,
        max_body: int = 64 << 20,
        replication: Optional[Any] = None,
        read_only: bool = False,
    ):
        super().__init__(host=host, port=port, max_body=max_body)
        self.engine = engine
        self.driver = driver
        self.quotas = quotas if quotas is not None else TenantQuotas()
        self.require_tenant = bool(require_tenant)
        self.submit_timeout = float(submit_timeout)
        self.result_timeout = float(result_timeout)
        self.replication = replication
        self.read_only = bool(read_only)
        # HTTP-layer metrics live in the engine's registry so one /metrics
        # scrape covers the whole serving spine; quota rejections join it
        reg = engine.metrics
        self._c_http = reg.counter(
            "repro_http_requests_total",
            "HTTP responses, by route and status code",
            labels=("route", "status"))
        self._h_http = reg.histogram(
            "repro_http_request_ms", "HTTP request handling latency",
            labels=("route",))
        self.quotas.bind_registry(reg)

    def _observe(self, route: str, status: int, dt_ms: float) -> None:
        self._c_http.inc(route=route, status=status)
        self._h_http.observe(dt_ms, route=route)

    def _routes(self) -> Dict[Tuple[str, str], Any]:
        return {
            ("GET", "/healthz"): self._do_health,
            ("GET", "/metrics"): self._do_metrics,
            ("GET", "/v1/stats"): self._do_stats,
            ("GET", "/v1/traces"): self._do_traces,
            ("POST", "/v1/search"): self._do_search,
            ("POST", "/v1/docs"): self._do_add,
            ("POST", "/v1/docs/delete"): self._do_delete,
        }

    # -- handlers (run on executor threads; blocking is fine) ----------------
    def _check_tenant(self, body: Dict) -> Optional[str]:
        tenant = body.get("tenant")
        if tenant is not None and not isinstance(tenant, str):
            raise _HTTPError(400, "tenant must be a string")
        if tenant is None and self.require_tenant:
            raise _HTTPError(
                400, "this server requires a tenant on every request "
                     "(start it with require_tenant=False for the "
                     "single-tenant/admin mode)")
        return tenant

    def _do_health(self, body: Dict) -> Dict:
        # liveness: the driver thread is up.  Readiness (?ready=1) is
        # stricter: recovery/WAL replay is done and, on a follower,
        # catch-up is within the configured lag bound — the router's
        # probes use readiness so no traffic lands on a replaying replica
        if not self.driver.running:
            raise _HTTPError(503, "engine driver is not running")
        out: Dict[str, Any] = {"status": "ok", "n_docs": self.engine.n_docs}
        if self.replication is not None:
            out["role"] = self.replication.role
            out["applied_seq"] = self.replication.applied_seq
            out["replica_lag"] = self.replication.lag()
            out["ready"] = self.replication.ready()
        else:
            out["ready"] = True
        if str(body.get("ready", "")).lower() in ("1", "true", "yes"):
            if not out["ready"]:
                raise _HTTPError(
                    503, "replica is not ready: "
                         f"{self.replication.status()}")
        if str(body.get("deep", "")).lower() in ("1", "true", "yes"):
            sup = self.driver.supervisor
            with self.engine.lock:
                stats = self.engine.stats
                out["deep"] = {
                    "driver": self.driver.health(),
                    "supervisor": (sup.summary() if sup is not None
                                   else {"attached": False}),
                    "wal": (self.engine.wal.summary()
                            if self.engine.wal is not None else None),
                    "last_recovery": self.engine.last_recovery,
                    "replication": (self.replication.status()
                                    if self.replication is not None
                                    else None),
                    "n_quarantined": self.driver.stats.n_quarantined,
                    "n_recoveries": stats.n_recoveries,
                    "n_rebuild_failures": stats.n_rebuild_failures,
                }
        return out

    def _do_metrics(self, body: Dict) -> _Raw:
        return _Raw(self.engine.metrics.render_prometheus().encode(),
                    "text/plain; version=0.0.4; charset=utf-8")

    def _do_traces(self, body: Dict) -> Dict:
        return {
            "traces": self.engine.trace_ring.snapshot(),
            "slow_queries": self.engine.slow_log.recent(),
        }

    def _do_stats(self, body: Dict) -> Dict:
        with self.engine.lock:
            out = {
                "engine": self.engine.stats.summary(),
                "driver": self.driver.stats.summary(),
                "store": dataclasses.asdict(self.engine.store.stats()),
                # snapshot taken under engine.lock — the counters mutate
                # there on the driver thread, so this read is never torn
                "mask_cache": self.engine.store.mask_cache_stats(),
                "tenants": self.engine.store.tenants(),
                "quotas": self.quotas.snapshot(),
                "config": self.engine.config.to_dict(),
            }
        out["adaptive"] = (self.driver.adaptive.summary()
                           if self.driver.adaptive is not None
                           else {"enabled": False})
        out["cache"] = (self.driver.cache.summary()
                        if self.driver.cache is not None
                        else {"enabled": False})
        return out

    def _do_search(self, body: Dict) -> Tuple[Dict, Dict[str, str]]:
        tenant = self._check_tenant(body)
        # Quota-lifecycle discipline: EVERYTHING that can reject the
        # request (tenant check, query parsing, SearchRequest validation)
        # runs BEFORE quotas.acquire, so a rejection never holds a slot;
        # acquire itself only increments after its cap check passes (no
        # partial state on QuotaExceeded).  From acquire onward every
        # path — check_request raising in submit, DriverQueueFull,
        # DriverStopped racing the submit, result timeout, dispatch
        # errors — unwinds through the try/finally below, so release()
        # always runs exactly once and an in-flight slot can never leak
        # (the regression test hammers these paths and asserts
        # quotas.inflight returns to zero).
        query = np.asarray(_body_field(body, "query"), np.float32)
        request = SearchRequest(
            query=query,
            k=body.get("k"),
            tenant=tenant,
            filter=body.get("filter"),
            deadline_ms=body.get("deadline_ms"),
        )
        min_seq = body.get("min_seq")
        if min_seq is not None:
            # read-your-writes: block (bounded) until this replica has
            # applied the client's consistency token; runs BEFORE acquire
            # so the wait never holds a quota slot
            self._await_min_seq(int(min_seq), request.deadline_ms)
        self.quotas.acquire(tenant)
        try:
            future = self.driver.submit(request,
                                        timeout=self.submit_timeout)
            result = future.result(self.result_timeout)
        finally:
            self.quotas.release(tenant)
        live = result.doc_ids >= 0             # drop padded empty slots
        st = result.stats
        headers: Dict[str, str] = {}
        if self.driver.adaptive is not None:
            headers["degraded"] = str(result.degraded_level)
        if self.driver.cache is not None:
            headers["cache"] = "hit" if result.cached else "miss"
        return {
            "ids": result.doc_ids[live].tolist(),
            "scores": result.scores[live].astype(float).tolist(),
            "request_id": result.request_id,
            "store_generation": result.store_generation,
            "latency_ms": st.latency_ms,
            "cached": result.cached,
            "degraded_level": result.degraded_level,
            # latency decomposition: queue_ms + compute_ms ~= latency_ms;
            # stage0/rescore split the compute only under obs.stage_fences
            # (null otherwise — the keys are always present)
            "spans": {
                "queue_ms": st.queue_ms,
                "compute_ms": st.compute_ms,
                "stage0_ms": st.stage0_ms,
                "rescore_ms": st.rescore_ms,
            },
        }, headers

    def _await_min_seq(self, min_seq: int,
                       deadline_ms: Optional[float]) -> None:
        """Wait until this replica's applied seq covers the client's
        consistency token; retryable 503 if it cannot within the bound
        (the router then fails over to a caught-up replica)."""
        if self.replication is None:
            raise _HTTPError(
                503, "this server tracks no replication state; min_seq "
                     "consistency tokens are not supported here")
        wait_s = self.engine.config.replication.min_seq_wait_s
        if deadline_ms is not None:
            wait_s = min(wait_s, float(deadline_ms) / 1e3)
        if not self.replication.wait_for_seq(min_seq, wait_s):
            raise _HTTPError(
                503, f"replica applied seq "
                     f"{self.replication.applied_seq} has not reached "
                     f"min_seq {min_seq} within {wait_s:.3f}s")

    def _check_writable(self) -> None:
        if self.read_only:
            raise _HTTPError(
                403, "this replica is a read-only follower — send "
                     "mutations to the primary (or through the router)")

    def _do_add(self, body: Dict) -> Dict:
        self._check_writable()
        tenant = self._check_tenant(body)
        vectors = np.asarray(_body_field(body, "vectors"), np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        if vectors.ndim != 2:
            raise _HTTPError(
                400, f"vectors must be a (n, d) array, got shape "
                     f"{vectors.shape}")
        metadata = body.get("metadata")
        with self.engine.lock:                 # quota check + add atomically
            self.quotas.check_docs(
                tenant,
                self.engine.store.tenant_doc_count(tenant)
                if tenant is not None else 0,
                len(vectors))
            ids = self.engine.add_docs(vectors, tenant=tenant,
                                       metadata=metadata)
            # seq is the mutation's WAL position — the client's
            # read-your-writes token (pass back as min_seq on searches)
            seq = (self.engine.wal.last_seq
                   if self.engine.wal is not None else None)
        return {"ids": ids.tolist(), "n_added": len(ids), "seq": seq}

    def _do_delete(self, body: Dict) -> Dict:
        self._check_writable()
        tenant = self._check_tenant(body)
        ids = np.asarray(_body_field(body, "ids"), np.int64).reshape(-1)
        with self.engine.lock:                 # ownership check + delete
            store = self.engine.store
            if tenant is not None:
                for doc_id in ids.tolist():
                    if not 0 <= doc_id < store.size:
                        raise _HTTPError(
                            400, f"doc id {doc_id} out of range")
                    owner = store.tenant_of(doc_id)
                    if store.is_live(doc_id) and owner != tenant:
                        raise _HTTPError(
                            403, f"doc {doc_id} does not belong to "
                                 f"tenant {tenant!r}")
            n_deleted = self.engine.delete_docs(ids)
            seq = (self.engine.wal.last_seq
                   if self.engine.wal is not None else None)
        return {"n_deleted": n_deleted, "seq": seq}


@dataclasses.dataclass
class ServerHandle:
    """A server running on its own event-loop thread (see
    ``serve_in_thread``); ``stop()`` is idempotent and joins the thread."""

    server: AsyncHTTPBase
    _loop: asyncio.AbstractEventLoop
    _thread: threading.Thread

    @property
    def url(self) -> str:
        return self.server.url

    @property
    def port(self) -> int:
        return self.server.port

    def stop(self, timeout: float = 10.0) -> None:
        if self._thread.is_alive():
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout)
            if self._thread.is_alive():        # pragma: no cover
                raise TimeoutError("server thread did not stop")

    def __enter__(self) -> "ServerHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False


def serve_in_thread(engine: RetrievalEngine, driver: EngineDriver,
                    **kwargs) -> ServerHandle:
    """Boot a ``RetrievalHTTPServer`` on a dedicated event-loop thread.

    Returns once the socket is bound (``handle.url`` is ready to hit).
    The caller keeps ownership of the driver's lifecycle — stopping the
    handle closes the listener but leaves engine and driver running.
    """
    return run_server_in_thread(RetrievalHTTPServer(engine, driver, **kwargs))


def run_server_in_thread(server: AsyncHTTPBase,
                         thread_name: str = "retrieval-http") -> ServerHandle:
    """Boot any ``AsyncHTTPBase`` server on its own event-loop thread."""
    started = threading.Event()
    boot_error: list = []
    loop = asyncio.new_event_loop()

    def run() -> None:
        asyncio.set_event_loop(loop)
        try:
            loop.run_until_complete(server.start())
        except Exception as e:                 # pragma: no cover
            boot_error.append(e)
            started.set()
            loop.close()
            return
        started.set()
        try:
            loop.run_forever()
        finally:
            loop.run_until_complete(server.stop())
            loop.close()

    thread = threading.Thread(target=run, name=thread_name,
                              daemon=True)
    thread.start()
    started.wait()
    if boot_error:                             # pragma: no cover
        raise boot_error[0]
    return ServerHandle(server, loop, thread)

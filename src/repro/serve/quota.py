"""Per-tenant admission control in front of the driver's bounded queue.

The driver's ``max_queue`` bound is global: one greedy tenant can fill it
and starve everyone else behind ``DriverQueueFull``.  ``TenantQuotas`` sits
in front of it and rejects *per tenant* — a tenant at its in-flight cap gets
a fast 429 while other tenants' requests still reach the queue.  Two limits:

* ``max_inflight`` — concurrent searches a tenant may have between submit
  and response (acquired before ``driver.submit``, released when the future
  resolves, success or not).
* ``max_docs`` — live documents a tenant may store (checked against
  ``DocStore.tenant_doc_count`` before an add; deletes free budget).

Both accept per-tenant overrides; ``None`` disables a limit.  The class is
plain thread-safe Python — no asyncio coupling — so the HTTP layer's
executor threads and any direct driver clients can share one instance.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.obs import NULL_INSTRUMENT


class QuotaExceeded(RuntimeError):
    """A tenant hit one of its admission limits (the HTTP layer's 429)."""

    def __init__(self, tenant: Optional[str], limit: str, message: str):
        super().__init__(message)
        self.tenant = tenant
        self.limit = limit                     # "inflight" | "docs"


class TenantQuotas:
    """Thread-safe per-tenant limit bookkeeping.

    Args:
      max_inflight: default concurrent-search cap per tenant
                    (None = unlimited).
      max_docs:     default live-document cap per tenant (None = unlimited).
      overrides:    {tenant: {"max_inflight": n, "max_docs": n}} exceptions
                    to the defaults (a key set to None lifts that limit for
                    that tenant).

    The tenantless pool (``tenant=None``) is the admin/legacy view and is
    never limited — servers that want no anonymous traffic at all enforce
    that with ``require_tenant`` instead.
    """

    def __init__(self, *, max_inflight: Optional[int] = 64,
                 max_docs: Optional[int] = None,
                 overrides: Optional[Dict[str, Dict]] = None):
        if max_inflight is not None and max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1 or None, got {max_inflight}")
        if max_docs is not None and max_docs < 0:
            raise ValueError(
                f"max_docs must be >= 0 or None, got {max_docs}")
        self._max_inflight = max_inflight
        self._max_docs = max_docs
        self._overrides = {t: dict(o) for t, o in (overrides or {}).items()}
        self._inflight: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._c_rejections = NULL_INSTRUMENT

    def bind_registry(self, registry) -> None:
        """Count rejections in a `repro.obs.MetricsRegistry` as
        ``repro_quota_rejections_total{tenant,limit}`` (the registry's
        series cap bounds an unruly tenant universe)."""
        self._c_rejections = registry.counter(
            "repro_quota_rejections_total",
            "Per-tenant admission rejections, by limit hit",
            labels=("tenant", "limit"))

    def _limit(self, tenant: str, name: str, default: Optional[int]):
        return self._overrides.get(tenant, {}).get(name, default)

    # -- in-flight searches --------------------------------------------------
    def acquire(self, tenant: Optional[str]) -> None:
        """Claim one in-flight slot for ``tenant`` or raise ``QuotaExceeded``.

        Every successful call must be paired with ``release`` — use
        try/finally around the submit-and-wait.
        """
        if tenant is None:
            return
        with self._lock:
            cap = self._limit(tenant, "max_inflight", self._max_inflight)
            held = self._inflight.get(tenant, 0)
            if cap is not None and held >= cap:
                self._c_rejections.inc(tenant=tenant, limit="inflight")
                raise QuotaExceeded(
                    tenant, "inflight",
                    f"tenant {tenant!r} already has {held} searches in "
                    f"flight (cap {cap})")
            self._inflight[tenant] = held + 1

    def release(self, tenant: Optional[str]) -> None:
        if tenant is None:
            return
        with self._lock:
            held = self._inflight.get(tenant, 0)
            if held <= 0:
                raise RuntimeError(
                    f"release() without acquire() for tenant {tenant!r}")
            if held == 1:
                self._inflight.pop(tenant)
            else:
                self._inflight[tenant] = held - 1

    # -- document budget -----------------------------------------------------
    def check_docs(self, tenant: Optional[str], current: int,
                   adding: int) -> None:
        """Reject an add that would push ``tenant`` past its document cap."""
        if tenant is None:
            return
        cap = self._limit(tenant, "max_docs", self._max_docs)
        if cap is not None and current + adding > cap:
            self._c_rejections.inc(tenant=tenant, limit="docs")
            raise QuotaExceeded(
                tenant, "docs",
                f"tenant {tenant!r} holds {current} docs; adding {adding} "
                f"would exceed cap {cap}")

    # -- introspection -------------------------------------------------------
    def inflight(self, tenant: str) -> int:
        with self._lock:
            return self._inflight.get(tenant, 0)

    def snapshot(self) -> Dict:
        """Current limits + per-tenant in-flight counts (for /v1/stats)."""
        with self._lock:
            return {
                "max_inflight": self._max_inflight,
                "max_docs": self._max_docs,
                "overrides": {t: dict(o)
                              for t, o in self._overrides.items()},
                "inflight": dict(self._inflight),
            }

"""AutoInt [arXiv:1810.11921; paper]: self-attention feature interaction.

39 sparse fields, embed_dim=16, 3 attention layers x 2 heads x d_attn=32
(Criteo-scale vocabularies ~ 100k rows/field).
"""

from repro.configs.base import RecsysConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="autoint", family="autoint",
    embed_dim=16, n_sparse=39, vocab_per_field=100_000,
    n_attn_layers=3, n_attn_heads=2, d_attn=32, interaction="self-attn",
)

SMOKE_CONFIG = RecsysConfig(
    name="autoint-smoke", family="autoint",
    embed_dim=8, n_sparse=6, vocab_per_field=500,
    n_attn_layers=2, n_attn_heads=2, d_attn=8,
)

SHAPES = RECSYS_SHAPES

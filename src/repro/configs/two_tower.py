"""Two-tower retrieval [Yi et al. RecSys'19 (YouTube); unverified].

embed_dim=256, tower MLPs 1024-512-256, dot-product interaction, in-batch
sampled softmax.  8 sparse fields (4 user + 4 item), 1M rows per field.

This is the architecture where the paper's progressive search is the serving
path: retrieval_cand scores one query against a 1M-item embedding DB through
the multi-stage truncated schedule (`repro.models.recsys.retrieval_serve`).
"""

from repro.configs.base import RecsysConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="two-tower-retrieval", family="two_tower",
    embed_dim=256, n_sparse=8, vocab_per_field=1_000_000,
    tower_mlp=(1024, 512, 256), interaction="dot",
    retrieval_d_start=64, retrieval_k0=128,
    matryoshka_dims=(64, 128),
)

SMOKE_CONFIG = RecsysConfig(
    name="two-tower-smoke", family="two_tower",
    embed_dim=32, n_sparse=4, vocab_per_field=1000,
    tower_mlp=(64, 32), interaction="dot",
    retrieval_d_start=8, retrieval_k0=16,
    matryoshka_dims=(8, 16),
)

SHAPES = RECSYS_SHAPES

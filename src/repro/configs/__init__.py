"""Architecture registry: ``get_arch(id)`` -> module with CONFIG /
SMOKE_CONFIG / SHAPES.  ``--arch <id>`` everywhere resolves through here.
"""

import importlib
from typing import Dict, List

_ARCHS: Dict[str, str] = {
    # LM family
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    # GNN
    "egnn": "repro.configs.egnn",
    # RecSys
    "two-tower-retrieval": "repro.configs.two_tower",
    "din": "repro.configs.din",
    "autoint": "repro.configs.autoint",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
}

LM_ARCHS = ["starcoder2-3b", "gemma3-4b", "mistral-nemo-12b",
            "deepseek-v2-236b", "qwen3-moe-235b-a22b"]
GNN_ARCHS = ["egnn"]
RECSYS_ARCHS = ["two-tower-retrieval", "din", "autoint", "dlrm-rm2"]


def list_archs() -> List[str]:
    return list(_ARCHS)


def get_arch(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_ARCHS)}")
    return importlib.import_module(_ARCHS[arch_id])


def family_of(arch_id: str) -> str:
    if arch_id in LM_ARCHS:
        return "lm"
    if arch_id in GNN_ARCHS:
        return "gnn"
    if arch_id in RECSYS_ARCHS:
        return "recsys"
    raise KeyError(arch_id)

"""Config dataclasses for every architecture family in the zoo.

Configs are frozen dataclasses (hashable -> usable as jit static args).
Every architecture file in `repro.configs` exposes

    CONFIG        — the exact published configuration
    SMOKE_CONFIG  — a reduced same-family configuration for CPU smoke tests
    SHAPES        — dict of shape-name -> ShapeSpec (the assigned input shapes)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# --------------------------------------------------------------------------
# input shapes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (architecture x input-shape) cell of the dry-run matrix."""

    name: str
    kind: str                      # 'train' | 'prefill' | 'decode' | 'graph' | 'recsys'
    seq_len: int = 0
    global_batch: int = 0
    # graph shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    graph_batch: int = 0           # batched-small-graphs
    # recsys shapes
    n_candidates: int = 0
    skip_reason: str = ""          # non-empty -> documented skip (DESIGN.md)


# --------------------------------------------------------------------------
# LM transformers
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001
    router_norm_topk: bool = True  # normalize top-k gate weights to sum 1
    first_k_dense: int = 0         # leading dense layers (DeepSeek-V2 uses 1)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int               # 0 -> full-rank q projection
    kv_lora_rank: int
    d_nope: int                    # per-head non-rotary dim
    d_rope: int                    # per-head rotary dim (shared key)
    d_v: int                       # per-head value dim


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    ffn_type: str = "swiglu"       # 'swiglu' | 'mlp' (gelu)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # attention pattern
    window: int = 0                # 0 -> full attention
    local_global_period: int = 0   # gemma3: every Nth layer is global (others local)
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0  # gemma3 uses a different theta for local layers
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True             # checkpoint each layer in training
    max_position: int = 131072

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def layer_window(self, layer: int) -> int:
        """Static per-layer sliding window (0 = full attention)."""
        if self.local_global_period <= 0:
            return self.window
        # gemma3 pattern: layers 0..p-2 local, layer p-1 global, repeating.
        if (layer + 1) % self.local_global_period == 0:
            return 0
        return self.window

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            q = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.d_nope + m.d_rope)
                 if m.q_lora_rank else d * self.n_heads * (m.d_nope + m.d_rope))
            kv = d * (m.kv_lora_rank + m.d_rope) + m.kv_lora_rank * self.n_heads * (m.d_nope + m.d_v)
            attn = q + kv + self.n_heads * m.d_v * d
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
        if self.moe is not None:
            e = self.moe
            gmul = 3 if self.ffn_type == "swiglu" else 2
            moe_ffn = e.n_experts * gmul * d * e.d_ff_expert \
                + e.n_shared_experts * gmul * d * e.d_ff_shared + d * e.n_experts
            dense_ffn = gmul * d * f
            ffn_total = e.first_k_dense * dense_ffn + (L - e.first_k_dense) * moe_ffn
            return emb + L * attn + ffn_total
        gmul = 3 if self.ffn_type == "swiglu" else 2
        return emb + L * (attn + gmul * d * f)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.n_layers
        gmul = 3 if self.ffn_type == "swiglu" else 2
        total = self.param_count()
        all_experts = (L - e.first_k_dense) * e.n_experts * gmul * d * e.d_ff_expert
        active_experts = (L - e.first_k_dense) * e.top_k * gmul * d * e.d_ff_expert
        return total - all_experts + active_experts


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_feat_in: int = 0             # set per shape
    d_coord: int = 3
    d_edge: int = 0
    n_classes: int = 16
    param_dtype: str = "float32"
    # dtype of gathered/scattered message tensors: full-graph cells are
    # collective-bound (node features replicate across edge shards); bf16
    # messages halve the wire bytes (§Perf iteration log)
    message_dtype: str = "float32"


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    family: str                    # 'two_tower' | 'din' | 'autoint' | 'dlrm'
    embed_dim: int
    n_dense: int = 0
    n_sparse: int = 0
    vocab_per_field: int = 1_000_000
    multi_hot: int = 1             # ids per sparse field (bag size)
    # two-tower
    tower_mlp: Tuple[int, ...] = ()
    # din
    seq_len: int = 0
    attn_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # dlrm
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    interaction: str = "dot"
    param_dtype: str = "float32"
    # progressive-retrieval integration (two-tower serving)
    retrieval_d_start: int = 64
    retrieval_k0: int = 128
    # Matryoshka auxiliary losses: also train the in-batch softmax on these
    # truncated prefixes, so the learned index is truncation-friendly and
    # the paper's progressive schedule applies without recall loss
    # (text-embedding-3 trains this way; beyond-paper framework feature).
    matryoshka_dims: Tuple[int, ...] = ()

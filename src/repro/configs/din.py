"""DIN [arXiv:1706.06978; paper]: target-attention over user history.

embed_dim=18, history seq_len=100, attention MLP 80-40, main MLP 200-80,
1M-item vocabulary.
"""

from repro.configs.base import RecsysConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="din", family="din",
    embed_dim=18, vocab_per_field=1_000_000, seq_len=100,
    attn_mlp=(80, 40), mlp=(200, 80), interaction="target-attn",
)

SMOKE_CONFIG = RecsysConfig(
    name="din-smoke", family="din",
    embed_dim=8, vocab_per_field=1000, seq_len=10,
    attn_mlp=(16, 8), mlp=(32, 16),
)

SHAPES = RECSYS_SHAPES

"""StarCoder2-3B [arXiv:2402.19173; hf]: dense GQA decoder, RoPE.

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288, vocab=49152.
StarCoder2 uses a plain GELU FFN (not gated) and learned+rotary positions;
we keep RoPE + RMSNorm (framework-uniform; noted in DESIGN.md).
"""

from repro.configs.base import LMConfig
from repro.configs.shapes import lm_shapes

CONFIG = LMConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49152, ffn_type="mlp",
    rope_theta=1e5, max_position=16384,
)

SMOKE_CONFIG = LMConfig(
    name="starcoder2-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab=512, ffn_type="mlp",
    param_dtype="float32", compute_dtype="float32", remat=False,
)

SHAPES = lm_shapes(long_ok=False)

"""Shared input-shape sets for each architecture family (assigned cells)."""

from repro.configs.base import ShapeSpec

LM_SHAPES = {
    "train_4k": ShapeSpec(name="train_4k", kind="train",
                          seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec(name="prefill_32k", kind="prefill",
                             seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec(name="decode_32k", kind="decode",
                            seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec(name="long_500k", kind="decode",
                           seq_len=524288, global_batch=1),
}


def lm_shapes(*, long_ok: bool, skip_reason: str = ""):
    shapes = dict(LM_SHAPES)
    if not long_ok:
        import dataclasses
        shapes["long_500k"] = dataclasses.replace(
            shapes["long_500k"],
            skip_reason=skip_reason or (
                "pure full-attention arch: 512k decode requires sub-quadratic "
                "attention / windowed cache (see DESIGN.md §Arch-applicability)"))
    return shapes


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(name="full_graph_sm", kind="graph",
                               n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": ShapeSpec(name="minibatch_lg", kind="graph",
                              n_nodes=232965, n_edges=114615892,
                              batch_nodes=1024, fanout=(15, 10), d_feat=602),
    "ogb_products": ShapeSpec(name="ogb_products", kind="graph",
                              n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule": ShapeSpec(name="molecule", kind="graph",
                          n_nodes=30, n_edges=64, graph_batch=128, d_feat=16),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec(name="train_batch", kind="recsys",
                             global_batch=65536),
    "serve_p99": ShapeSpec(name="serve_p99", kind="recsys",
                           global_batch=512),
    "serve_bulk": ShapeSpec(name="serve_bulk", kind="recsys",
                            global_batch=262144),
    "retrieval_cand": ShapeSpec(name="retrieval_cand", kind="recsys",
                                global_batch=1, n_candidates=1_000_000),
}

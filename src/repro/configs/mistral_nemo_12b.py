"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407; hf]: dense GQA, 128k.

40L, d_model=5120, 32 heads (GQA kv=8), head_dim=128, d_ff=14336,
vocab=131072, SwiGLU, rope theta 1M, full attention.
"""

from repro.configs.base import LMConfig
from repro.configs.shapes import lm_shapes

CONFIG = LMConfig(
    name="mistral-nemo-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072, ffn_type="swiglu",
    rope_theta=1e6, max_position=131072,
)

SMOKE_CONFIG = LMConfig(
    name="mistral-nemo-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512, ffn_type="swiglu",
    param_dtype="float32", compute_dtype="float32", remat=False,
)

SHAPES = lm_shapes(long_ok=False)

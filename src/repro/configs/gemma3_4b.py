"""Gemma3-4B [hf:google/gemma-3-4b-pt; unverified]: 5:1 local:global, 128k.

34L, d_model=2560, 8 heads (GQA kv=4), head_dim=256, d_ff=10240,
vocab=262144.  Every 6th layer is global (full attention, rope theta 1M);
the rest are 1024-token sliding-window local layers (theta 10k).

long_500k runs for this arch: the hybrid local:global pattern makes decode
sub-quadratic-in-memory (window-sized ring caches on 5/6 of the layers) and
the sequence axis of the remaining global caches shards over the mesh.
"""

from repro.configs.base import LMConfig
from repro.configs.shapes import lm_shapes

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab=262144, ffn_type="swiglu",
    window=1024, local_global_period=6,
    rope_theta=1e6, rope_theta_local=1e4,
    tie_embeddings=True, max_position=131072,
)

SMOKE_CONFIG = LMConfig(
    name="gemma3-smoke",
    n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab=512, ffn_type="swiglu",
    window=16, local_global_period=3,
    rope_theta=1e6, rope_theta_local=1e4, tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32", remat=False,
)

SHAPES = lm_shapes(long_ok=True)

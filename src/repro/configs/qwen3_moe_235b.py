"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-235B-A22B; hf]: 128-expert top-8 MoE.

94L, d_model=4096, 64 heads (GQA kv=4), head_dim=128, 128 routed experts
top-8 (no shared experts), expert d_ff=1536, vocab=151936, SwiGLU.
"""

from repro.configs.base import LMConfig, MoEConfig
from repro.configs.shapes import lm_shapes

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936, ffn_type="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25, router_norm_topk=True),
    rope_theta=1e6, max_position=131072,
)

SMOKE_CONFIG = LMConfig(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, ffn_type="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
    param_dtype="float32", compute_dtype="float32", remat=False,
)

SHAPES = lm_shapes(long_ok=False)

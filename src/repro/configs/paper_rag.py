"""The paper's own experimental configuration (§III-IV).

Two embedding regimes (gte-Qwen2-7B-instruct 3584d, text-embedding-3-large
3072d) over a 1M corpus with 2470 queries; Table III/V progressive configs.

Offline, the corpus is synthetic (`repro.rag.make_corpus`) with the default
dimension budget scaled to 1024 (full-scale runs pass --dim 3584 --docs
1000000); schedules below are expressed relative to whatever d_max is used.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperRAGConfig:
    n_docs: int = 1_000_000
    n_queries: int = 2470
    dim_gte: int = 3584
    dim_openai: int = 3072
    # Table II/IV truncation sweep (powers of two + full)
    trunc_dims: tuple = (16, 32, 64, 128, 256, 512, 1024, 2048)
    # Table III (gte): (d_start, d_max, K) fastest matched-accuracy configs
    table3_configs: tuple = (
        (128, 512, 128),
        (128, 2048, 16),
        (128, 3584, 64),
        (256, 3584, 16),
        (512, 3584, 16),
    )
    # Table V (openai)
    table5_configs: tuple = (
        (128, 256, 128),
        (256, 512, 16),
        (128, 2048, 32),
        (128, 3072, 64),
        (256, 3072, 64),
    )
    # progressive sweep grid (§IV.A)
    sweep_d_start: tuple = (64, 128, 256, 512, 1024, 2048)
    sweep_k0: tuple = (4, 8, 16, 32, 64, 128, 256, 512, 1024)
    sweep_d_max: tuple = (128, 256, 512, 1024, 2048, 3584)


CONFIG = PaperRAGConfig()

# reduced budget for the offline container (dims scale ~1/3.5, docs 1/10)
SMOKE_CONFIG = PaperRAGConfig(
    n_docs=100_000, n_queries=1000, dim_gte=1024, dim_openai=768,
    trunc_dims=(16, 32, 64, 128, 256, 512),
    table3_configs=((64, 256, 64), (64, 512, 16), (64, 1024, 32),
                    (128, 1024, 16), (256, 1024, 16)),
    table5_configs=((64, 128, 64), (128, 256, 16), (64, 512, 32),
                    (64, 768, 32), (128, 768, 32)),
    sweep_d_start=(32, 64, 128, 256),
    sweep_k0=(4, 8, 16, 32, 64, 128),
    sweep_d_max=(128, 256, 512, 1024),
)

"""EGNN [arXiv:2102.09844; paper]: E(n)-equivariant GNN, 4 layers, hidden 64.

Message passing is segment_sum over an edge list; the four assigned graph
shapes exercise full-batch small (cora-like), sampled-minibatch (reddit-like,
real fanout sampler), full-batch-large (ogbn-products), and batched small
molecules.
"""

from repro.configs.base import EGNNConfig
from repro.configs.shapes import GNN_SHAPES

CONFIG = EGNNConfig(
    name="egnn", n_layers=4, d_hidden=64, n_classes=47,
)

SMOKE_CONFIG = EGNNConfig(
    name="egnn-smoke", n_layers=2, d_hidden=16, d_feat_in=8, n_classes=4,
)

SHAPES = GNN_SHAPES

"""DeepSeek-V2-236B [arXiv:2405.04434; hf]: MLA + fine-grained MoE.

60L, d_model=5120, 128 heads, MLA (q_lora=1536, kv_lora=512, d_nope=128,
d_rope=64, d_v=128); MoE: 2 shared + 160 routed experts top-6,
expert d_ff=1536, first layer dense (d_ff=12288); vocab=102400.

Decode uses the absorbed-MLA path: the cache is (c_kv 512 + k_rope 64) per
token — 9x smaller than GQA-8 at the same d_model.
"""

from repro.configs.base import LMConfig, MLAConfig, MoEConfig
from repro.configs.shapes import lm_shapes

CONFIG = LMConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=12288, vocab=102400, ffn_type="swiglu",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared_experts=2, d_ff_shared=1536,
                  capacity_factor=1.25, first_k_dense=1),
    rope_theta=1e4, max_position=131072,
)

SMOKE_CONFIG = LMConfig(
    name="deepseek-v2-smoke",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab=512, ffn_type="swiglu",
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  d_nope=32, d_rope=16, d_v=32),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                  n_shared_experts=1, d_ff_shared=64, first_k_dense=1),
    param_dtype="float32", compute_dtype="float32", remat=False,
)

SHAPES = lm_shapes(long_ok=False)

"""DLRM-RM2 [arXiv:1906.00091; paper]: Facebook ranking model 2.

13 dense + 26 sparse features, embed_dim=64, bottom MLP 13-512-256-64,
top MLP 512-512-256-1, pairwise-dot interaction; 5M rows per table
(RM2-scale).  Tables shard table-wise over 'model' and row-wise over 'data'
(hybrid parallelism); the lookup exchange is the collective-bound hot spot.
"""

from repro.configs.base import RecsysConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="dlrm-rm2", family="dlrm",
    embed_dim=64, n_dense=13, n_sparse=26, vocab_per_field=5_000_000,
    bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1), interaction="dot",
)

SMOKE_CONFIG = RecsysConfig(
    name="dlrm-smoke", family="dlrm",
    embed_dim=16, n_dense=13, n_sparse=6, vocab_per_field=1000,
    bot_mlp=(32, 16), top_mlp=(32, 16, 1),
)

SHAPES = RECSYS_SHAPES

"""Fused L2-distance + streaming top-k Pallas TPU kernel.

This is the stage-0 hot loop of progressive retrieval: score every database
row against a query block at a truncated dimensionality and keep the best k
per query.  The fusion is the point — for Q=2470 queries and N=1M docs the
(Q, N) score matrix is ~10 GB; computing it through HBM makes the scan
memory-bound.  The kernel keeps the running top-k in VMEM scratch, so HBM
traffic collapses to *one streaming read of the database* (N·d bytes) plus a
(Q, k) result — which pushes the scan from the memory roofline onto the
compute (MXU) roofline.

Tiling (grid = (Q/bq, N/bn); the document axis is the inner, sequential,
dimension so the top-k carry in scratch is valid — TPU grids execute in
row-major order and revisit scratch in place):

              d (stage dim)                 k
    q_ref  : (bq, d)    VMEM     out_s  : (bq, k)  VMEM
    db_ref : (bn, d)    VMEM     out_i  : (bq, k)  VMEM
    sq_ref : (1, bn)    VMEM     scratch: best_s/best_i (bq, k)

Per tile: ``scores = sq - 2 * q @ db^T`` on the MXU (f32 accumulate), then the
tile's candidates are folded into the carry.  Two merge strategies:

* ``merge='sort'``   — concat (k + bn) columns, one ``lax.top_k``.  Fewer,
  larger ops; relies on Mosaic's sort lowering.
* ``merge='select'`` — k iterations of (argmin, mask).  Only min/where/iota —
  lowers everywhere, and is the guaranteed path on older toolchains.

Both are validated against `repro.kernels.ref.l2_topk_ref` in interpret mode
(this container is CPU-only; real-TPU runs select the same code path with
``interpret=False``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams, MemorySpace

Array = jax.Array

_NEG_INF = float("-inf")


def _merge_topk_sort(cat_s: Array, cat_i: Array, k: int) -> Tuple[Array, Array]:
    """Top-k smallest via one descending top_k on negated scores."""
    neg, pos = jax.lax.top_k(-cat_s, k)
    return -neg, jnp.take_along_axis(cat_i, pos, axis=1)


def _merge_topk_select(cat_s: Array, cat_i: Array, k: int) -> Tuple[Array, Array]:
    """Top-k smallest via k rounds of (min, argmin-mask).

    O(k · width) VPU work, but only elementwise ops + reductions, which lower
    on every Mosaic version.  Ties broken by lowest column index.
    """
    bq, width = cat_s.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)

    def body(j, carry):
        s, out_s, out_i = carry
        m = jnp.min(s, axis=1, keepdims=True)                    # (bq, 1)
        is_min = s == m
        # lowest column among the minima
        first = jnp.min(jnp.where(is_min, cols, width), axis=1, keepdims=True)
        hit = cols == first
        out_s = out_s.at[:, j].set(m[:, 0])
        out_i = out_i.at[:, j].set(
            jnp.sum(jnp.where(hit, cat_i, 0), axis=1)
        )
        s = jnp.where(hit, jnp.inf, s)
        return s, out_s, out_i

    out_s = jnp.zeros((bq, k), cat_s.dtype)
    out_i = jnp.zeros((bq, k), cat_i.dtype)
    _, out_s, out_i = jax.lax.fori_loop(0, k, body, (cat_s, out_s, out_i))
    return out_s, out_i


def _kernel(
    q_ref, db_ref, sq_ref, out_s_ref, out_i_ref, best_s, best_i,
    *, k: int, bn: int, merge: str, n_valid: int,
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    q = q_ref[...]
    db = db_ref[...]
    sq = sq_ref[...]  # (1, bn)

    ip = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    scores = sq - 2.0 * ip                                     # (bq, bn)
    base = j * bn
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    # Mask rows past the true db length (padding tile).
    scores = jnp.where(col < n_valid, scores, jnp.inf)

    cat_s = jnp.concatenate([best_s[...], scores], axis=1)
    cat_i = jnp.concatenate([best_i[...], col], axis=1)
    if merge == "sort":
        new_s, new_i = _merge_topk_sort(cat_s, cat_i, k)
    else:
        new_s, new_i = _merge_topk_select(cat_s, cat_i, k)
    best_s[...] = new_s
    best_i[...] = new_i

    @pl.when(j == nj - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_n", "merge", "interpret"),
)
def l2_topk(
    q: Array,
    db: Array,
    *,
    k: int,
    db_sq: Optional[Array] = None,
    block_q: int = 256,
    block_n: int = 512,
    merge: str = "sort",
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Fused distance+top-k scan of ``db`` for each row of ``q``.

    Args:
      q:      (Q, d) queries.
      db:     (N, d) database (same trailing dim; truncate before calling).
      k:      neighbours kept (static; k <= block_n).
      db_sq:  optional (N,) precomputed squared norms.
      block_q/block_n: VMEM tile sizes.  ``d * (block_q + block_n) * 4`` bytes
        plus the (block_q, block_n) score tile must fit VMEM (~16 MB/core).
      merge:  'sort' | 'select' (see module docstring).
      interpret: run the kernel in interpret mode (CPU validation).

    Returns:
      ((Q, k) float32 rank-equivalent scores ascending, (Q, k) int32 indices).
    """
    nq, d = q.shape
    n, d2 = db.shape
    assert d == d2, (d, d2)
    if k > block_n:
        raise ValueError(f"k={k} must be <= block_n={block_n}")
    if db_sq is None:
        db_sq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)

    # Pad every axis to tile multiples.
    pq = -nq % block_q
    pn = -n % block_n
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0)))
    if pn:
        db = jnp.pad(db, ((0, pn), (0, 0)))
        db_sq = jnp.pad(db_sq, (0, pn), constant_values=jnp.inf)
    sq2d = db_sq.reshape(1, -1)

    grid = (q.shape[0] // block_q, db.shape[0] // block_n)
    kernel = functools.partial(
        _kernel, k=k, bn=block_n, merge=merge, n_valid=n
    )
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((q.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            MemorySpace.VMEM((block_q, k), jnp.float32),
            MemorySpace.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, db, sq2d)
    return out_s[:nq], out_i[:nq]

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical specification the kernel must match
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose).
No tiling, no memory-space tricks — just the math.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_topk_ref(
    q: Array, db: Array, k: int, db_sq: Optional[Array] = None
) -> Tuple[Array, Array]:
    """Exact top-k by rank-equivalent L2 score ``||x||^2 - 2 q.x``.

    Returns ((Q, k) scores ascending, (Q, k) int32 indices).
    """
    if db_sq is None:
        db_sq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)
    ip = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = db_sq[None, :] - 2.0 * ip
    neg, idx = jax.lax.top_k(-s, k)
    return -neg, idx.astype(jnp.int32)


def gather_rescore_ref(
    q: Array, db: Array, cand: Array
) -> Array:
    """Distances of each query to its own candidate rows at full dims.

    Args:
      q:    (Q, D); db: (N, D); cand: (Q, C) int32, -1 = padding.
    Returns:
      (Q, C) float32 scores, +inf at padded slots.
    """
    safe = jnp.maximum(cand, 0)
    rows = db[safe]  # (Q, C, D)
    sq = jnp.sum(rows.astype(jnp.float32) ** 2, axis=-1)
    ip = jnp.einsum("qd,qcd->qc", q, rows, preferred_element_type=jnp.float32)
    s = sq - 2.0 * ip
    return jnp.where(cand >= 0, s, jnp.inf)


def ivf_scan_ref(
    q: Array, db: Array, member_ids: Array, probe: Array, *, dim: int, k: int
) -> Tuple[Array, Array]:
    """Fused IVF stage-0 oracle: exact top-k over each query's probed lists.

    Args:
      q:          (Q, D) queries; db: (N, D) corpus.
      member_ids: (n_lists, max_len) int32 global ids, -1 = masked/padding.
      probe:      (Q, n_probe) int32 probed list indices (distinct per row).
      dim:        stage-0 truncation; k: neighbours kept.
    Returns:
      ((Q, k) scores ascending, +inf empties; (Q, k) int32 ids, -1 empties).
    """
    cand = member_ids[probe].reshape(q.shape[0], -1)   # (Q, n_probe*max_len)
    s = gather_rescore_ref(q[:, :dim], db[:, :dim], cand)
    neg, pos = jax.lax.top_k(-s, k)
    idx = jnp.take_along_axis(cand, pos, axis=1)
    idx = jnp.where(jnp.isfinite(-neg), idx, -1)
    return -neg, idx.astype(jnp.int32)


def pq_adc_ref(lut: Array, codes: Array) -> Array:
    """ADC scores of every query against every coded row.

    Args:
      lut:   (Q, M, C) per-query lookup tables (rank-equivalent distances).
      codes: (N, M) uint8 PQ codes.
    Returns:
      (Q, N) float32: ``sum_m lut[q, m, codes[n, m]]``.
    """
    idx = codes.astype(jnp.int32)                     # (N, M)
    m = idx.shape[1]
    planes = [jnp.take(lut[:, j, :], idx[:, j], axis=1) for j in range(m)]
    return functools.reduce(jnp.add, planes)


def pq_scan_ref(
    lut: Array, codes: Array, ids: Array, *, k: int
) -> Tuple[Array, Array]:
    """Fused flat PQ scan oracle: exact ADC top-k over masked rows.

    Args:
      lut:   (Q, M, C) per-query lookup tables.
      codes: (N, M) uint8 codes.
      ids:   (N,) int32 ids, -1 = masked (tombstoned / uncoded).
      k:     neighbours kept.
    Returns:
      ((Q, k) scores ascending, +inf empties; (Q, k) int32 ids, -1 empties).
    """
    s = pq_adc_ref(lut, codes)
    s = jnp.where(ids[None, :] >= 0, s, jnp.inf)
    neg, pos = jax.lax.top_k(-s, k)
    idx = jnp.where(jnp.isfinite(-neg), ids[pos], -1)
    return -neg, idx.astype(jnp.int32)


def pq_ivf_scan_ref(
    lut: Array, codes: Array, member_ids: Array, probe: Array, *, k: int
) -> Tuple[Array, Array]:
    """Fused IVF-PQ stage-0 oracle: ADC top-k over each query's probed lists.

    Args:
      lut:        (Q, M, C) per-query lookup tables.
      codes:      (N, M) uint8 codes indexed by *global* doc id.
      member_ids: (n_lists, max_len) int32 global ids, -1 = masked/padding.
      probe:      (Q, n_probe) int32 probed lists (distinct per row).
      k:          neighbours kept.
    Returns:
      ((Q, k) scores ascending, +inf empties; (Q, k) int32 ids, -1 empties).
    """
    cand = member_ids[probe].reshape(lut.shape[0], -1)  # (Q, n_probe*max_len)
    safe = jnp.maximum(cand, 0)
    idx = codes.astype(jnp.int32)                       # (N, M)
    m = idx.shape[1]
    planes = [
        jnp.take_along_axis(lut[:, j, :], idx[safe, j], axis=1)
        for j in range(m)
    ]
    s = functools.reduce(jnp.add, planes)               # (Q, C_cand)
    s = jnp.where(cand >= 0, s, jnp.inf)
    neg, pos = jax.lax.top_k(-s, k)
    idx_out = jnp.take_along_axis(cand, pos, axis=1)
    idx_out = jnp.where(jnp.isfinite(-neg), idx_out, -1)
    return -neg, idx_out.astype(jnp.int32)


def embedding_bag_ref(
    table: Array, indices: Array, *, mode: str = "sum",
    weights: Optional[Array] = None,
) -> Array:
    """EmbeddingBag: reduce table rows per bag.

    Args:
      table:   (V, D) embedding table.
      indices: (B, L) int32 ids, -1 = padding.
      mode:    'sum' | 'mean' | 'max'.
      weights: optional (B, L) per-sample weights (sum/mean only).
    Returns:
      (B, D) float32.
    """
    safe = jnp.maximum(indices, 0)
    rows = table[safe].astype(jnp.float32)            # (B, L, D)
    valid = (indices >= 0)[..., None].astype(jnp.float32)
    if weights is not None:
        rows = rows * weights[..., None]
    if mode == "sum":
        return jnp.sum(rows * valid, axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1), 1.0)
        return jnp.sum(rows * valid, axis=1) / cnt
    if mode == "max":
        neg = jnp.where(valid > 0, rows, -jnp.inf)
        out = jnp.max(neg, axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode}")


def flash_attention_ref(
    q: Array, k: Array, v: Array, *, causal: bool = False,
    window: Optional[int] = None, scale: Optional[float] = None,
) -> Array:
    """Plain softmax attention. q,k,v: (B, H, S, Dh) (k/v may have Hkv heads).

    GQA: if k/v have fewer heads, they are repeated to match q.
    ``window``: optional sliding-window size (attend to [i-window+1, i]).
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", p, v, preferred_element_type=jnp.float32
    ).astype(q.dtype)


def segment_sum_ref(data: Array, segment_ids: Array, num_segments: int) -> Array:
    """Scatter-add rows of ``data`` into ``num_segments`` buckets."""
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)

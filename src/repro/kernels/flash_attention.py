"""FlashAttention-style fused attention Pallas TPU kernel.

The LM architectures in the zoo (prefill at 32k, decode against long caches)
need attention whose peak memory does not include the (S, S) score matrix.
The framework's model code uses a mathematically identical chunked
online-softmax in pure JAX (`repro.layers.attention.chunked_attention`) for
the CPU dry-run lowering; on real TPU this kernel is the drop-in replacement
(same signature, validated against `repro.kernels.ref.flash_attention_ref`).

Tiling (grid = (B·H, Sq/bq, Skv/bk), kv innermost/sequential):

    q_ref  : (1, bq, dh) VMEM     acc    : (bq, dh) f32 scratch
    k_ref  : (1, bk, dh) VMEM     m, l   : (bq, 1)  f32 scratch (running max/sum)
    v_ref  : (1, bk, dh) VMEM     out    : (1, bq, dh)

Causal and sliding-window masks are applied per-tile; tiles that are fully
masked under the causal/window pattern are skipped via ``pl.when`` (block
sparsity — this is what makes the gemma3 5:1 local:global pattern profitable
at long context).  Query positions are aligned to the *end* of kv, so the
same kernel serves prefill (sq == skv) and decode (sq << skv).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.compat import CompilerParams, MemorySpace

Array = jax.Array

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, sq: int, skv: int,
):
    iq = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, _NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    offset = skv - sq  # absolute position of q row 0

    def compute():
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # (bq, bk)
        mask = k_pos < skv  # exclude kv padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        acc[...] = acc[...] * alpha + pv
        m_i[...] = m_new

    skip = None
    if causal:
        # tile entirely above the causal diagonal (first k of tile beyond the
        # last q position of the tile) contributes nothing
        last_q_pos = (iq + 1) * bq - 1 + offset
        skip = j * bk > last_q_pos
    if window is not None:
        # tile entirely left of the window of the tile's *first* q row
        first_q_pos = iq * bq + offset
        too_old = (j + 1) * bk - 1 <= first_q_pos - window
        skip = too_old if skip is None else (skip | too_old)

    if skip is None:
        compute()
    else:
        pl.when(jnp.logical_not(skip))(compute)

    @pl.when(j == nj - 1)
    def _flush():
        o_ref[0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """Fused attention.  q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh).

    GQA handled by repeating kv heads (view-level repeat; on real TPU prefer
    reshaping q to share kv tiles across the q-head group).

    Returns (B, Hq, Sq, Dh) in q's dtype.
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / (dh ** 0.5)

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = -sq % bq, -skv % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sqp, skp = q.shape[2], k.shape[2]

    qf = q.reshape(b * hq, sqp, dh)
    kf = k.reshape(b * hq, skp, dh)
    vf = v.reshape(b * hq, skp, dh)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sq=sq, skv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sqp // bq, skp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, dh), q.dtype),
        scratch_shapes=[
            MemorySpace.VMEM((bq, dh), jnp.float32),
            MemorySpace.VMEM((bq, 1), jnp.float32),
            MemorySpace.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sqp, dh)[:, :, :sq]

"""Fused PQ ADC scan Pallas TPU kernel: LUT-resident stage 0 at M bytes/row.

The PQ stage-0 scan in XLA is a per-subspace gather chain: the (Q, M, C)
lookup tables materialize, then M (Q, N) gathered score planes are summed
and written back for ``top_k`` — all HBM round trips proportional to N.
This kernel keeps the per-query **(M, C) ADC lookup table resident in
VMEM** for the whole scan and streams only the uint8 code slabs:

* Code slabs ((block_m, M) uint8) stream HBM→VMEM via the same
  auto-double-buffered block pipeline as `ivf_scan` — M bytes per row, the
  4–8× compression step past the int8 member slabs.
* In-VMEM table lookup is a **one-hot contraction**: TPUs have no fast
  VMEM gather, but ``codes == iota(C)`` builds a (block_m, M·C) one-hot
  that contracts with the flattened LUT on the MXU — a (1, M·C) ×
  (block_m, M·C) matmul whose result IS the ADC score row.
* Padding and tombstones are masked in-kernel via the caller-masked id
  table (-1 ids score +inf), and the running top-k rides in VMEM scratch
  (reusing `distance_topk`'s sort/select merges); only the final (Q, k)
  result ever reaches HBM.

Two grid shapes share the kernel body:

* `pq_scan_topk` — **flat**: the whole (N, M) code block, chunked.  Backs
  ``QuantizedProgressiveBackend(codec='pq', use_kernel=...)``.
* `pq_ivf_scan_topk` — **list-major**: scalar-prefetched probe table
  drives dynamic BlockSpec index maps over `pack_ivf_lists(dtype='pq')`
  slabs, so IVF-PQ is one fused probe+LUT-scan program.  Backs
  ``IVFProgressiveBackend(stage0_dtype='pq')``.

Validated against `repro.kernels.ref.pq_scan_ref` / `pq_ivf_scan_ref` and
the XLA `pq_progressive_search` path in interpret mode (CPU container);
the same code targets real TPUs with ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams, MemorySpace
from repro.kernels.distance_topk import _merge_topk_select, _merge_topk_sort

Array = jax.Array


def _pq_body(lut_ref, codes_ref, ids_ref, out_s_ref, out_i_ref,
             best_s, best_i, *, k: int, merge: str):
    """Score one (block_m, M) code slab against the resident LUT."""
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    lut = lut_ref[...]                               # (1, M, C) f32
    m, c = lut.shape[1], lut.shape[2]
    codes = codes_ref[...].astype(jnp.int32)         # (bm, M)
    bm = codes.shape[0]
    # one-hot contraction: the TPU-native LUT gather. hot[r, m, c] selects
    # row r's code in subspace m; contracting (M, C) jointly against the
    # flattened LUT sums the M table entries in one MXU pass.
    hot = (codes[:, :, None]
           == jax.lax.broadcasted_iota(jnp.int32, (1, 1, c), 2))
    scores = jax.lax.dot_general(
        lut.reshape(1, m * c),
        hot.astype(jnp.float32).reshape(bm, m * c),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (1, bm)
    # -1 ids are padding or tombstoned rows: unreturnable
    scores = jnp.where(ids_ref[...] >= 0, scores, jnp.inf)

    cat_s = jnp.concatenate([best_s[...], scores], axis=1)
    cat_i = jnp.concatenate([best_i[...], ids_ref[...]], axis=1)
    if merge == "sort":
        new_s, new_i = _merge_topk_sort(cat_s, cat_i, k)
    else:
        new_s, new_i = _merge_topk_select(cat_s, cat_i, k)
    best_s[...] = new_s
    best_i[...] = new_i

    @pl.when(j == nj - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "merge", "interpret"))
def _pq_scan_call(lut, codes, ids, *, k, block_m, merge, interpret):
    nq, m, c = lut.shape
    nj = codes.shape[0] // block_m

    kern = functools.partial(_pq_body, k=k, merge=merge)
    out_s, out_i = pl.pallas_call(
        kern,
        grid=(nq, nj),
        in_specs=[
            pl.BlockSpec((1, m, c), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_m, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        scratch_shapes=[
            MemorySpace.VMEM((1, k), jnp.float32),
            MemorySpace.VMEM((1, k), jnp.int32),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lut, codes, ids)
    return out_s, out_i


def pq_scan_topk(
    lut: Array,
    codes: Array,
    ids: Array,
    *,
    k: int,
    block_m: int = 128,
    merge: str = "sort",
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Fused flat ADC scan: score every coded row, keep the best k.

    Args:
      lut:       (Q, M, C) per-query ADC tables (`repro.core.pq.pq_lut`).
      codes:     (N, M) uint8 PQ codes.
      ids:       (N,) int32 global doc ids with every unreturnable row
                 already masked to -1 (tombstones, rows past the coded
                 prefix); live rows carry their own index.
      k:         neighbours kept (static).
      merge:     'sort' | 'select' (see `distance_topk`).
      interpret: interpret mode for CPU validation.

    Returns:
      ((Q, k) float32 rank-equivalent ADC scores ascending, +inf at empty
      slots; (Q, k) int32 global doc ids, -1 at empty slots).
    """
    if merge not in ("sort", "select"):
        raise ValueError(f"merge must be sort|select, got {merge!r}")
    nq = lut.shape[0]
    if nq == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    n = codes.shape[0]
    bm = min(int(block_m), max(n, 1))
    pad = -n % bm
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        ids = jnp.pad(ids, (0, pad), constant_values=-1)
    return _pq_scan_call(
        lut.astype(jnp.float32), codes, ids[None, :].astype(jnp.int32),
        k=k, block_m=bm, merge=merge, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_len", "block_m", "merge", "interpret"))
def _pq_ivf_call(lut, probe, codes, member_ids, *, k, max_len, block_m,
                 merge, interpret):
    nq, m, c = lut.shape
    n_probe = probe.shape[1]
    nc = max_len // block_m
    nj = n_probe * nc

    def codes_idx(i, j, probe):
        return (probe[i, j // nc] * nc + j % nc, 0)

    def list_idx(i, j, probe):
        return (probe[i, j // nc], j % nc)

    body = functools.partial(_pq_body, k=k, merge=merge)

    def kern(probe_ref, *args):
        body(*args)

    out_s, out_i = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nq, nj),
            in_specs=[
                pl.BlockSpec((1, m, c), lambda i, j, probe: (i, 0, 0)),
                pl.BlockSpec((block_m, m), codes_idx),
                pl.BlockSpec((1, block_m), list_idx),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda i, j, probe: (i, 0)),
                pl.BlockSpec((1, k), lambda i, j, probe: (i, 0)),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, k), jnp.float32),
                MemorySpace.VMEM((1, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(probe, lut, codes, member_ids)
    return out_s, out_i


def pq_ivf_scan_topk(
    q: Array,
    probe: Array,
    member_ids: Array,
    pack: Dict,
    *,
    k: int,
    merge: str = "sort",
    interpret: bool = False,
    lut: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Fused IVF-PQ stage 0: probe-driven LUT scan over list-major codes.

    The list-major twin of `repro.kernels.ivf_scan.ivf_scan_topk`: same
    scalar-prefetched probe table, same double-buffered slab streaming,
    same in-VMEM top-k — but the member slabs hold PQ codes
    (`pack_ivf_lists(dtype='pq')`) and scoring is the resident-LUT one-hot
    contraction instead of a distance matmul.

    Args:
      q:          (Q, D) queries (only ``[:, :pack['dim']]`` feeds the LUT;
                  ignored when ``lut`` is given).
      probe:      (Q, n_probe) int32 probed list indices (distinct per row).
      member_ids: (n_lists, max_len) int32 global ids, every unreturnable
                  slot pre-masked to -1 (padding AND tombstones).
      pack:       `pack_ivf_lists(..., dtype='pq')` output.
      k:          neighbours kept (static).
      merge:      'sort' | 'select'.
      interpret:  interpret mode for CPU validation.
      lut:        optional precomputed (Q, M, C) ADC tables.

    Returns:
      ((Q, k) float32 ADC scores ascending, +inf empties;
       (Q, k) int32 global doc ids, -1 empties).
    """
    from repro.core.pq import pq_lut

    if merge not in ("sort", "select"):
        raise ValueError(f"merge must be sort|select, got {merge!r}")
    if pack["dtype"] != "pq":
        raise ValueError(
            f"pq_ivf_scan_topk needs a dtype='pq' pack, got "
            f"{pack['dtype']!r} (use ivf_scan_topk)")
    max_len, bm = pack["max_len"], pack["block_m"]
    if lut is None:
        d0 = pack["dim"]
        lut = pq_lut(q[:, :d0], pack["codebooks"], pack["cent_sq"])
    nq = lut.shape[0]
    if nq == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    pad = max_len - member_ids.shape[1]
    if pad:
        member_ids = jnp.pad(member_ids, ((0, 0), (0, pad)),
                             constant_values=-1)
    return _pq_ivf_call(
        lut.astype(jnp.float32), probe.astype(jnp.int32), pack["rows"],
        member_ids, k=k, max_len=max_len, block_m=bm, merge=merge,
        interpret=interpret)


def flat_stage0_bytes_model(
    *,
    n: int,
    k: int,
    row_bytes: float,
    lut_bytes: float = 0.0,
) -> Dict[str, float]:
    """Modeled per-query stage-0 HBM bytes for a *flat* coded scan.

    The full-scan twin of `repro.kernels.ivf_scan.stage0_bytes_model`, for
    the quantized backend's code-block stage 0 (int8: ``row_bytes = Ds``;
    PQ: ``row_bytes = M`` plus the ``lut_bytes`` per-query table):

      XLA   : read the code block once (``row_bytes``/row), write + re-read
              the (N,) f32 score row for ``top_k``, plus the LUT round trip
              (PQ only — XLA materializes it too).
      fused : stream the code block once, the (N,) masked id table, the
              LUT read (it stays VMEM-resident thereafter), and the (k,)
              result.
    """
    n = float(n)
    xla = row_bytes * n + 2 * 4 * n + lut_bytes
    fused = row_bytes * n + 4 * n + lut_bytes + 8 * k
    return {"xla_bytes": xla, "fused_bytes": fused,
            "ratio": fused / xla if xla else 0.0}

"""Fused candidate gather + high-dim rescore Pallas TPU kernel.

Late progressive-search stages score each query only against *its own*
surviving candidates, at a higher dimensionality.  A naive XLA lowering
materializes the gathered (Q, C, D) tensor in HBM (for the paper's workload:
2470 × 128 × 3584 × 4 B ≈ 4.5 GB written + re-read).  This kernel performs the
gather as row-granular HBM→VMEM DMAs (the database never leaves HBM whole)
and computes the distances in the same pass — the PagedAttention-style
"indirection" kernel regime adapted from KV-block lookup to ANN candidate
lookup (DESIGN.md §Hardware-adaptation).

Layout (grid = (Q,); one query per step):

    cand   : (Q, C) int32   — scalar-prefetched so DMA source addresses are
                              known before the kernel body runs
    q_ref  : (1, D)  VMEM   — the query row
    db_ref : (N, D)  ANY    — stays in HBM; rows DMA'd on demand
    buf    : (2, bc, D) VMEM scratch — double-buffered candidate slab
    out    : (1, C) float32 — rank-equivalent L2 scores

The candidate axis is processed in chunks of ``bc`` rows; chunk j+1's DMAs
are issued before chunk j's compute, overlapping gather latency with the VPU
distance math.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace

Array = jax.Array


def _kernel(cand_ref, q_ref, db_ref, out_ref, buf, sem, *, bc: int, c_total: int):
    i = pl.program_id(0)
    n_chunks = c_total // bc

    def issue(chunk, slot):
        """Start DMAs for all rows of one candidate chunk into buf[slot]."""
        def issue_row(r, _):
            idx = cand_ref[i, chunk * bc + r]
            idx = jnp.maximum(idx, 0)  # padded (-1) rows fetch row 0; masked later
            pltpu.make_async_copy(
                db_ref.at[pl.ds(idx, 1), :],
                buf.at[slot, pl.ds(r, 1), :],
                sem.at[slot],
            ).start()
            return ()

        jax.lax.fori_loop(0, bc, issue_row, ())

    def wait(slot):
        def wait_row(r, _):
            pltpu.make_async_copy(
                db_ref.at[pl.ds(0, 1), :],
                buf.at[slot, pl.ds(0, 1), :],
                sem.at[slot],
            ).wait()
            return ()

        jax.lax.fori_loop(0, bc, wait_row, ())

    issue(0, 0)
    q = q_ref[...]  # (1, D)

    def body(chunk, _):
        slot = jax.lax.rem(chunk, 2)
        nxt = jax.lax.rem(chunk + 1, 2)

        @pl.when(chunk + 1 < n_chunks)
        def _prefetch():
            issue(chunk + 1, nxt)

        wait(slot)
        rows = buf[slot]                                   # (bc, D)
        sq = jnp.sum(rows.astype(jnp.float32) ** 2, axis=-1, keepdims=True).T
        ip = jax.lax.dot_general(
            q, rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (1, bc)
        scores = sq - 2.0 * ip
        out_ref[0, pl.ds(chunk * bc, bc)] = scores[0]
        return ()

    jax.lax.fori_loop(0, n_chunks, body, ())


@functools.partial(
    jax.jit, static_argnames=("block_c", "interpret")
)
def gather_rescore(
    q: Array,
    db: Array,
    cand: Array,
    *,
    block_c: int = 16,
    interpret: bool = False,
) -> Array:
    """Score each query against its candidate rows without materializing the gather.

    Args:
      q:       (Q, D) queries.
      db:      (N, D) database (HBM-resident).
      cand:    (Q, C) int32 candidate indices, -1 = padding.
      block_c: candidate rows DMA'd per chunk (C padded to a multiple).
      interpret: interpret mode for CPU validation.

    Returns:
      (Q, C) float32 rank-equivalent scores (``||x||² − 2 q·x``), +inf at pads.
    """
    nq, d = q.shape
    c = cand.shape[1]
    pc = -c % block_c
    if pc:
        cand_p = jnp.pad(cand, ((0, 0), (0, pc)), constant_values=-1)
    else:
        cand_p = cand
    c_total = cand_p.shape[1]

    kernel = functools.partial(_kernel, bc=block_c, c_total=c_total)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nq,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, cand: (i, 0)),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            out_specs=pl.BlockSpec((1, c_total), lambda i, cand: (i, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((2, block_c, d), jnp.float32),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((nq, c_total), jnp.float32),
        interpret=interpret,
    )(cand_p, q, db)
    out = jnp.where(cand_p >= 0, out, jnp.inf)
    return out[:, :c]


def gather_rescore_topk(
    q: Array,
    db: Array,
    cand: Array,
    *,
    k: int,
    block_c: int = 16,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Convenience: fused rescore + top-k (selection outside the kernel)."""
    s = gather_rescore(q, db, cand, block_c=block_c, interpret=interpret)
    neg, pos = jax.lax.top_k(-s, k)
    return -neg, jnp.take_along_axis(cand, pos, axis=1)

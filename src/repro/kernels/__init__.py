"""Pallas TPU kernels for the compute hot-spots of progressive retrieval
and the architecture zoo, each with a pure-jnp oracle in `ref.py`.

  distance_topk   — fused L2 scores + streaming top-k (stage-0 full-DB scan)
  ivf_scan        — fused IVF probe+scan: probed lists stream HBM→VMEM once,
                    top-k in VMEM (stage 0 of the IVF backend; f32 or int8)
  gather_rescore  — DMA-gather candidates + high-dim rescore (late stages)
  embedding_bag   — fused gather + bag-reduce (recsys tables)
  flash_attention — online-softmax attention (LM prefill/decode)
  segment_sum     — sorted-CSR scatter as per-block MXU matmuls (GNN)

Use the `ops` wrappers in model code; they pick interpret mode on CPU and
fall back to the references when REPRO_NO_PALLAS=1 (dry-run lowering).
"""

from repro.kernels.ops import (
    embedding_bag_op,
    flash_attention_op,
    gather_rescore_op,
    l2_topk_op,
    use_pallas,
)

__all__ = [
    "l2_topk_op", "gather_rescore_op", "embedding_bag_op",
    "flash_attention_op", "use_pallas",
]

"""Fused IVF probe+scan Pallas TPU kernel: probed lists stay in VMEM from
gather to top-k.

The IVF stage-0 hot path in XLA is three HBM round trips: the ``lists[probe]``
gather materializes a (Q, n_probe·max_len) candidate-id table, the rescore
gathers every candidate row into a (Q, C, d0) tensor, and the (Q, C) score
matrix is written back out for ``top_k``.  All three are pure memory traffic —
exactly where the RAG surveys put the retrieval bottleneck.  This kernel
collapses them into **one streaming read of the probed lists' member rows**:

* Member vectors are re-packed *list-major* at build time
  (`pack_ivf_lists`): list ``c``'s members occupy the contiguous slab
  ``rows[c·max_len : (c+1)·max_len]`` at the stage-0 dimensionality, so one
  probed list is one contiguous HBM→VMEM block copy — no row-granular
  gather at query time.
* The probe table is **scalar-prefetched** (like `gather_rescore`'s
  candidate ids): BlockSpec index maps read ``probe[i, p]`` before the body
  runs, so Pallas's pipeline machinery double-buffers list ``p+1``'s member
  slab while list ``p`` is being scored.
* Scores are truncated-dim L2 (``‖x‖² − 2 q·x`` on the MXU, f32 accumulate)
  with padding (``-1`` ids) and tombstoned rows masked to +inf in-kernel via
  the caller-masked id table.
* A running top-k rides in VMEM scratch across the sequential
  (probe × chunk) grid axis, reusing `distance_topk`'s ``sort``/``select``
  merge strategies — only the final (Q, k) result ever reaches HBM.

An **int8 member-block variant** composes with `repro.core.quant`: member
slabs are stored as per-dimension-scaled int8 codes (4× less stage-0 HBM
traffic), the query is folded onto the same grid outside the kernel
(``q_eff = round(clip(q/s))·s²``, the `_scaled_space_scores` split), and the
packed norms are the dequantized ones — so the quantized and IVF backends
stop being either/or.

Validated against `repro.kernels.ref.ivf_scan_ref` and the XLA
`ivf_progressive_search_sched` path in interpret mode (CPU container); the
same code targets real TPUs with ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant
from repro.kernels.compat import CompilerParams, MemorySpace
from repro.kernels.distance_topk import _merge_topk_select, _merge_topk_sort

Array = jax.Array


def pack_ivf_lists(
    db: Array,
    lists: Array,
    *,
    dim: int,
    db_sq_at_dim: Optional[Array] = None,
    dtype: str = "float32",
    block_m: int = 128,
    scale: Optional[Array] = None,
    pq_codebooks: Optional[Array] = None,
) -> Dict:
    """Build the list-major member pack the fused kernel scans.

    Args:
      db:           (N, D) corpus rows (HBM snapshot at build time).
      lists:        (n_lists, max_len) int32 member table, -1 padded.
      dim:          stage-0 dimensionality; member slabs store ``[:, :dim]``.
      db_sq_at_dim: optional (N,) precomputed prefix squared norms at ``dim``
                    (the store's cached ``sq_prefix`` column) — passing it
                    keeps the pack's norms bit-identical to the XLA rescore
                    path and skips the O(N·dim) recompute.
      dtype:        'float32' | 'int8' (per-dimension symmetric codes; the
                    packed norms become the *dequantized* ones) | 'pq'
                    (product-quantization codes against ``pq_codebooks``;
                    ADC lookup needs no norm table — ``sq`` is None).
      block_m:      member rows scored per kernel step; ``max_len`` is padded
                    to a multiple.
      scale:        optional (dim,) quantization scale to reuse (int8 only) —
                    lets incremental appends code new rows onto the grid the
                    pack was built with.
      pq_codebooks: (M, C, dim//M) PQ codebooks ('pq' only, required) —
                    trained by the caller on live rows (`repro.core.pq`);
                    stored in the pack so incremental appends encode against
                    the same frozen codebooks.

    Returns:
      dict: ``rows`` (n_lists·max_len_p, dim-or-M) member slabs, ``sq``
      (n_lists, max_len_p) f32 norms (+inf at pads; None for 'pq'),
      ``scale`` (dim,) f32 or None, ``codebooks``/``cent_sq`` ('pq' only),
      plus static meta (``dim``, ``max_len``, ``block_m``, ``dtype``).
    """
    if dtype not in ("float32", "int8", "pq"):
        raise ValueError(f"pack dtype must be float32|int8|pq, got {dtype!r}")
    if dtype == "pq" and pq_codebooks is None:
        raise ValueError("dtype='pq' needs pq_codebooks (see repro.core.pq)")
    n_lists, max_len = lists.shape
    bm = min(int(block_m), max(int(max_len), 1))
    pad = -max_len % bm
    if pad:
        lists = jnp.pad(lists, ((0, 0), (0, pad)), constant_values=-1)
        max_len = max_len + pad
    flat = lists.reshape(-1)
    safe = jnp.maximum(flat, 0)
    rows = db[safe, :dim].astype(jnp.float32)          # (n_lists*max_len, dim)
    member = flat >= 0

    codebooks = cent_sq = None
    if dtype == "int8":
        if scale is None:
            # fit the grid on real member rows only (pad slots repeat row 0)
            scale = quant.fit_int8_scale(rows, member)
        rows, sq = quant.int8_encode(rows, scale)
        sq = jnp.where(member, sq, jnp.inf).reshape(n_lists, max_len)
    elif dtype == "pq":
        from repro.core.pq import pq_cent_sq, pq_encode
        scale, sq = None, None
        codebooks = pq_codebooks
        cent_sq = pq_cent_sq(codebooks)
        rows = pq_encode(rows, codebooks)              # (n_lists*max_len, M)
    else:
        scale = None
        if db_sq_at_dim is not None:
            sq = db_sq_at_dim[safe].astype(jnp.float32)
        else:
            sq = jnp.sum(rows * rows, axis=-1)
        sq = jnp.where(member, sq, jnp.inf).reshape(n_lists, max_len)
    return {
        "rows": rows,
        "sq": sq,
        "scale": scale,
        "codebooks": codebooks,
        "cent_sq": cent_sq,
        "dim": int(dim),
        "max_len": int(max_len),
        "block_m": int(bm),
        "dtype": dtype,
    }


# host-side scatter-batch padding shared with the incremental-append paths
_pad_pow2 = quant.pad_pow2


def update_pack(pack: Dict, db: Array, ids, dests) -> Dict:
    """Write appended rows into the pack's member slabs (incremental IVF).

    ``ids`` are global doc ids, ``dests`` their flat slab positions
    (``list·max_len + slot``).  Returns a new pack dict; int8 packs code
    the new rows with the **stored** scale and 'pq' packs encode against
    the **stored** codebooks, so the grid stays consistent with the built
    slabs.  The scatters are `repro.core.quant.scatter_rows*`: slab
    buffers are donated off-CPU, so XLA updates them in place instead of
    copying the whole O(n_lists·max_len·dim) slab.
    """
    ids = _pad_pow2(np.asarray(ids, np.int32))
    dests = jnp.asarray(_pad_pow2(np.asarray(dests, np.int32)))
    rows = db[jnp.asarray(ids), : pack["dim"]].astype(jnp.float32)
    out = dict(pack)
    if pack["dtype"] == "pq":
        from repro.core.pq import pq_encode
        codes = pq_encode(rows, pack["codebooks"])
        out["rows"] = quant.scatter_rows(pack["rows"], dests, codes)
        return out
    if pack["dtype"] == "int8":
        rows, sq = quant.int8_encode(rows, pack["scale"])
    else:
        sq = jnp.sum(rows * rows, axis=-1)
    new_rows, new_sq = quant.scatter_rows2(
        pack["rows"], pack["sq"].reshape(-1), dests, rows, sq)
    out["rows"] = new_rows
    out["sq"] = new_sq.reshape(pack["sq"].shape)
    return out


def _kernel(
    probe_ref, q_ref, rows_ref, sq_ref, ids_ref, out_s_ref, out_i_ref,
    best_s, best_i, *, k: int, merge: str, cast: str,
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    q = q_ref[...]                                     # (1, d0) f32
    rows = rows_ref[...]                               # (bm, d0)
    # int8 slabs matmul through bf16 (the int8 path of core.quant); f32
    # slabs pass through untouched
    rows = rows.astype(jnp.bfloat16 if cast == "int8" else jnp.float32)
    ip = jax.lax.dot_general(
        q, rows, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (1, bm)
    scores = sq_ref[...] - 2.0 * ip
    # -1 ids are list padding or tombstoned rows: unreturnable
    scores = jnp.where(ids_ref[...] >= 0, scores, jnp.inf)

    cat_s = jnp.concatenate([best_s[...], scores], axis=1)
    cat_i = jnp.concatenate([best_i[...], ids_ref[...]], axis=1)
    if merge == "sort":
        new_s, new_i = _merge_topk_sort(cat_s, cat_i, k)
    else:
        new_s, new_i = _merge_topk_select(cat_s, cat_i, k)
    best_s[...] = new_s
    best_i[...] = new_i

    @pl.when(j == nj - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "dim", "max_len", "block_m", "dtype", "merge",
                     "interpret"),
)
def _ivf_scan_call(
    q, probe, rows, sq, member_ids, *, k, dim, max_len, block_m, dtype,
    merge, interpret,
):
    nq = q.shape[0]
    n_probe = probe.shape[1]
    nc = max_len // block_m
    nj = n_probe * nc

    def rows_idx(i, j, probe):
        return (probe[i, j // nc] * nc + j % nc, 0)

    def list_idx(i, j, probe):
        return (probe[i, j // nc], j % nc)

    kern = functools.partial(_kernel, k=k, merge=merge, cast=dtype)
    out_s, out_i = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nq, nj),
            in_specs=[
                pl.BlockSpec((1, dim), lambda i, j, probe: (i, 0)),
                pl.BlockSpec((block_m, dim), rows_idx),
                pl.BlockSpec((1, block_m), list_idx),
                pl.BlockSpec((1, block_m), list_idx),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda i, j, probe: (i, 0)),
                pl.BlockSpec((1, k), lambda i, j, probe: (i, 0)),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, k), jnp.float32),
                MemorySpace.VMEM((1, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(probe, q, rows, sq, member_ids)
    return out_s, out_i


def ivf_scan_topk(
    q: Array,
    probe: Array,
    member_ids: Array,
    pack: Dict,
    *,
    k: int,
    merge: str = "sort",
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Fused stage-0 scan: score every probed list's members, keep the best k.

    Args:
      q:          (Q, D) queries (only ``[:, :pack['dim']]`` is scored).
      probe:      (Q, n_probe) int32 — per-query probed list indices, all in
                  ``[0, n_lists)`` and **distinct within a row** (duplicated
                  probes would double-count their members in the top-k).
      member_ids: (n_lists, max_len) int32 global doc ids with every
                  unreturnable slot already masked to -1 (list padding AND
                  tombstoned rows — mask with the live validity bits before
                  calling; the packed member *vectors* are a build-time
                  snapshot and are not consulted for liveness).
      pack:       `pack_ivf_lists` output (member slabs at stage-0 dim).
      k:          neighbours kept (static).
      merge:      'sort' | 'select' (see `distance_topk`).
      interpret:  interpret mode for CPU validation.

    Returns:
      ((Q, k) float32 rank-equivalent L2 scores ascending, +inf at empty
      slots; (Q, k) int32 global doc ids, -1 at empty slots).
    """
    if merge not in ("sort", "select"):
        raise ValueError(f"merge must be sort|select, got {merge!r}")
    if pack["dtype"] == "pq":
        raise ValueError(
            "pq packs are scanned by repro.kernels.pq_scan.pq_ivf_scan_topk "
            "(ADC lookup-table scoring, not a distance matmul)")
    d0, max_len, bm = pack["dim"], pack["max_len"], pack["block_m"]
    nq = q.shape[0]
    if nq == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    qd = q[:, :d0].astype(jnp.float32)
    if pack["dtype"] == "int8":
        # fold the query onto the codes' grid outside the kernel: int32-ish
        # inner products rescaled per-dim by s², db side stays int8
        qd = quant.fold_int8_query(qd, pack["scale"])
    pad = max_len - member_ids.shape[1]
    if pad:
        member_ids = jnp.pad(member_ids, ((0, 0), (0, pad)),
                             constant_values=-1)
    return _ivf_scan_call(
        qd, probe.astype(jnp.int32), pack["rows"], pack["sq"], member_ids,
        k=k, dim=d0, max_len=max_len, block_m=bm, dtype=pack["dtype"],
        merge=merge, interpret=interpret,
    )


def stage0_bytes_model(
    *,
    n_lists: int,
    max_len: int,
    n_probe: int,
    d0: int,
    k: int,
    member_bytes: int = 4,
    row_bytes: Optional[float] = None,
    lut_bytes: float = 0.0,
    norms: bool = True,
) -> Dict[str, float]:
    """Modeled per-query stage-0 HBM bytes: fused kernel vs the XLA lowering.

    Both paths share the probe matmul (centroid read, amortized across the
    batch) so it is excluded; the model counts the candidate-dependent terms
    with C = n_probe · max_len:

      XLA   : write + re-read the (C,) id table (top_k gather feeds from it),
              read C member rows (4 B/dim f32), write + re-read the gathered
              (C, d0) tensor (XLA materializes it for the einsum), and
              write + re-read the (C,) f32 score row for top_k.
      fused : stream C member rows once (``member_bytes``/dim, or
              ``row_bytes`` per row when the slab width is decoupled from
              d0 — PQ codes are M bytes/row regardless of d0), plus the
              (C,) id table, the norm side table (``norms=False`` for ADC
              scoring, which needs none), the per-query lookup table
              (``lut_bytes``, PQ only), and the (k,) result.

    The fused path models strictly fewer bytes for every d0 ≥ 1 — the
    acceptance check `benchmarks/backend_comparison.py --ivf-kernel` records.
    """
    c = float(n_probe * max_len)
    xla = (
        2 * 4 * c            # candidate-id table: write + read back
        + 4 * c * d0         # gather reads member rows (f32)
        + 2 * 4 * c * d0     # materialized (C, d0) gather: write + re-read
        + 2 * 4 * c          # (C,) score row: write + read for top_k
    )
    per_row = member_bytes * d0 if row_bytes is None else row_bytes
    fused = (
        per_row * c             # one streaming read of member slabs
        + 4 * c                 # masked id table
        + (4 * c if norms else 0.0)   # packed norms (ADC needs none)
        + lut_bytes             # per-query LUT read (stays VMEM-resident)
        + 8 * k                 # (k,) scores + ids out
    )
    return {"xla_bytes": xla, "fused_bytes": fused,
            "ratio": fused / xla if xla else 0.0}

"""Backend-aware entry points for the Pallas kernels.

Each ``*_op`` dispatches to the Pallas kernel with ``interpret=True`` on CPU
(validation / this container) and ``interpret=False`` on TPU (production).
Model code should call these, never the kernels directly, so the same model
definition lowers everywhere.

``use_pallas(False)`` (or REPRO_NO_PALLAS=1) falls back to the pure-jnp
reference implementations — this is what the multi-pod dry-run uses, since
the roofline terms must reflect the XLA program a real run would execute,
not interpret-mode scaffolding.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.distance_topk import l2_topk as _l2_topk
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.gather_rescore import gather_rescore as _gather_rescore

Array = jax.Array

_FORCE_REF = os.environ.get("REPRO_NO_PALLAS", "0") == "1"
_ENABLED = not _FORCE_REF


def use_pallas(enabled: bool) -> None:
    """Globally enable/disable Pallas kernels (ref fallback when disabled)."""
    global _ENABLED
    _ENABLED = enabled and not _FORCE_REF


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def l2_topk_op(
    q: Array, db: Array, *, k: int, db_sq: Optional[Array] = None, **kw
) -> Tuple[Array, Array]:
    if not _ENABLED:
        return _ref.l2_topk_ref(q, db, k, db_sq)
    return _l2_topk(q, db, k=k, db_sq=db_sq, interpret=_interpret(), **kw)


def gather_rescore_op(q: Array, db: Array, cand: Array, **kw) -> Array:
    if not _ENABLED:
        return _ref.gather_rescore_ref(q, db, cand)
    return _gather_rescore(q, db, cand, interpret=_interpret(), **kw)


def embedding_bag_op(table: Array, indices: Array, *, mode: str = "sum", **kw) -> Array:
    if not _ENABLED or mode == "max":
        return _ref.embedding_bag_ref(table, indices, mode=mode)
    return _embedding_bag(table, indices, mode=mode, interpret=_interpret(), **kw)


def flash_attention_op(
    q: Array, k: Array, v: Array, *, causal: bool = False,
    window: Optional[int] = None, **kw
) -> Array:
    if not _ENABLED:
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_attention(
        q, k, v, causal=causal, window=window, interpret=_interpret(), **kw
    )


def segment_sum_op(data: Array, seg_ids: Array, *, num_segments: int,
                   block_n: int = 128, **kw) -> Array:
    """Segment-sum with the sorted-CSR Pallas kernel (GNN scatter hot path).

    Accepts *unsorted* (data, seg_ids) with -1 padding: sorts by segment,
    builds the CSR indptr, pads, and calls `sorted_segment_sum`.
    """
    if not _ENABLED:
        return _ref.segment_sum_ref(
            jnp.where((seg_ids >= 0)[:, None], data, 0),
            jnp.maximum(seg_ids, 0), num_segments)
    from repro.kernels.segment_sum import sorted_segment_sum
    e, d = data.shape
    seg = jnp.where(seg_ids >= 0, seg_ids, num_segments).astype(jnp.int32)
    order = jnp.argsort(seg)
    data_s = data[order]
    seg_s = seg[order]
    n_pad = -num_segments % block_n
    n_total = num_segments + n_pad
    indptr = jnp.searchsorted(seg_s, jnp.arange(n_total + 1)).astype(jnp.int32)
    # tail padding so chunked DMA may read past the last valid edge
    ec = kw.get("edge_chunk", 256)
    data_s = jnp.pad(data_s, ((0, ec), (0, 0)))
    seg_s = jnp.pad(seg_s, (0, ec), constant_values=n_total)
    out = sorted_segment_sum(
        data_s, seg_s, indptr, num_segments=n_total, block_n=block_n,
        interpret=_interpret(), **kw)
    return out[:num_segments]

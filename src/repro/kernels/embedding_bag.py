"""EmbeddingBag Pallas TPU kernel — the recsys hot path.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse; the framework-level
implementation (``repro.models.recsys.embedding_bag``) is ``jnp.take`` +
``segment_sum``.  That lowering materializes the gathered (B, L, D) tensor in
HBM before reducing — for a DLRM batch of 65536 × 26 fields that is the
dominant memory term.  This kernel fuses gather + bag-reduce: table rows are
DMA'd HBM→VMEM per bag and accumulated in registers, so HBM traffic is one
row-read per index plus one (B, D) result write.

Layout (grid = (B // block_b,)):

    indices : (B, L) int32  — scalar-prefetched (DMA addresses)
    table   : (V, D) ANY    — stays in HBM
    out     : (block_b, D) VMEM
    buf     : (2, D) VMEM   — double-buffered row landing slot

Supports 'sum' and 'mean' over fixed-size bags with -1 padding (multi-hot
fields padded to L — the standard TPU-friendly recsys batch layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace

Array = jax.Array


def _kernel(idx_ref, table_ref, out_ref, buf, sem, *, block_b: int, bag: int, mode: str):
    g = pl.program_id(0)

    def row_copy(idx, slot):
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(jnp.maximum(idx, 0), 1), :],
            buf.at[pl.ds(slot, 1), :],
            sem.at[slot],
        )

    def bag_body(b, _):
        row = g * block_b + b
        first = idx_ref[row, 0]
        row_copy(first, 0).start()

        def acc_body(l, carry):
            acc, cnt = carry
            slot = jax.lax.rem(l, 2)
            nxt = jax.lax.rem(l + 1, 2)

            @pl.when(l + 1 < bag)
            def _prefetch():
                row_copy(idx_ref[row, l + 1], nxt).start()

            row_copy(idx_ref[row, l], slot).wait()
            valid = (idx_ref[row, l] >= 0).astype(jnp.float32)
            acc = acc + valid * buf[slot].astype(jnp.float32)
            cnt = cnt + valid
            return acc, cnt

        acc0 = jnp.zeros_like(buf[0], dtype=jnp.float32)
        acc, cnt = jax.lax.fori_loop(0, bag, acc_body, (acc0, 0.0))
        if mode == "mean":
            acc = acc / jnp.maximum(cnt, 1.0)
        out_ref[b, :] = acc
        return ()

    jax.lax.fori_loop(0, block_b, bag_body, ())


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "interpret")
)
def embedding_bag(
    table: Array,
    indices: Array,
    *,
    mode: str = "sum",
    block_b: int = 8,
    interpret: bool = False,
) -> Array:
    """Fused gather + per-bag reduce over an HBM-resident embedding table.

    Args:
      table:   (V, D) embedding table.
      indices: (B, L) int32 ids per bag, -1 = padding.
      mode:    'sum' | 'mean'.
      block_b: bags per grid step.
      interpret: interpret mode for CPU validation.

    Returns:
      (B, D) float32 bag embeddings.
    """
    if mode not in ("sum", "mean"):
        raise ValueError(f"kernel supports sum|mean, got {mode}")
    b, bag = indices.shape
    v, d = table.shape
    pb = -b % block_b
    idx_p = jnp.pad(indices, ((0, pb), (0, 0)), constant_values=-1) if pb else indices
    bp = idx_p.shape[0]

    kernel = functools.partial(_kernel, block_b=block_b, bag=bag, mode=mode)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bp // block_b,),
            in_specs=[pl.BlockSpec(memory_space=MemorySpace.ANY)],
            out_specs=pl.BlockSpec((block_b, d), lambda g, idx: (g, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((2, d), jnp.float32),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bp, d), jnp.float32),
        interpret=interpret,
    )(idx_p, table)
    return out[:b]

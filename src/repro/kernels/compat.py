"""Pallas-TPU API compatibility: jax >= 0.5 renamed ``TPUMemorySpace`` ->
``MemorySpace`` and ``TPUCompilerParams`` -> ``CompilerParams``.

Kernels import the names from here so the same code runs on the new API and
on jax 0.4.x (where the enum members are callable the same way:
``MemorySpace.VMEM(shape, dtype)`` builds a scratch MemoryRef).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["MemorySpace", "CompilerParams"]

"""Sorted segment-sum Pallas TPU kernel — the GNN message-passing scatter.

``jax.ops.segment_sum`` lowers to HLO scatter-add: on TPU that serializes
per-row updates through HBM.  With edges *sorted by receiver* the reduction
becomes block-local: the edges of node block [n0, n0+bn) occupy one
contiguous range [indptr[n0], indptr[n0+bn]) of the sorted message array, so
the kernel can stream that range through VMEM and reduce each chunk with a
single MXU matmul:

    out_block += onehot(seg_chunk - n0)ᵀ @ msg_chunk     # (bn,ec)x(ec,D)

Layout (grid = (N/bn,), indptr scalar-prefetched):

    data    : (E, D) ANY  — messages sorted by segment id (HBM-resident)
    seg     : (E, 1) ANY  — sorted segment ids
    indptr  : (N+1,) SMEM — CSR row pointers (scalar prefetch)
    out     : (bn, D) VMEM
    scratch : msg chunk (ec, D) + seg chunk (ec, 1), double-buffered

Padded edges carry segment id >= N and sit at the tail of the sorted order,
beyond indptr[N] — never touched.  The `ops.segment_sum_op` wrapper sorts
unsorted inputs and builds indptr; `ref.segment_sum_ref` is the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace

Array = jax.Array


def _kernel(indptr_ref, data_ref, seg_ref, out_ref, buf_d, buf_s, sem,
            *, bn: int, ec: int, d: int):
    g = pl.program_id(0)
    n0 = g * bn
    e_start = indptr_ref[n0]
    e_end = indptr_ref[n0 + bn]
    n_chunks = pl.cdiv(e_end - e_start, ec)

    def copies(chunk, slot):
        e = e_start + chunk * ec
        cp_d = pltpu.make_async_copy(
            data_ref.at[pl.ds(e, ec), :], buf_d.at[slot], sem.at[slot, 0])
        cp_s = pltpu.make_async_copy(
            seg_ref.at[pl.ds(e, ec), :], buf_s.at[slot], sem.at[slot, 1])
        return cp_d, cp_s

    @pl.when(n_chunks > 0)
    def _run():
        for c in copies(0, 0):
            c.start()

        def body(chunk, acc):
            slot = jax.lax.rem(chunk, 2)
            nxt = jax.lax.rem(chunk + 1, 2)

            @pl.when(chunk + 1 < n_chunks)
            def _prefetch():
                for c in copies(chunk + 1, nxt):
                    c.start()

            for c in copies(chunk, slot):
                c.wait()
            msg = buf_d[slot]                              # (ec, D)
            seg = buf_s[slot][:, 0]                        # (ec,)
            # mask rows past this block's edge range (tail chunk overlap)
            e = e_start + chunk * ec
            valid = (jax.lax.broadcasted_iota(jnp.int32, (ec,), 0) + e) < e_end
            local = seg - n0
            onehot = (
                (jax.lax.broadcasted_iota(jnp.int32, (bn, ec), 0)
                 == local[None, :])
                & valid[None, :]
            ).astype(jnp.float32)
            acc = acc + jax.lax.dot_general(
                onehot, msg.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)        # (bn, D)
            return acc

        acc = jax.lax.fori_loop(
            0, n_chunks, body, jnp.zeros((bn, d), jnp.float32))
        out_ref[...] = acc

    @pl.when(n_chunks <= 0)
    def _zero():
        out_ref[...] = jnp.zeros((bn, d), jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "edge_chunk",
                              "interpret"))
def sorted_segment_sum(
    data: Array,
    seg_ids: Array,
    indptr: Array,
    *,
    num_segments: int,
    block_n: int = 128,
    edge_chunk: int = 256,
    interpret: bool = False,
) -> Array:
    """Segment-sum of ``data`` rows, pre-sorted by ``seg_ids``.

    Args:
      data:     (E, D) messages sorted ascending by segment id.  E must allow
                reading ``edge_chunk`` rows past any block boundary (the ops
                wrapper pads the tail; reads are masked).
      seg_ids:  (E,) int32 sorted segment ids (>= num_segments = padding).
      indptr:   (num_segments + 1,) int32 CSR pointers into the sorted order.
      num_segments: output rows (padded to block_n by the wrapper).

    Returns:
      (num_segments, D) float32 sums.
    """
    e, d = data.shape
    assert num_segments % block_n == 0, (num_segments, block_n)
    kernel = functools.partial(_kernel, bn=block_n, ec=edge_chunk, d=d)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_segments // block_n,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            out_specs=pl.BlockSpec((block_n, d), lambda g, ip: (g, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((2, edge_chunk, d), data.dtype),
                MemorySpace.VMEM((2, edge_chunk, 1), jnp.int32),
                pltpu.SemaphoreType.DMA((2, 2)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(indptr, data, seg_ids[:, None].astype(jnp.int32))
    return out

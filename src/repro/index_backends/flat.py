"""Flat progressive backend — the engine's original search path, extracted.

No build artifact beyond the store's own buffers (the prefix-norm table is
maintained incrementally by ``DocStore.add``), so the state is a bare
snapshot record: builds are free, nothing ever goes stale, and every row is
covered the moment it lands in the buffer.  This is the exactness baseline
the approximate backends are benchmarked against.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core import progressive_search
from repro.core.progressive import rescore_ladder_jit
from repro.index_backends.base import (
    IndexBackend,
    IndexState,
    StoreStats,
    register_backend,
)

Array = jax.Array


@register_backend
class FlatProgressiveBackend(IndexBackend):
    """Stage-0 full scan at truncated dims + progressive rescore (paper §III.D)."""

    name = "flat"

    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        return IndexState.from_stats(self.name, stats,
                                     shape_key=(self.name,))

    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        overrides=None,
    ) -> Tuple[Array, Array]:
        # adaptive degradation: swap in the shallower schedule (higher
        # stage-0 truncation error, same final_k → same result width);
        # its stage dims are present in self.dims, so sq-prefix lookups
        # stay precomputed
        sched = self.sched if overrides is None or overrides.sched is None \
            else overrides.sched
        scores, ids = progressive_search(
            q, db, sched,
            sq_prefix=sq_prefix,
            index_dims=self.dims,
            valid=valid,
            block_n=min(self.block_n, db.shape[0]),
            metric=self.metric,
        )
        # scores ascend; the leading k columns are the top results (only a
        # single-stage schedule is wider than the engine's out_k)
        return scores[:, :k], ids[:, :k]

    def search_fenced(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        fence,
        overrides=None,
    ) -> Tuple[Array, Array]:
        sched = self.sched if overrides is None or overrides.sched is None \
            else overrides.sched
        scores, cand = progressive_search(
            q, db, sched,
            sq_prefix=sq_prefix,
            index_dims=self.dims,
            valid=valid,
            block_n=min(self.block_n, db.shape[0]),
            metric=self.metric,
            stage0_only=True,
        )
        fence((scores, cand))
        scores, ids = rescore_ladder_jit(
            q, db, cand, sched.stages[1:],
            sq_prefix=sq_prefix, index_dims=self.dims,
            valid=valid, metric=self.metric, scores=scores,
        )
        return scores[:, :k], ids[:, :k]

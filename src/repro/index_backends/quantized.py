"""Quantized-progressive backend: int8 stage-0 scan, full-precision rescore.

The stage-0 scan still touches every row, but reads 1 byte per dimension
instead of 4 — the paper's "cheap sketch" idea applied to precision instead
of (and composed with) dimensionality.  The int8 code block is a build
artifact: rows appended later aren't coded yet, so stage-0 ranking is
limited to ``[0, built_size)`` (a ``row_limit`` mask) and appended rows ride
the tail window into the full-precision rescore, exactly like the IVF
backend.  The per-dimension scale is fit on live rows at build time;
distribution drift from churn is a quality (not correctness) concern —
the rescore ladder runs at full precision either way — and is what
``needs_rebuild``'s churn budget bounds.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import build_quantized_index, quantized_progressive_search
from repro.index_backends.base import (
    ChurnRebuildBackend,
    IndexState,
    StoreStats,
    register_backend,
    tail_ids,
)

Array = jax.Array


@register_backend
class QuantizedProgressiveBackend(ChurnRebuildBackend):
    """int8 stage-0 block scan + exact progressive rescore."""

    name = "quantized"

    def __init__(
        self,
        sched,
        *,
        metric: str = "l2",
        block_n: int = 65536,
        rebuild_frac: float = 0.25,
        min_rebuild_rows: int = 64,
        tail_window: int = 512,
    ):
        super().__init__(
            sched, metric=metric, block_n=block_n,
            rebuild_frac=rebuild_frac, min_rebuild_rows=min_rebuild_rows,
            tail_window=tail_window,
        )
        if metric != "l2":
            raise ValueError(
                "QuantizedProgressiveBackend supports metric='l2' only "
                "(the int8 stage-0 scores are rank-equivalent L2 distances)"
            )

    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        # Code the whole buffer (static shape = capacity); the scale is fit
        # on live rows only, and dead/unpopulated rows are masked at search.
        idx = build_quantized_index(db, self.sched, valid=valid)
        tail_cap = self._tail_cap(stats.n_active)
        return IndexState.from_stats(
            self.name, stats,
            shape_key=(self.name, int(idx["db0_q"].shape[0]), tail_cap),
            data={"idx": idx, "tail_cap": tail_cap},
        )

    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
    ) -> Tuple[Array, Array]:
        idx = state.data["idx"]
        tail = tail_ids(state, n_total, state.data["tail_cap"])
        n_coded = idx["db0_q"].shape[0]
        scores, ids = quantized_progressive_search(
            q, idx, self.sched,
            metric=self.metric,
            db=db,                       # rescore against the LIVE buffer
            valid=valid,
            # rows appended after the build have no codes: keep them out of
            # stage-0 ranking, reachable via the tail injection instead
            row_limit=jnp.asarray(min(state.built_size, n_coded)),
            extra_cand=jnp.asarray(tail),
        )
        return scores[:, :k], ids[:, :k]

    def describe(self) -> str:
        return (
            f"QuantizedProgressiveBackend(rebuild_frac={self.rebuild_frac}, "
            f"metric={self.metric})"
        )

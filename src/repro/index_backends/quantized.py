"""Quantized-progressive backend: coded stage-0 scan, full-precision rescore.

The stage-0 scan still touches every row, but reads a compressed sketch —
the paper's "cheap sketch" idea applied to precision instead of (and
composed with) dimensionality.  Two codecs share the backend:

* ``codec='int8'`` — per-dimension symmetric int8 codes: 1 byte/dim, 4x
  less stage-0 HBM traffic than f32 (`repro.core.quant`).
* ``codec='pq'``  — product-quantization codes: ``pq_m`` uint8 codes/row
  against per-subspace k-means codebooks, scored by ADC lookup tables
  (`repro.core.pq`) — 4–8x less traffic than int8 again.  With
  ``use_kernel`` the scan runs the fused Pallas LUT kernel
  (`repro.kernels.pq_scan`): the per-query (M, C) table stays VMEM-resident
  while code slabs stream HBM→VMEM once.

**Churn-aware maintenance.**  The code block is a build artifact, but the
grid it is coded on (int8 scale / PQ codebooks) is *frozen* between
rebuilds: rows appended later are encoded against the frozen grid at
engine safe points (``absorb_appends``) and scattered into the code
block in place, so append-heavy workloads stop forcing early rebuilds —
only rows past the block's capacity ride the tail window.  Codebooks and
scales are refit at the next rebuild safe point, which is also when
distribution drift from churn is absorbed; drift is a quality (not
correctness) concern — the rescore ladder runs at full precision either
way — and is what ``needs_rebuild``'s churn budget bounds.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.progressive import rescore_ladder_jit
from repro.core.quant import (
    build_quantized_index,
    int8_encode,
    pad_pow2,
    quant_rest_stages,
    quantized_progressive_search,
    scatter_rows,
    scatter_rows2,
)
from repro.index_backends.base import (
    ChurnRebuildBackend,
    IndexState,
    StoreStats,
    register_backend,
)

Array = jax.Array


@register_backend
class QuantizedProgressiveBackend(ChurnRebuildBackend):
    """Coded stage-0 block scan + exact progressive rescore."""

    name = "quantized"

    def __init__(
        self,
        sched,
        *,
        metric: str = "l2",
        block_n: int = 65536,
        rebuild_frac: float = 0.25,
        min_rebuild_rows: int = 64,
        tail_window: int = 512,
        codec: str = "int8",
        pq_m: Optional[int] = None,
        pq_codes: int = 256,
        pq_iters: int = 10,
        pq_train_rows: int = 65536,
        pq_oversample: int = 4,
        encode_appends: bool = True,
        use_kernel="auto",
        kernel_block_m: int = 128,
        kernel_merge: str = "sort",
        seed: int = 0,
    ):
        """Args beyond the shared churn config:

        codec:          'int8' (per-dim symmetric codes) | 'pq' (product
                        quantization: pq_m uint8 codes/row + ADC tables).
        pq_m:           'pq' only: subspaces per stage-0 row (None: aim
                        8-dim subspaces — `repro.core.pq.auto_pq_m`); must
                        divide the stage-0 dim.
        pq_codes:       'pq' only: centroids per subspace (<= 256).
        pq_iters:       'pq' only: k-means iterations per subspace.
        pq_train_rows:  'pq' only: codebooks train on at most this many
                        sampled live rows.
        pq_oversample:  'pq' only: stage-0 survivor pool widens to
                        ``pq_oversample × k0`` — ADC ranking noise is
                        absorbed by the full-precision rescore, which cuts
                        the pool back (the classic IVF-PQ re-rank trick).
        encode_appends: encode appended rows against the frozen grid at
                        engine safe points (in-place code-block scatter)
                        instead of riding the tail window; False restores
                        pure tail-window behavior.
        use_kernel:     'pq' only: 'auto' | True | False — stage-0 via the
                        fused Pallas ADC LUT kernel ('auto': TPU only;
                        True forces it, interpret mode off-TPU; False: the
                        XLA ADC reference).  int8 stage 0 is a plain
                        matmul — XLA already lowers it well.
        kernel_block_m / kernel_merge: kernel step rows / top-k merge.
        """
        super().__init__(
            sched, metric=metric, block_n=block_n,
            rebuild_frac=rebuild_frac, min_rebuild_rows=min_rebuild_rows,
            tail_window=tail_window,
        )
        if metric != "l2":
            raise ValueError(
                "QuantizedProgressiveBackend supports metric='l2' only "
                "(coded stage-0 scores are rank-equivalent L2 distances)"
            )
        if codec not in ("int8", "pq"):
            raise ValueError(f"codec must be int8|pq, got {codec!r}")
        if use_kernel not in ("auto", True, False):
            raise ValueError(
                f"use_kernel must be 'auto'|True|False, got {use_kernel!r}")
        if use_kernel is True and codec != "pq":
            raise ValueError(
                "use_kernel applies to codec='pq' (the fused ADC LUT "
                "kernel); the int8 stage 0 is already a plain XLA matmul")
        self.codec = codec
        self.pq_codes = int(pq_codes)
        self.pq_iters = int(pq_iters)
        self.pq_train_rows = int(pq_train_rows)
        self.pq_oversample = max(1, int(pq_oversample))
        self.encode_appends = bool(encode_appends)
        self.use_kernel = use_kernel
        self.kernel_block_m = int(kernel_block_m)
        self.kernel_merge = kernel_merge
        self.seed = int(seed)
        s0_dim = sched.stages[0].dim
        if codec == "pq":
            from repro.core.pq import auto_pq_m
            self.pq_m = int(pq_m) if pq_m else auto_pq_m(s0_dim)
            if s0_dim % self.pq_m:
                raise ValueError(
                    f"pq_m={self.pq_m} does not divide the stage-0 dim "
                    f"{s0_dim}")
        else:
            self.pq_m = pq_m

    def _kernel_enabled(self) -> bool:
        if self.codec != "pq" or self.use_kernel is False:
            return False
        if self.use_kernel is True:
            return True
        return jax.default_backend() == "tpu"

    @staticmethod
    def _interpret() -> bool:
        return jax.default_backend() != "tpu"

    # -- build ---------------------------------------------------------------
    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        # Code the whole buffer (static shape = capacity); the grid is fit
        # on live rows only, and dead/unpopulated rows are masked at search.
        if self.codec == "pq":
            from repro.core.pq import build_pq_index
            idx = build_pq_index(
                db, self.sched, m=self.pq_m, n_codes=self.pq_codes,
                n_iter=self.pq_iters, train_rows=self.pq_train_rows,
                valid=valid, seed=self.seed)
            n_coded = int(idx["codes"].shape[0])
        else:
            idx = build_quantized_index(db, self.sched, valid=valid)
            n_coded = int(idx["db0_q"].shape[0])
        tail_cap = self._tail_cap(stats.n_active)
        return IndexState.from_stats(
            self.name, stats,
            shape_key=(self.name, self.codec, n_coded, tail_cap,
                       self._kernel_enabled()),
            data={
                "idx": idx,
                "tail_cap": tail_cap,
                "codec": self.codec,
                # rows [0, coded_upto) carry codes on the state's frozen
                # grid: the built prefix, extended in place by
                # absorb_appends up to the block's capacity
                "coded_upto": min(stats.size, n_coded),
                "n_coded": n_coded,
            },
        )

    # -- incremental maintenance ----------------------------------------------
    def _tail_load(self, state: IndexState, stats: StoreStats) -> int:
        return stats.size - state.data["coded_upto"]

    def absorb_appends(
        self,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> None:
        """Encode appended rows against the state's frozen grid, in place.

        Runs between rebuilds at engine safe points: rows in
        ``[coded_upto, n_total)`` that still fit the code block are encoded
        with the build-time scale/codebooks and scattered into it — the
        grid refit waits for the next rebuild.  Rows past the block's
        capacity (the store grew) ride the tail window until then.
        Mutates ``state.data`` in place; every traced shape is preserved.
        """
        if not self.encode_appends:
            return
        upto = state.data["coded_upto"]
        n_new = min(stats.size, state.data["n_coded"]) - upto
        if n_new <= 0:
            return
        ids = jnp.asarray(pad_pow2(
            np.arange(upto, upto + n_new, dtype=np.int32)))
        idx = state.data["idx"]
        if self.codec == "pq":
            from repro.core.pq import pq_encode
            ds = idx["codebooks"].shape[0] * idx["codebooks"].shape[2]
            new = pq_encode(db[ids, :ds], idx["codebooks"])
            idx["codes"] = scatter_rows(idx["codes"], ids, new)
        else:
            ds = idx["db0_q"].shape[1]
            new, new_sq = int8_encode(db[ids, :ds], idx["scale0"])
            idx["db0_q"], idx["sq0"] = scatter_rows2(
                idx["db0_q"], idx["sq0"], ids, new, new_sq)
        state.data["coded_upto"] = upto + n_new

    def _tail_ids(self, state: IndexState, n_total: int) -> np.ndarray:
        """Static-shape (tail_cap,) window over rows past the coded prefix."""
        cap = state.data["tail_cap"]
        out = np.full((cap,), -1, np.int32)
        upto = state.data["coded_upto"]
        n_tail = min(max(n_total - upto, 0), cap)
        if n_tail:
            out[:n_tail] = np.arange(upto, upto + n_tail, dtype=np.int32)
        return out

    # -- search ---------------------------------------------------------------
    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        overrides=None,
    ) -> Tuple[Array, Array]:
        idx = state.data["idx"]
        tail = jnp.asarray(self._tail_ids(state, n_total))
        # adaptive degradation: the stage-0 codes are built at a fixed dim,
        # so the only per-dispatch lever here is the PQ oversample pool
        # (int8 has none — its stage-0 cost is pinned by the code block)
        pq_os = self._oversample(overrides)
        kw = dict(
            metric=self.metric,
            db=db,                       # rescore against the LIVE buffer
            valid=valid,
            # rows past the coded prefix have no codes: keep them out of
            # stage-0 ranking, reachable via the tail injection instead
            row_limit=jnp.asarray(state.data["coded_upto"]),
            extra_cand=tail,
        )
        if self.codec == "pq":
            from repro.core.pq import (
                pq_progressive_search,
                pq_progressive_search_kernel,
            )
            if self._kernel_enabled():
                scores, ids = pq_progressive_search_kernel(
                    q, idx, self.sched, merge=self.kernel_merge,
                    block_m=self.kernel_block_m,
                    oversample=pq_os,
                    interpret=self._interpret(), **kw)
            else:
                scores, ids = pq_progressive_search(
                    q, idx, self.sched, oversample=pq_os, **kw)
        else:
            scores, ids = quantized_progressive_search(
                q, idx, self.sched, **kw)
        return scores[:, :k], ids[:, :k]

    def _oversample(self, overrides) -> int:
        if overrides is None:
            return self.pq_oversample
        return max(1, int(round(
            self.pq_oversample * overrides.oversample_frac)))

    def search_fenced(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        fence,
        overrides=None,
    ) -> Tuple[Array, Array]:
        idx = state.data["idx"]
        tail = jnp.asarray(self._tail_ids(state, n_total))
        pq_os = self._oversample(overrides)
        kw = dict(
            metric=self.metric, db=db, valid=valid,
            row_limit=jnp.asarray(state.data["coded_upto"]),
            extra_cand=tail, stage0_only=True,
        )
        if self.codec == "pq":
            from repro.core.pq import (
                pq_progressive_search,
                pq_progressive_search_kernel,
            )
            if self._kernel_enabled():
                scores, cand = pq_progressive_search_kernel(
                    q, idx, self.sched, merge=self.kernel_merge,
                    block_m=self.kernel_block_m,
                    oversample=pq_os,
                    interpret=self._interpret(), **kw)
            else:
                scores, cand = pq_progressive_search(
                    q, idx, self.sched, oversample=pq_os, **kw)
        else:
            scores, cand = quantized_progressive_search(
                q, idx, self.sched, **kw)
        fence((scores, cand))
        # the stage-0 outputs already carry the injected tail; finish with
        # the same ladder stages the fused paths' rest logic would pick
        rest = quant_rest_stages(self.sched, extra_cand=tail, valid=valid)
        scores, ids = rescore_ladder_jit(
            q, db, cand, rest,
            valid=valid, metric=self.metric, scores=scores,
        )
        return scores[:, :k], ids[:, :k]

    def gauges(self, state: IndexState, stats: StoreStats):
        out = super().gauges(state, stats)
        n_coded = state.data["n_coded"]
        out.update({
            "coded_upto": float(state.data["coded_upto"]),
            "coded_frac": (min(stats.size, state.data["coded_upto"])
                           / stats.size if stats.size else 1.0),
            "code_block_rows": float(n_coded),
        })
        return out

    # -- persistence ----------------------------------------------------------
    # the idx's ``db`` entry is a snapshot of the store's own buffer — huge
    # and reconstructable: drop it at save, re-bind the live buffer at load
    _SAVE_SKIP = ("idx/db",)

    def _rebind_loaded(self, data, *, db, valid, sq_prefix=None) -> None:
        if data.get("codec") != self.codec:
            raise ValueError(
                f"checkpointed quantized index uses codec="
                f"{data.get('codec')!r}; this backend is configured for "
                f"{self.codec!r}")
        n_coded = data["n_coded"]
        if db.shape[0] < n_coded:
            raise ValueError(
                f"checkpointed code block covers {n_coded} buffer rows but "
                f"the store's capacity is {db.shape[0]}; the code block is "
                f"capacity-shaped — restore into a store grown to at least "
                f"the saved capacity")
        data["idx"]["db"] = db

    def describe(self) -> str:
        pq = f", pq_m={self.pq_m}" if self.codec == "pq" else ""
        return (
            f"QuantizedProgressiveBackend(codec={self.codec}{pq}, "
            f"rebuild_frac={self.rebuild_frac}, metric={self.metric}, "
            f"use_kernel={self.use_kernel})"
        )

"""Pluggable index backends for the retrieval engine.

The engine delegates its search structure to an ``IndexBackend``:

  flat       — stage-0 full scan at truncated dims (the paper's algorithm;
               exact baseline; builds are free, never stale)
  ivf        — k-means coarse quantizer (clustered and probed at
               ``probe_dim``, the schedule's max dim by default — probing
               is a tiny matmul); only probed lists' members are scored
               (sub-linear stage 0, rebuilt on churn)
  quantized  — int8 stage-0 block scan (4x less HBM traffic), exact
               full-precision rescore

All three share the progressive rescore ladder after candidate generation,
honor the store's validity mask (deleted rows are unreturnable), and keep
rows appended after a build reachable via tail injection until the engine
rebuilds.  See ``base.IndexBackend`` for the protocol and
``RetrievalEngine(backend=...)`` for the serving integration.
"""

from repro.index_backends.base import (
    ChurnRebuildBackend,
    IndexBackend,
    IndexState,
    StoreStats,
    backend_names,
    make_backend,
    register_backend,
    tail_ids,
)
from repro.index_backends.flat import FlatProgressiveBackend
from repro.index_backends.ivf import IVFProgressiveBackend
from repro.index_backends.quantized import QuantizedProgressiveBackend

__all__ = [
    "ChurnRebuildBackend", "IndexBackend", "IndexState", "StoreStats",
    "backend_names", "make_backend", "register_backend", "tail_ids",
    "FlatProgressiveBackend", "IVFProgressiveBackend",
    "QuantizedProgressiveBackend",
]

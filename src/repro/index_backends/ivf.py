"""IVF-progressive backend: k-means coarse quantizer in front of the schedule.

Stage 0 stops scanning the whole buffer: queries probe the ``n_probe``
nearest centroids and only the probed lists' members are scored, then the
normal progressive rescore ladder runs on the survivors.  Two build-time
decisions drive the cost/recall profile:

* **Probe space** (``probe_dim``) — centroids are clustered, assigned, and
  probed in the *same* truncated space, so a query equal to a document
  ranks that document's cell exactly where the assignment did.  Probing is
  an (n_lists, d) matmul — tiny next to the member scan — so a wider probe
  space buys better cell ranking nearly for free.
* **Balanced assignment** (``balance_factor``) — the member table is dense
  (its width is the longest list), so unbounded nearest-centroid
  assignment makes every query pay the occupancy *skew* in padded
  candidate slots.  Lists are capacity-bounded at ``balance_factor`` times
  the mean occupancy (see `repro.core.ivf.balanced_assign`), trading a
  little displacement for a table width near the mean.

Staleness: appended rows ride the tail window (see ``base.tail_ids``) until
churn crosses ``rebuild_frac`` of the built corpus, at which point the
engine re-clusters; deletes only degrade list occupancy (the validity mask
keeps them unreturnable) and count toward the same churn budget.  A rebuild
drops tombstoned rows from the lists entirely — the index side of
compaction.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import progressive_search
from repro.core.ivf import balanced_assign, ivf_progressive_search_sched, kmeans
from repro.core import truncated as T
from repro.index_backends.base import (
    ChurnRebuildBackend,
    IndexState,
    StoreStats,
    register_backend,
    tail_ids,
)

Array = jax.Array


@register_backend
class IVFProgressiveBackend(ChurnRebuildBackend):
    """Coarse-quantized candidate generation + progressive rescore."""

    name = "ivf"

    def __init__(
        self,
        sched,
        *,
        metric: str = "l2",
        block_n: int = 65536,
        n_lists: Optional[int] = None,
        n_probe: int = 12,
        probe_dim: Optional[int] = None,
        balance_factor: Optional[float] = 2.0,
        assign_m: int = 8,
        kmeans_iters: int = 10,
        train_rows: int = 131072,
        assign_block: int = 65536,
        rebuild_frac: float = 0.25,
        min_rebuild_rows: int = 64,
        tail_window: int = 512,
        min_index_rows: int = 64,
        seed: int = 0,
    ):
        """Args beyond the shared engine config:

        n_lists:        coarse-quantizer cells (None: ~n_live / 64, i.e. a
                        mean occupancy of 64 rows — candidate width then
                        stays roughly constant as the corpus grows — capped
                        at 4096 so k-means' per-iteration (rows, n_lists)
                        matrices stay bounded).
        train_rows:     k-means trains on at most this many sampled live
                        rows (the classic quantizer-training bound; the
                        assignment still covers every row).
        assign_block:   rows scored per tile when assigning — the
                        (rows, n_lists) score matrix never materializes for
                        the whole corpus at once.
        n_probe:        cells scanned per query.
        probe_dim:      clustering/probing dimensionality (None: the
                        schedule's max dim — probing is cheap, so rank
                        cells in the best space available).
        balance_factor: per-list capacity as a multiple of mean occupancy
                        (None: unbounded nearest-centroid assignment).
        assign_m:       centroid choices per row for balanced assignment.
        rebuild_frac / min_rebuild_rows / tail_window: see
                        ``ChurnRebuildBackend``.
        min_index_rows: below this live-row count, skip clustering and
                        serve the flat path (state flag) — exact and
                        cheaper than probing a near-empty table.
        """
        super().__init__(
            sched, metric=metric, block_n=block_n,
            rebuild_frac=rebuild_frac, min_rebuild_rows=min_rebuild_rows,
            tail_window=tail_window,
        )
        self.n_lists = n_lists
        self.n_probe = int(n_probe)
        self.probe_dim = probe_dim
        self.balance_factor = balance_factor
        self.assign_m = int(assign_m)
        self.kmeans_iters = int(kmeans_iters)
        self.train_rows = int(train_rows)
        self.assign_block = int(assign_block)
        self.min_index_rows = int(min_index_rows)
        self.seed = int(seed)

    # -- build --------------------------------------------------------------
    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        live = np.nonzero(np.asarray(valid[: stats.size]))[0] if stats.size else (
            np.zeros((0,), np.int64)
        )
        n_live = int(live.size)
        if n_live < self.min_index_rows:
            return IndexState.from_stats(
                self.name, stats,
                shape_key=(self.name, "flat-fallback"),
                data={"flat": True, "tail_cap": self._tail_cap(n_live)},
            )

        # auto n_lists snaps DOWN to a power of two: small corpus churn then
        # reproduces the same cell count (and thus the same traced shapes)
        # across rebuilds, so a state swap doesn't recompile every bucket
        auto = min(max(1, n_live // 64), 4096)
        n_lists = self.n_lists or 1 << (auto.bit_length() - 1)
        n_lists = min(n_lists, n_live)
        d_probe = self.probe_dim or self.sched.d_max
        db_live = db[jnp.asarray(live)][:, :d_probe].astype(jnp.float32)

        # Train the quantizer on a bounded sample (assignment covers all
        # rows below): k-means holds a (rows, n_lists) matrix per iteration.
        rng = np.random.default_rng(self.seed)
        if n_live > self.train_rows:
            sample = np.sort(rng.choice(n_live, self.train_rows,
                                        replace=False))
            train = db_live[jnp.asarray(sample)]
        else:
            train = db_live
        cents = kmeans(train, n_lists, n_iter=self.kmeans_iters,
                       key=jax.random.PRNGKey(self.seed))

        m = min(self.assign_m, n_lists)
        # rank cells with the serving metric so assignment and probing
        # agree on what "nearest cell" means; tile over rows so the
        # (rows, n_lists) score matrix stays O(assign_block * n_lists)
        score_fn = T._METRICS[self.metric]
        neg_parts, choice_parts = [], []
        for lo in range(0, n_live, self.assign_block):
            blk = db_live[lo: lo + self.assign_block]
            neg_b, choices_b = jax.lax.top_k(-score_fn(blk, cents), m)
            # keep tiles on device: converting inside the loop would sync
            # per tile and serialize dispatch against compute
            neg_parts.append(neg_b[:, 0])
            choice_parts.append(choices_b)
        neg0, choices = jax.device_get(
            (jnp.concatenate(neg_parts), jnp.concatenate(choice_parts)))
        if self.balance_factor is None or n_lists == 1:
            assign = choices[:, 0]
        else:
            cap = max(1, int(math.ceil(
                self.balance_factor * n_live / n_lists)))
            order = np.argsort(-neg0)               # confident rows first
            assign = balanced_assign(choices, order, n_lists, cap)

        # Host-side packing into a dense -1-padded table of *global* doc ids
        # (one argsort, not a per-list scan — n_lists scales with n_live, so
        # a scan per list would make the build quadratic).
        order = np.argsort(assign, kind="stable")
        counts = np.bincount(assign, minlength=n_lists)
        # table width rounds UP to a power of two (same shape-stability
        # story as n_lists; the padding rows are -1 and score +inf)
        max_len = 1 << (max(int(counts.max()), 1) - 1).bit_length()
        starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
        table = np.full((n_lists, max_len), -1, np.int32)
        sorted_lists = assign[order]
        table[sorted_lists, np.arange(n_live) - starts[sorted_lists]] = (
            live[order])
        tail_cap = self._tail_cap(n_live)
        return IndexState.from_stats(
            self.name, stats,
            shape_key=(self.name, n_lists, max_len, tail_cap),
            data={
                "centroids": cents,                 # (n_lists, d_probe) f32
                "lists": jnp.asarray(table),        # (n_lists, max_len) i32
                "n_lists": n_lists,
                "max_len": max_len,
                "tail_cap": tail_cap,
            },
        )

    # -- search -------------------------------------------------------------
    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
    ) -> Tuple[Array, Array]:
        if state.data.get("flat"):
            scores, ids = progressive_search(
                q, db, self.sched,
                sq_prefix=sq_prefix, index_dims=self.dims,
                valid=valid, block_n=min(self.block_n, db.shape[0]),
                metric=self.metric,
            )
            return scores[:, :k], ids[:, :k]
        tail = tail_ids(state, n_total, state.data["tail_cap"])
        scores, ids = ivf_progressive_search_sched(
            q, db, state.data["centroids"], state.data["lists"], self.sched,
            n_probe=min(self.n_probe, state.data["n_lists"]),
            valid=valid,
            sq_prefix=sq_prefix, index_dims=self.dims,
            extra_cand=jnp.asarray(tail),
            metric=self.metric,
        )
        return scores[:, :k], ids[:, :k]

    def describe(self) -> str:
        return (
            f"IVFProgressiveBackend(n_lists={self.n_lists or 'auto'}, "
            f"n_probe={self.n_probe}, rebuild_frac={self.rebuild_frac}, "
            f"metric={self.metric})"
        )

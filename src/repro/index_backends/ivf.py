"""IVF-progressive backend: k-means coarse quantizer in front of the schedule.

Stage 0 stops scanning the whole buffer: queries probe the ``n_probe``
nearest centroids and only the probed lists' members are scored, then the
normal progressive rescore ladder runs on the survivors.  Two build-time
decisions drive the cost/recall profile:

* **Probe space** (``probe_dim``) — centroids are clustered, assigned, and
  probed in the *same* truncated space, so a query equal to a document
  ranks that document's cell exactly where the assignment did.  Probing is
  an (n_lists, d) matmul — tiny next to the member scan — so a wider probe
  space buys better cell ranking nearly for free.
* **Balanced assignment** (``balance_factor``) — the member table is dense
  (its width is the longest list), so unbounded nearest-centroid
  assignment makes every query pay the occupancy *skew* in padded
  candidate slots.  Lists are capacity-bounded at ``balance_factor`` times
  the mean occupancy (see `repro.core.ivf.balanced_assign`), trading a
  little displacement for a table width near the mean.

**Fused stage-0 kernel** (``use_kernel``): the probe+scan hot path can run
as the Pallas kernel `repro.kernels.ivf_scan` — probed lists' member rows
stream HBM→VMEM once (list-major slabs packed at build time) and the
stage-0 top-k never leaves VMEM, instead of the XLA gather → candidate
table → score matrix round trips.  ``'auto'`` picks the kernel on real TPUs
and the XLA path on CPU (where the kernel would run in the interpreter);
``True`` forces it everywhere (interpret mode off-TPU — the parity-tested
configuration).  ``stage0_dtype='int8'`` stores the member slabs as
per-dimension int8 codes (`repro.core.quant`'s grid), composing the
quantized and IVF backends: 4× less stage-0 HBM traffic on top of the
probed-list pruning, full-precision rescore unchanged.

Staleness: appended rows are **absorbed incrementally** at engine safe
points (``absorb_appends``): each new row goes to its nearest centroid's
list while that list has spare slots (``append_spare`` reserved per list at
build time); only rows whose list is full ride the tail window (see
``base.tail_ids``), so append-heavy workloads stop forcing early rebuilds.
Churn past ``rebuild_frac`` of the built corpus still triggers a full
re-cluster (assignment quality), and deletes only degrade list occupancy
(the validity mask keeps them unreturnable).  A rebuild drops tombstoned
rows from the lists entirely — the index side of compaction.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import progressive_search
from repro.core.progressive import rescore_ladder_jit
from repro.core.ivf import (
    balanced_assign,
    ivf_progressive_search_kernel,
    ivf_progressive_search_sched,
    kmeans,
    pack_lists,
)
from repro.core import truncated as T
from repro.index_backends.base import (
    ChurnRebuildBackend,
    IndexState,
    StoreStats,
    register_backend,
)

Array = jax.Array


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_lists_donate(lists, lst, slot, ids):
    return lists.at[lst, slot].set(ids)


@jax.jit
def _scatter_lists_copy(lists, lst, slot, ids):
    return lists.at[lst, slot].set(ids)


@register_backend
class IVFProgressiveBackend(ChurnRebuildBackend):
    """Coarse-quantized candidate generation + progressive rescore."""

    name = "ivf"

    def __init__(
        self,
        sched,
        *,
        metric: str = "l2",
        block_n: int = 65536,
        n_lists: Optional[int] = None,
        n_probe: int = 12,
        probe_dim: Optional[int] = None,
        balance_factor: Optional[float] = 2.0,
        assign_m: int = 8,
        kmeans_iters: int = 10,
        train_rows: int = 131072,
        assign_block: int = 65536,
        rebuild_frac: float = 0.25,
        min_rebuild_rows: int = 64,
        tail_window: int = 512,
        min_index_rows: int = 64,
        append_spare: int = 8,
        use_kernel="auto",
        stage0_dtype: str = "float32",
        kernel_block_m: int = 128,
        kernel_merge: str = "sort",
        pq_m: Optional[int] = None,
        pq_codes: int = 256,
        pq_iters: int = 10,
        pq_oversample: int = 4,
        seed: int = 0,
    ):
        """Args beyond the shared engine config:

        n_lists:        coarse-quantizer cells (None: ~n_live / 64, i.e. a
                        mean occupancy of 64 rows — candidate width then
                        stays roughly constant as the corpus grows — capped
                        at 4096 so k-means' per-iteration (rows, n_lists)
                        matrices stay bounded).
        train_rows:     k-means trains on at most this many sampled live
                        rows (the classic quantizer-training bound; the
                        assignment still covers every row).
        assign_block:   rows scored per tile when assigning — the
                        (rows, n_lists) score matrix never materializes for
                        the whole corpus at once.
        n_probe:        cells scanned per query.
        probe_dim:      clustering/probing dimensionality (None: the
                        schedule's max dim — probing is cheap, so rank
                        cells in the best space available).
        balance_factor: per-list capacity as a multiple of mean occupancy
                        (None: unbounded nearest-centroid assignment).
        assign_m:       centroid choices per row for balanced assignment.
        rebuild_frac / min_rebuild_rows / tail_window: see
                        ``ChurnRebuildBackend``.
        min_index_rows: below this live-row count, skip clustering and
                        serve the flat path (state flag) — exact and
                        cheaper than probing a near-empty table.
        append_spare:   free slots reserved per list at build time;
                        ``absorb_appends`` places appended rows there
                        (nearest centroid) between rebuilds, so only rows
                        whose list is full consume the tail window.  0
                        disables absorption (appends ride the tail only).
        use_kernel:     'auto' | True | False — stage-0 via the fused
                        Pallas probe+scan kernel ('auto': TPU only; True
                        forces it, interpret mode off-TPU; False: XLA).
        stage0_dtype:   'float32' | 'int8' | 'pq' member slabs for the
                        kernel scan (int8 composes `repro.core.quant`'s
                        codes — 4x less stage-0 traffic; 'pq' composes
                        `repro.core.pq`'s product-quantization codes —
                        pq_m bytes/row and a VMEM-resident ADC lookup
                        table, the fused probe+LUT-scan.  Both require
                        the kernel path).
        kernel_block_m: member rows per kernel step.
        kernel_merge:   in-kernel top-k merge ('sort' | 'select').
        pq_m:           'pq' only: subspaces per stage-0 row (None: aim
                        8-dim subspaces — `repro.core.pq.auto_pq_m`); must
                        divide the stage-0 dim.
        pq_codes:       'pq' only: centroids per subspace (<= 256).
        pq_iters:       'pq' only: k-means iterations per subspace.
        pq_oversample:  'pq' only: stage-0 survivor pool widens to
                        ``pq_oversample × k0`` (ADC noise is absorbed by
                        the full-precision rescore, which cuts it back).
        """
        super().__init__(
            sched, metric=metric, block_n=block_n,
            rebuild_frac=rebuild_frac, min_rebuild_rows=min_rebuild_rows,
            tail_window=tail_window,
        )
        self.n_lists = n_lists
        self.n_probe = int(n_probe)
        self.probe_dim = probe_dim
        self.balance_factor = balance_factor
        self.assign_m = int(assign_m)
        self.kmeans_iters = int(kmeans_iters)
        self.train_rows = int(train_rows)
        self.assign_block = int(assign_block)
        self.min_index_rows = int(min_index_rows)
        self.append_spare = int(append_spare)
        if use_kernel not in ("auto", True, False):
            raise ValueError(
                f"use_kernel must be 'auto'|True|False, got {use_kernel!r}")
        if stage0_dtype not in ("float32", "int8", "pq"):
            raise ValueError(
                f"stage0_dtype must be float32|int8|pq, got {stage0_dtype!r}")
        if use_kernel is True and metric != "l2":
            raise ValueError(
                "the fused IVF kernel scores L2 only; use metric='l2' or "
                "use_kernel='auto'/False")
        self.use_kernel = use_kernel
        self.stage0_dtype = stage0_dtype
        self.kernel_block_m = int(kernel_block_m)
        self.kernel_merge = kernel_merge
        self.pq_codes = int(pq_codes)
        self.pq_iters = int(pq_iters)
        self.pq_oversample = max(1, int(pq_oversample))
        s0_dim = sched.stages[0].dim
        if stage0_dtype == "pq":
            from repro.core.pq import auto_pq_m
            self.pq_m = int(pq_m) if pq_m else auto_pq_m(s0_dim)
            if s0_dim % self.pq_m:
                raise ValueError(
                    f"pq_m={self.pq_m} does not divide the stage-0 dim "
                    f"{s0_dim}")
        else:
            self.pq_m = pq_m
        self.seed = int(seed)
        if stage0_dtype in ("int8", "pq") and not self._kernel_enabled():
            # coded member slabs only exist on the kernel path; silently
            # serving the f32 XLA path instead would report a traffic win
            # that never happens
            raise ValueError(
                f"stage0_dtype={stage0_dtype!r} packs member slabs for the "
                "fused kernel, which is disabled here (use_kernel="
                f"{use_kernel!r} on backend {jax.default_backend()!r}); "
                "pass use_kernel=True (interpret mode off-TPU) or "
                "stage0_dtype='float32'")

    def _kernel_enabled(self) -> bool:
        if self.use_kernel is False or self.metric != "l2":
            return False
        if self.use_kernel is True:
            return True
        return jax.default_backend() == "tpu"

    @staticmethod
    def _interpret() -> bool:
        return jax.default_backend() != "tpu"

    # -- build --------------------------------------------------------------
    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        live = np.nonzero(np.asarray(valid[: stats.size]))[0] if stats.size else (
            np.zeros((0,), np.int64)
        )
        n_live = int(live.size)
        if n_live < self.min_index_rows:
            return IndexState.from_stats(
                self.name, stats,
                shape_key=(self.name, "flat-fallback"),
                data={"flat": True, "tail_cap": self._tail_cap(n_live)},
            )

        # auto n_lists snaps DOWN to a power of two: small corpus churn then
        # reproduces the same cell count (and thus the same traced shapes)
        # across rebuilds, so a state swap doesn't recompile every bucket
        auto = min(max(1, n_live // 64), 4096)
        n_lists = self.n_lists or 1 << (auto.bit_length() - 1)
        n_lists = min(n_lists, n_live)
        d_probe = self.probe_dim or self.sched.d_max
        db_live = db[jnp.asarray(live)][:, :d_probe].astype(jnp.float32)

        # Train the quantizer on a bounded sample (assignment covers all
        # rows below): k-means holds a (rows, n_lists) matrix per iteration.
        rng = np.random.default_rng(self.seed)
        if n_live > self.train_rows:
            sample = np.sort(rng.choice(n_live, self.train_rows,
                                        replace=False))
            train = db_live[jnp.asarray(sample)]
        else:
            train = db_live
        cents = kmeans(train, n_lists, n_iter=self.kmeans_iters,
                       key=jax.random.PRNGKey(self.seed))
        # centroid norms are probe-time constants: cache them in the state
        # so no search call recomputes them
        cent_sq = jnp.sum(cents.astype(jnp.float32) ** 2, axis=-1)

        m = min(self.assign_m, n_lists)
        # rank cells with the serving metric so assignment and probing
        # agree on what "nearest cell" means; tile over rows so the
        # (rows, n_lists) score matrix stays O(assign_block * n_lists)
        score_fn = T._METRICS[self.metric]
        neg_parts, choice_parts = [], []
        for lo in range(0, n_live, self.assign_block):
            blk = db_live[lo: lo + self.assign_block]
            neg_b, choices_b = jax.lax.top_k(-score_fn(blk, cents, cent_sq), m)
            # keep tiles on device: converting inside the loop would sync
            # per tile and serialize dispatch against compute
            neg_parts.append(neg_b[:, 0])
            choice_parts.append(choices_b)
        neg0, choices = jax.device_get(
            (jnp.concatenate(neg_parts), jnp.concatenate(choice_parts)))
        if self.balance_factor is None or n_lists == 1:
            assign = choices[:, 0]
        else:
            cap = max(1, int(math.ceil(
                self.balance_factor * n_live / n_lists)))
            order = np.argsort(-neg0)               # confident rows first
            assign = balanced_assign(choices, order, n_lists, cap)

        # Dense -1-padded table of *global* doc ids via the shared packing
        # path; append_spare slots stay free for incremental absorption, and
        # the width rounds UP to a power of two (same shape-stability story
        # as n_lists; padding slots are -1 and score +inf)
        table = pack_lists(assign, n_lists, ids=live,
                           spare=self.append_spare, round_pow2=True)
        max_len = table.shape[1]
        list_fill = np.bincount(assign, minlength=n_lists).astype(np.int64)
        tail_cap = self._tail_cap(n_live)

        kernel_on = self._kernel_enabled()
        pack = None
        if kernel_on:
            from repro.core.ivf import _sq_col
            from repro.kernels.ivf_scan import pack_ivf_lists
            s0_dim = self.sched.stages[0].dim
            codebooks = None
            if self.stage0_dtype == "pq":
                # ADC codebooks are fit on live rows at the *stage-0* dim
                # (the space the slabs are scanned in), on the same bounded
                # sample budget as the coarse quantizer
                from repro.core.pq import train_pq
                tr = live
                if tr.size > self.train_rows:
                    tr = np.sort(rng.choice(tr, self.train_rows,
                                            replace=False))
                codebooks = train_pq(
                    db[jnp.asarray(tr)][:, :s0_dim],
                    m=self.pq_m, n_codes=self.pq_codes,
                    n_iter=self.pq_iters,
                    key=jax.random.PRNGKey(self.seed + 1))
            pack = pack_ivf_lists(
                db, jnp.asarray(table), dim=s0_dim,
                db_sq_at_dim=_sq_col(sq_prefix, self.dims, s0_dim),
                dtype=self.stage0_dtype, block_m=self.kernel_block_m,
                pq_codebooks=codebooks,
            )
        return IndexState.from_stats(
            self.name, stats,
            shape_key=(self.name, n_lists, max_len, tail_cap,
                       kernel_on, self.stage0_dtype),
            data={
                "centroids": cents,                 # (n_lists, d_probe) f32
                "cent_sq": cent_sq,                 # (n_lists,) f32 cached
                "lists": jnp.asarray(table),        # (n_lists, max_len) i32
                "list_fill": list_fill,             # (n_lists,) host counts
                "absorb_upto": stats.size,          # rows examined so far
                "tail_pending": np.zeros((0,), np.int32),
                "pack": pack,                       # kernel member slabs
                "n_lists": n_lists,
                "max_len": max_len,
                "tail_cap": tail_cap,
            },
        )

    # -- incremental maintenance -------------------------------------------
    def _tail_load(self, state: IndexState, stats: StoreStats) -> int:
        if state.data.get("flat"):
            return super()._tail_load(state, stats)
        return (len(state.data["tail_pending"])
                + (stats.size - state.data["absorb_upto"]))

    def absorb_appends(
        self,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> None:
        """Assign appended rows to their nearest centroid's spare slots.

        Runs between rebuilds at engine safe points: each row in
        ``[absorb_upto, n_total)`` joins its nearest list if that list has a
        free slot, otherwise it stays in the tail window (``tail_pending``).
        Mutates ``state.data`` in place; every traced shape is preserved —
        only table/slab *contents* change, so no dispatch recompiles.
        """
        if state.data.get("flat"):
            return
        if self.append_spare == 0:
            # incremental maintenance disabled: appended rows ride the tail
            # window until the next rebuild (the pre-absorption behavior,
            # and what the tail-overflow hard-bound tests exercise)
            return
        n_total = stats.size
        upto = state.data["absorb_upto"]
        if n_total <= upto:
            # no new rows — deletes may have freed tail-window capacity, but
            # only re-check liveness when something was actually deleted
            # since the last prune: this branch runs on every dispatch and
            # the gather below is a device round trip under engine.lock
            pending = state.data["tail_pending"]
            if (pending.size
                    and state.data.get("pruned_at_deleted")
                    != stats.total_deleted):
                alive = np.asarray(valid[jnp.asarray(pending)])
                state.data["tail_pending"] = pending[alive]
                state.data["pruned_at_deleted"] = stats.total_deleted
            return
        new_ids = np.arange(upto, n_total, dtype=np.int64)
        cents = state.data["centroids"]
        d_probe = cents.shape[1]
        score_fn = T._METRICS[self.metric]
        rows = db[jnp.asarray(new_ids), :d_probe].astype(jnp.float32)
        nearest = np.asarray(jnp.argmin(
            score_fn(rows, cents, state.data["cent_sq"]), axis=1))

        lists = state.data["lists"]
        pack = state.data["pack"]
        fill = state.data["list_fill"]
        max_len = state.data["max_len"]
        acc_ids, acc_lists, acc_slots, rejected = [], [], [], []
        for rid, lst in zip(new_ids, nearest):
            lst = int(lst)
            if fill[lst] < max_len:
                acc_ids.append(rid)
                acc_lists.append(lst)
                acc_slots.append(int(fill[lst]))
                fill[lst] += 1
            else:
                rejected.append(rid)
        if acc_ids:
            # jitted scatter with buffer donation off-CPU: absorbing a few
            # rows must update the device tables in place, not copy them
            # (batch padded to a power of two so burst sizes don't retrace)
            from repro.kernels.ivf_scan import _pad_pow2, update_pack
            scatter = (_scatter_lists_copy
                       if jax.default_backend() == "cpu"
                       else _scatter_lists_donate)
            lists = scatter(
                lists,
                jnp.asarray(_pad_pow2(np.asarray(acc_lists, np.int32))),
                jnp.asarray(_pad_pow2(np.asarray(acc_slots, np.int32))),
                jnp.asarray(_pad_pow2(np.asarray(acc_ids, np.int32))))
            if pack is not None:
                dests = (np.asarray(acc_lists, np.int64) * pack["max_len"]
                         + np.asarray(acc_slots, np.int64))
                pack = update_pack(pack, db, np.asarray(acc_ids, np.int32),
                                   dests)
        pending = np.concatenate(
            [state.data["tail_pending"],
             np.asarray(rejected, np.int32)]).astype(np.int32)
        if pending.size:
            # tombstoned pending rows would hold window capacity forever;
            # the validity mask already makes them unreturnable, so drop them
            alive = np.asarray(valid[jnp.asarray(pending)])
            pending = pending[alive]
        state.data.update(
            lists=lists, pack=pack, list_fill=fill,
            absorb_upto=n_total, tail_pending=pending,
            pruned_at_deleted=stats.total_deleted,
        )

    def _tail_ids(self, state: IndexState, n_total: int) -> np.ndarray:
        """Static-shape (tail_cap,) window: pending + not-yet-absorbed ids."""
        cap = state.data["tail_cap"]
        out = np.full((cap,), -1, np.int32)
        ids = np.concatenate([
            state.data["tail_pending"],
            np.arange(state.data["absorb_upto"], n_total, dtype=np.int32),
        ])[:cap]
        out[: ids.size] = ids
        return out

    # -- search -------------------------------------------------------------
    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        overrides=None,
    ) -> Tuple[Array, Array]:
        # adaptive degradation knobs, all static per dispatch (one extra
        # compiled program per level, pre-warmed by engine.warmup): probe
        # fewer lists, shrink the PQ oversample pool, and — on the paths
        # whose stage-0 dim isn't baked into packed slabs — enter the
        # progressive ladder at a lower d_start rung
        sched, n_probe, pq_os = self._apply_overrides(state, overrides)
        if state.data.get("flat"):
            scores, ids = progressive_search(
                q, db, sched,
                sq_prefix=sq_prefix, index_dims=self.dims,
                valid=valid, block_n=min(self.block_n, db.shape[0]),
                metric=self.metric,
            )
            return scores[:, :k], ids[:, :k]
        tail = jnp.asarray(self._tail_ids(state, n_total))
        if state.data["pack"] is not None:
            scores, ids = ivf_progressive_search_kernel(
                q, db, state.data["centroids"], state.data["lists"],
                self.sched, n_probe=n_probe,
                valid=valid, sq_prefix=sq_prefix, index_dims=self.dims,
                extra_cand=tail, metric=self.metric,
                cent_sq=state.data["cent_sq"], pack=state.data["pack"],
                merge=self.kernel_merge,
                pq_oversample=pq_os,
                interpret=self._interpret(),
            )
        else:
            scores, ids = ivf_progressive_search_sched(
                q, db, state.data["centroids"], state.data["lists"],
                sched, n_probe=n_probe,
                valid=valid, sq_prefix=sq_prefix, index_dims=self.dims,
                extra_cand=tail, metric=self.metric,
                cent_sq=state.data["cent_sq"],
            )
        return scores[:, :k], ids[:, :k]

    def _apply_overrides(self, state: IndexState, overrides):
        """Resolve (sched, n_probe, pq_oversample) for one dispatch.

        ``overrides.sched`` only applies where the stage-0 dim is not
        frozen into a build artifact (the flat fallback and the XLA sched
        path); packed int8/PQ member slabs pin their stage-0 dim/codes at
        build time, so those paths degrade via n_probe/oversample alone.
        """
        pq_os = self.pq_oversample if self.stage0_dtype == "pq" else 1
        if state.data.get("flat"):
            n_probe = self.n_probe
        else:
            n_probe = min(self.n_probe, state.data["n_lists"])
        if overrides is None:
            return self.sched, n_probe, pq_os
        sched = self.sched if overrides.sched is None else overrides.sched
        if not state.data.get("flat"):
            n_probe = min(
                max(1, int(round(self.n_probe * overrides.n_probe_frac))),
                state.data["n_lists"])
        if pq_os > 1:
            pq_os = max(1, int(round(pq_os * overrides.oversample_frac)))
        return sched, n_probe, pq_os

    def search_fenced(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        fence,
        overrides=None,
    ) -> Tuple[Array, Array]:
        sched, n_probe, pq_os = self._apply_overrides(state, overrides)
        if state.data.get("flat"):
            scores, cand = progressive_search(
                q, db, sched,
                sq_prefix=sq_prefix, index_dims=self.dims,
                valid=valid, block_n=min(self.block_n, db.shape[0]),
                metric=self.metric, stage0_only=True,
            )
            fence((scores, cand))
            ladder_stages = sched.stages[1:]
        else:
            tail = jnp.asarray(self._tail_ids(state, n_total))
            if state.data["pack"] is not None:
                scores, cand = ivf_progressive_search_kernel(
                    q, db, state.data["centroids"], state.data["lists"],
                    self.sched, n_probe=n_probe,
                    valid=valid, sq_prefix=sq_prefix, index_dims=self.dims,
                    extra_cand=tail, metric=self.metric,
                    cent_sq=state.data["cent_sq"], pack=state.data["pack"],
                    merge=self.kernel_merge,
                    pq_oversample=pq_os,
                    interpret=self._interpret(),
                    stage0_only=True,
                )
                fence((scores, cand))
                ladder_stages = self.sched.stages[1:]
            else:
                # the sched path has no stage-0 scores: probing only gathers
                # candidates, and ALL schedule stages rescore them
                scores, cand = ivf_progressive_search_sched(
                    q, db, state.data["centroids"], state.data["lists"],
                    sched, n_probe=n_probe,
                    valid=valid, sq_prefix=sq_prefix, index_dims=self.dims,
                    extra_cand=tail, metric=self.metric,
                    cent_sq=state.data["cent_sq"],
                    stage0_only=True,
                )
                fence(cand)
                ladder_stages = sched.stages
        scores, ids = rescore_ladder_jit(
            q, db, cand, ladder_stages,
            sq_prefix=sq_prefix, index_dims=self.dims,
            valid=valid, metric=self.metric, scores=scores,
        )
        return scores[:, :k], ids[:, :k]

    def gauges(self, state: IndexState, stats: StoreStats):
        out = super().gauges(state, stats)
        if state.data.get("flat"):
            return out
        n_lists = state.data["n_lists"]
        max_len = state.data["max_len"]
        fill = state.data["list_fill"]
        out.update({
            "n_lists": float(n_lists),
            "list_fill_frac": (float(fill.sum()) / (n_lists * max_len)
                               if n_lists * max_len else 0.0),
            "append_spare_used": float(
                max(0, int(fill.sum()) - state.built_active)),
            "tail_pending": float(len(state.data["tail_pending"])),
            "absorbed_rows": float(
                state.data["absorb_upto"] - state.built_size),
        })
        return out

    def describe(self) -> str:
        return (
            f"IVFProgressiveBackend(n_lists={self.n_lists or 'auto'}, "
            f"n_probe={self.n_probe}, rebuild_frac={self.rebuild_frac}, "
            f"metric={self.metric}, use_kernel={self.use_kernel}, "
            f"stage0_dtype={self.stage0_dtype})"
        )

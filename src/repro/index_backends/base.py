"""The index-backend protocol: pluggable search structures behind the engine.

The paper's progressive search needs only a flat buffer, but its stated
future work — ANN integration — and the repo's north star (corpus scale)
need *index structures* with build state: IVF centroids, int8 code blocks,
and whatever comes next.  This module defines the contract between
`repro.engine.RetrievalEngine` and such structures so new backends slot in
without forking the engine:

  * ``build(db, valid, sq_prefix=..., stats=...) -> IndexState`` — construct
    index state from a snapshot of the store's buffers.  Called at a safe
    point between batches (or on a background thread); must not mutate the
    store.
  * ``search(q, state, db, valid, ...) -> (scores, ids)`` — answer a padded
    query batch against the *live* buffers using the (possibly stale) state.
    Correctness contract: a row whose validity bit is clear is never
    returned, and a live row is always reachable — even when it was appended
    after ``state`` was built (see the tail-injection note below).
  * ``needs_rebuild(state, stats) -> bool`` — staleness policy: the engine
    rebuilds when this fires.  ``must_rebuild`` is the hard variant the
    engine honors even with rebuilds disabled, for backends whose
    correctness (not just quality) degrades past a staleness bound.

**Tail injection.**  Rows appended after a build are not in the index
(IVF lists / int8 codes don't cover them).  Backends keep a static-size
*tail window* (``tail_cap``, sized from the rebuild threshold at build
time): the ids ``[built_size, store.size)`` are injected into every query's
candidate list ahead of the progressive rescore, so un-indexed rows are
scored exactly and stay retrievable between rebuilds.  When the tail
outgrows its window, ``must_rebuild`` fires and the engine rebuilds before
the next dispatch — the window can never be silently exceeded.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.index import stage_dims
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StoreStats:
    """Snapshot of a DocStore's mutation counters (feeds ``needs_rebuild``)."""

    size: int            # high-water mark: rows ever appended (pre-compaction)
    n_active: int        # rows with the validity bit set
    capacity: int        # allocated buffer rows
    generation: int      # bumped on every mutation
    total_added: int     # lifetime rows appended
    total_deleted: int   # lifetime rows tombstoned

    @property
    def n_dead(self) -> int:
        return self.size - self.n_active

    @property
    def dead_frac(self) -> float:
        return self.n_dead / self.size if self.size else 0.0


@dataclasses.dataclass
class IndexState:
    """Opaque (to the engine) build artifact + the snapshot it was built at.

    ``shape_key`` participates in the engine's compile tracking: any change
    that alters the traced program's shapes (list-table width, tail window)
    must change it, so recompiles are attributed correctly.
    """

    kind: str
    generation: int         # store generation at build time
    built_size: int         # rows [0, built_size) are covered by the index
    built_active: int       # live rows at build time
    built_added: int        # store.total_added at build time
    built_deleted: int      # store.total_deleted at build time
    shape_key: Tuple = ()
    data: Dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_stats(
        cls,
        kind: str,
        stats: "StoreStats",
        *,
        shape_key: Tuple = (),
        data: Optional[Dict] = None,
    ) -> "IndexState":
        """Snapshot the stats fields every backend must record identically —
        the churn accounting in ``ChurnRebuildBackend`` depends on them."""
        return cls(
            kind=kind,
            generation=stats.generation,
            built_size=stats.size,
            built_active=stats.n_active,
            built_added=stats.total_added,
            built_deleted=stats.total_deleted,
            shape_key=shape_key,
            data=data if data is not None else {},
        )


def tail_ids(state: IndexState, n_total: int, tail_cap: int) -> np.ndarray:
    """Static-shape (tail_cap,) int32 id window over un-indexed appended rows.

    Ids ``[built_size, n_total)`` padded with -1 (the candidate sentinel
    ``rescore_candidates`` already scores +inf).  Host-side on purpose: the
    *content* changes per dispatch but the shape never does, so no retrace.
    """
    out = np.full((tail_cap,), -1, np.int32)
    n_tail = min(max(n_total - state.built_size, 0), tail_cap)
    if n_tail:
        out[:n_tail] = np.arange(
            state.built_size, state.built_size + n_tail, dtype=np.int32
        )
    return out


class IndexBackend(abc.ABC):
    """Search structure behind the retrieval engine.

    Subclasses are constructed with the engine's static search config
    (schedule / stage dims / metric / scan block) plus backend-specific
    options, and are stateless across builds: all per-corpus state lives in
    the ``IndexState`` they return, which the engine owns and swaps
    atomically.
    """

    name: str = "?"

    def __init__(
        self,
        sched: ProgressiveSchedule,
        *,
        metric: str = "l2",
        block_n: int = 65536,
    ):
        self.sched = sched
        self.dims = stage_dims(sched)
        self.metric = metric
        self.block_n = int(block_n)

    # -- protocol ----------------------------------------------------------
    @abc.abstractmethod
    def build(
        self,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        """Build index state from a buffer snapshot.  Must not mutate it."""

    @abc.abstractmethod
    def search(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        overrides=None,
    ) -> Tuple[Array, Array]:
        """((Q, k) scores, (Q, k) int32 ids) over the live buffers.

        ``n_total`` is the store's current high-water row count (`store.size`
        — a host int, so tail windows never force a retrace).  May return
        device arrays; the engine syncs.

        ``overrides`` is an optional duck-typed degradation bundle (the
        adaptive policy's `SearchOverrides`: ``n_probe_frac`` /
        ``oversample_frac`` / ``sched`` attributes, frozen and hashable so
        it can ride jit static arguments).  Backends honour the knobs they
        have and ignore the rest; the engine only passes it when the
        adaptive policy is degrading, so the kwarg's default keeps custom
        backends working unchanged.  The result width (``k`` columns) must
        not change with ``overrides``.
        """

    def search_fenced(
        self,
        q: Array,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        n_total: int,
        k: int,
        fence,
        overrides=None,
    ) -> Tuple[Array, Array]:
        """`search` with a host fence at the stage-0/rescore boundary.

        ``fence(arrays)`` is an engine-supplied callback: implementations
        call it exactly once with the stage-0 outputs; the engine
        ``block_until_ready``s them there and timestamps the boundary
        (`repro.obs` trace marks).  This path trades one extra host sync
        per batch for a real stage-0/rescore latency split — it is only
        selected under ``obs.stage_fences``; the default serving path keeps
        the fully fused programs.

        Default: fall back to the fused `search` without calling ``fence``
        (custom backends degrade to traces without the split).
        """
        kw = {} if overrides is None else {"overrides": overrides}
        return self.search(q, state, db, valid, sq_prefix=sq_prefix,
                           n_total=n_total, k=k, **kw)

    def gauges(self, state: IndexState, stats: StoreStats) -> Dict[str, float]:
        """Point-in-time observability gauges for this state (staleness,
        tail occupancy, code coverage, ...), published by the engine's
        metrics collector as ``repro_backend_state{backend=...,key=...}``.
        Keys are backend-defined; values must be numeric."""
        return {}

    def needs_rebuild(self, state: IndexState, stats: StoreStats) -> bool:
        """Soft staleness: rebuild improves quality/cost but isn't required."""
        return False

    def must_rebuild(self, state: IndexState, stats: StoreStats) -> bool:
        """Hard staleness: searching ``state`` would be incorrect."""
        return False

    def absorb_appends(
        self,
        state: IndexState,
        db: Array,
        valid: Array,
        *,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> None:
        """Fold rows appended since the build into ``state`` incrementally.

        Called by the engine at the same safe points as ``maybe_rebuild``
        (under ``engine.lock``, never mid-batch); may mutate ``state.data``
        in place but must preserve every traced shape (``shape_key`` is
        fixed for the state's lifetime).  Default: no-op — appended rows
        ride the tail window until the next rebuild.  Backends that can
        absorb appends cheaply (e.g. IVF nearest-centroid assignment into
        spare list slots) override this so append-heavy workloads stop
        forcing early rebuilds.
        """

    def describe(self) -> str:
        return f"{type(self).__name__}(metric={self.metric})"

    # -- persistence ---------------------------------------------------------
    # Data paths (slash-joined nested keys) excluded from state_dict; they
    # reference live store buffers and are re-bound at load (_rebind_loaded).
    _SAVE_SKIP: Tuple[str, ...] = ()

    def state_dict(self, state: IndexState) -> Dict:
        """Serialize ``state`` to ``{"meta": json-able, "arrays": {name:
        np.ndarray}}`` — the payload `repro.checkpoint.save_arrays` persists.

        Generic over every backend: ``state.data`` is walked as a nested
        dict of device arrays / host arrays / scalars; array leaves land in
        ``arrays`` under their slash-joined path with their host-vs-device
        kind recorded, everything else lands in the meta.  Backends whose
        data references live store buffers list those paths in
        ``_SAVE_SKIP`` and re-attach them at load.
        """
        arrays: Dict[str, np.ndarray] = {}
        scalars: Dict[str, object] = {}
        kinds: Dict[str, str] = {}
        dicts: list = []

        def walk(d: Dict, prefix: str) -> None:
            for key, val in d.items():
                path = f"{prefix}{key}"
                if path in self._SAVE_SKIP:
                    continue
                if isinstance(val, dict):
                    dicts.append(path)
                    walk(val, path + "/")
                elif isinstance(val, jax.Array):
                    arrays[path] = np.asarray(jax.device_get(val))
                    kinds[path] = "jax"
                elif isinstance(val, np.ndarray):
                    arrays[path] = val
                    kinds[path] = "np"
                elif isinstance(val, np.generic):
                    scalars[path] = val.item()
                elif isinstance(val, (bool, int, float, str)) or val is None:
                    scalars[path] = val
                else:
                    raise TypeError(
                        f"cannot serialize state.data[{path!r}] of type "
                        f"{type(val).__name__}; extend "
                        f"{type(self).__name__}.state_dict")

        walk(state.data, "")
        meta = {
            "backend": self.name,
            "kind": state.kind,
            "built_size": state.built_size,
            "built_active": state.built_active,
            "shape_key": _jsonify_key(state.shape_key),
            "scalars": scalars,
            "array_kinds": kinds,
            "dict_paths": dicts,
        }
        return {"meta": meta, "arrays": arrays}

    def load_state(
        self,
        payload: Dict,
        *,
        db: Array,
        valid: Array,
        sq_prefix: Optional[Array] = None,
        stats: StoreStats,
    ) -> IndexState:
        """Reconstruct an `IndexState` from a `state_dict` payload.

        The caller (the engine) guarantees the store holds the same rows
        ``[0, built_size)`` the state was built over — typically a serving
        restart that re-adds the identical corpus; this method validates
        only what it can see (backend kind, sizes).  Churn counters are
        re-stamped against the *current* store so staleness accounting
        starts clean: rows appended beyond ``built_size`` since the save
        ride the tail window exactly like rows appended after a build.
        """
        meta, arrays = payload["meta"], payload["arrays"]
        if meta["kind"] != self.name:
            raise ValueError(
                f"checkpointed index is a {meta['kind']!r} state; this "
                f"engine runs the {self.name!r} backend")
        if meta["built_size"] > stats.size:
            raise ValueError(
                f"checkpointed index covers rows [0, {meta['built_size']}) "
                f"but the store holds only {stats.size}; re-add the corpus "
                f"before load_index")
        data: Dict = {}
        for path in meta["dict_paths"]:
            _dig(data, path.split("/"))
        for path, val in meta["scalars"].items():
            parts = path.split("/")
            _dig(data, parts[:-1])[parts[-1]] = val
        for path, arr in arrays.items():
            parts = path.split("/")
            if meta["array_kinds"].get(path) == "jax":
                arr = jnp.asarray(arr)
            _dig(data, parts[:-1])[parts[-1]] = arr
        self._rebind_loaded(data, db=db, valid=valid, sq_prefix=sq_prefix)
        return IndexState(
            kind=meta["kind"],
            generation=stats.generation,
            built_size=meta["built_size"],
            built_active=meta["built_active"],
            # re-stamp churn counters so (adds since load) == (rows past
            # built_size): loaded state starts with zero counted churn
            built_added=stats.total_added - (stats.size - meta["built_size"]),
            built_deleted=stats.total_deleted,
            shape_key=_tuplify_key(meta["shape_key"]),
            data=data,
        )

    def _rebind_loaded(
        self,
        data: Dict,
        *,
        db: Array,
        valid: Array,
        sq_prefix: Optional[Array] = None,
    ) -> None:
        """Hook: re-attach live-buffer references `_SAVE_SKIP` dropped and
        validate loaded shapes against the store.  Default: nothing."""


def _jsonify_key(key):
    """shape_key tuple -> msgpack-able nested list."""
    if isinstance(key, (tuple, list)):
        return [_jsonify_key(x) for x in key]
    return key


def _tuplify_key(key):
    """Nested list -> hashable tuple (the engine's compile-tracking set)."""
    if isinstance(key, list):
        return tuple(_tuplify_key(x) for x in key)
    return key


def _dig(d: Dict, parts) -> Dict:
    for p in parts:
        d = d.setdefault(p, {})
    return d


class ChurnRebuildBackend(IndexBackend):
    """Shared staleness policy for backends with real build artifacts.

    Soft: rebuild once churn (adds + deletes since build) crosses
    ``rebuild_frac`` of the built corpus.  Hard: rebuild when appended rows
    outgrow the tail window (``state.data['tail_cap']``), since rows past
    it would be unreachable.  Subclasses size their window with
    ``_tail_cap`` at build time and store it in the state.
    """

    def __init__(
        self,
        sched: ProgressiveSchedule,
        *,
        metric: str = "l2",
        block_n: int = 65536,
        rebuild_frac: float = 0.25,
        min_rebuild_rows: int = 64,
        tail_window: int = 512,
    ):
        super().__init__(sched, metric=metric, block_n=block_n)
        self.rebuild_frac = float(rebuild_frac)
        self.min_rebuild_rows = int(min_rebuild_rows)
        self.tail_window = int(tail_window)

    def _churn_since_build(self, state: IndexState, stats: StoreStats) -> int:
        return (stats.total_added - state.built_added) + (
            stats.total_deleted - state.built_deleted
        )

    def _tail_load(self, state: IndexState, stats: StoreStats) -> int:
        """Rows the tail window must currently carry.

        Default: everything appended since the build.  Backends that absorb
        appends into the index between rebuilds (``absorb_appends``)
        override this to count only the rows still outside it, which is
        what keeps absorbed appends from tripping the rebuild bounds.
        """
        return stats.size - state.built_size

    def _tail_cap(self, n_active: int) -> int:
        # 2x the soft-staleness budget, clamped to an absolute window: every
        # query rescores the whole window (even empty slots cost a gather),
        # so it must NOT scale with the corpus.  needs_rebuild fires at half
        # the window, so the soft trigger always precedes the hard bound —
        # a background build has the other half of the window to land.
        soft = max(self.min_rebuild_rows, int(self.rebuild_frac * n_active))
        cap = max(self.min_rebuild_rows, min(2 * soft, self.tail_window))
        # round to a power of two: the window is part of the traced shape,
        # and a stable shape across rebuilds is what keeps state swaps
        # compile-free
        return 1 << (cap - 1).bit_length()

    def needs_rebuild(self, state: IndexState, stats: StoreStats) -> bool:
        if self.must_rebuild(state, stats):
            return True
        # appends approaching the hard tail bound: start rebuilding now
        # (in background mode this is what keeps the sync path off the
        # serving thread — the hard bound only fires if the build lags)
        if self._tail_load(state, stats) >= state.data["tail_cap"] // 2:
            return True
        threshold = max(
            self.min_rebuild_rows,
            self.rebuild_frac * max(state.built_active, 1),
        )
        return self._churn_since_build(state, stats) >= threshold

    def must_rebuild(self, state: IndexState, stats: StoreStats) -> bool:
        # correctness bound: un-absorbed appended rows beyond the tail
        # window would be unreachable until the next build
        return self._tail_load(state, stats) > state.data["tail_cap"]

    def gauges(self, state: IndexState, stats: StoreStats) -> Dict[str, float]:
        tail_cap = int(state.data.get("tail_cap", 0))
        tail_load = self._tail_load(state, stats)
        return {
            "tail_load": float(tail_load),
            "tail_cap": float(tail_cap),
            "tail_fill_frac": tail_load / tail_cap if tail_cap else 0.0,
            "churn_since_build": float(self._churn_since_build(state, stats)),
            "built_size": float(state.built_size),
            "staleness_rows": float(stats.size - state.built_size),
        }


# -- registry ---------------------------------------------------------------
_REGISTRY: Dict[str, type] = {}


def register_backend(cls: type) -> type:
    """Class decorator: expose a backend under its ``name``."""
    _REGISTRY[cls.name] = cls
    return cls


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_backend(
    spec,
    *,
    sched: ProgressiveSchedule,
    metric: str = "l2",
    block_n: int = 65536,
    **opts,
) -> "IndexBackend":
    """Resolve a backend from a name (``'flat'``/``'ivf'``/``'quantized'``)
    or pass an already-constructed instance through."""
    if isinstance(spec, IndexBackend):
        if opts:
            raise ValueError(
                f"backend_opts {sorted(opts)} conflict with an "
                f"already-constructed backend instance"
            )
        return spec
    try:
        cls = _REGISTRY[spec]
    except KeyError:
        raise ValueError(
            f"unknown index backend {spec!r}; available: {backend_names()}"
        ) from None
    return cls(sched, metric=metric, block_n=block_n, **opts)

"""Load-adaptive search policy: degrade recall, not availability.

The paper's trade — dimensionality (and probe/pool width) for speed at a
chosen accuracy — becomes a control loop here instead of a constant.
:class:`AdaptivePolicy` maps the driver's measured queue pressure (depth
and queue-wait p95, both already collected for PR 7's telemetry) onto a
small integer *pressure level*; each level carries a
:class:`SearchOverrides` bundle of the knobs that are safe to move per
dispatch without recompiling:

* ``n_probe_frac`` — fraction of the IVF probe count to visit,
* ``oversample_frac`` — fraction of the PQ ADC oversample pool,
* ``sched`` — a degraded progressive schedule entered at a *smaller*
  ``d_start`` rung (cheaper full-corpus stage-0 scan, same final width).

Escalation is immediate (pressure is load-shedding, waiting makes the
queue worse); recovery is hysteretic — one level down only after the
queue has stayed calm for a continuous dwell (``hysteresis_s``), so the
policy doesn't flap around a threshold.  Every transition is counted and
mirrored into the obs registry at scrape time, same discipline as
``EngineStats``: plain ints are the source of truth, mutated only on the
driver thread; readers see them via ``summary()`` / ``/v1/stats`` or the
published Prometheus series.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Optional

from ..obs import NULL_INSTRUMENT

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (engine pkg)
    from ..core.schedule import ProgressiveSchedule
    from ..obs import MetricsRegistry
    from .config import AdaptiveConfig


@dataclass(frozen=True)
class SearchOverrides:
    """Per-dispatch search-knob overrides for one pressure level.

    Frozen and hashable on purpose: instances ride the engine's dispatch
    shape keys (one cached compiled program per (bucket, overrides)
    pair, pre-warmed by ``engine.warmup()``) and are passed to backends
    as an opaque ``overrides=`` kwarg — backends never import this
    module, they just read the attributes they can honour.
    """

    level: int = 0
    n_probe_frac: float = 1.0
    oversample_frac: float = 1.0
    sched: Optional["ProgressiveSchedule"] = None


class AdaptivePolicy:
    """Hysteretic queue-pressure → degradation-level controller.

    Single-writer: ``update()`` runs only on the driver thread (under the
    driver cv, next to where depth/wait are measured).  ``level`` is a
    plain int read lock-free by the submit path and the HTTP layer — a
    stale read is harmless (one request served at the neighbouring
    level).
    """

    def __init__(self, cfg: "AdaptiveConfig") -> None:
        self.cfg = cfg
        self.level = 0
        self.n_escalations = 0
        self.n_recoveries = 0
        self._calm_since: Optional[float] = None
        self._c_transitions = NULL_INSTRUMENT
        self._g_level = NULL_INSTRUMENT

    # -- thresholds ---------------------------------------------------
    def _entry_depth(self, level: int) -> float:
        return self.cfg.depth_high * self.cfg.escalate_factor ** (level - 1)

    def _entry_wait(self, level: int) -> Optional[float]:
        if self.cfg.wait_high_ms is None:
            return None
        return self.cfg.wait_high_ms * self.cfg.escalate_factor ** (level - 1)

    def target_level(self, depth: int, wait_p95_ms: Optional[float]) -> int:
        """Deepest level whose entry threshold the current pressure
        clears (depth OR wait — either signal alone escalates)."""
        target = 0
        for lvl in range(1, self.cfg.levels + 1):
            over = depth >= self._entry_depth(lvl)
            w = self._entry_wait(lvl)
            if not over and w is not None and wait_p95_ms is not None:
                over = wait_p95_ms >= w
            if over:
                target = lvl
            else:
                break
        return target

    # -- control loop -------------------------------------------------
    def update(self, depth: int, wait_p95_ms: Optional[float],
               now: float) -> int:
        """One controller step; returns the (possibly new) level.

        Escalate immediately to the deepest justified level; step DOWN
        one level at a time, and only after ``hysteresis_s`` seconds of
        continuous calm (pressure below ``recover_frac`` of the current
        level's entry threshold).  The calm timer resets whenever
        pressure reappears and after every downward step, so a recovery
        from level N to 0 takes N full dwells — deliberate damping.
        """
        target = self.target_level(depth, wait_p95_ms)
        if target > self.level:
            self.n_escalations += target - self.level
            self.level = target
            self._calm_since = None
            return self.level
        if self.level == 0:
            self._calm_since = None
            return 0
        calm = depth < self.cfg.recover_frac * self._entry_depth(self.level)
        w = self._entry_wait(self.level)
        if calm and w is not None and wait_p95_ms is not None:
            calm = wait_p95_ms < self.cfg.recover_frac * w
        if not calm:
            self._calm_since = None
            return self.level
        if self._calm_since is None:
            self._calm_since = now
        if now - self._calm_since >= self.cfg.hysteresis_s:
            self.level -= 1
            self.n_recoveries += 1
            self._calm_since = None  # next step down needs its own dwell
        return self.level

    # -- observability ------------------------------------------------
    def bind(self, registry: "MetricsRegistry") -> None:
        self._c_transitions = registry.counter(
            "repro_adaptive_transitions_total",
            "Pressure-level transitions (direction=up escalations, "
            "direction=down hysteretic recoveries)",
            labels=("direction",))
        self._g_level = registry.gauge(
            "repro_adaptive_level",
            "Current degradation level (0 = full-quality static config)")
        self.publish()

    def publish(self) -> None:
        """Scrape-time mirror — called from the driver's collector."""
        self._c_transitions.set_total(self.n_escalations, direction="up")
        self._c_transitions.set_total(self.n_recoveries, direction="down")
        self._g_level.set(self.level)

    def summary(self) -> Dict:
        return {
            "enabled": True,
            "level": self.level,
            "levels": self.cfg.levels,
            "n_escalations": self.n_escalations,
            "n_recoveries": self.n_recoveries,
            "depth_high": self.cfg.depth_high,
            "wait_high_ms": self.cfg.wait_high_ms,
            "hysteresis_s": self.cfg.hysteresis_s,
        }

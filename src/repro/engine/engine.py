"""RetrievalEngine: queued, shape-bucketed progressive search over a mutable
corpus.

The serving decomposition (standard for RAG retrieval backends — see the
surveys in PAPERS.md):

    submit() ──> RequestQueue ──> step(): pop chunk, pad to bucket,
                                          progressive_search over DocStore
                                          ──> per-request results + stats

* **Shape bucketing** — every dispatch shape is (bucket, capacity) for a
  bucket from a static ladder, so XLA compiles each bucket exactly once per
  corpus capacity; compile events are counted separately in the stats so
  latency percentiles aren't polluted by tracing time.
* **Mutable corpus** — ``add_docs`` / ``delete_docs`` mutate the DocStore's
  capacity-doubling buffers; the validity mask rides through every search
  stage, so a deleted doc can never be returned, even by an in-flight
  candidate list.
* **Pluggable index backends** — the search structure is an
  `repro.index_backends.IndexBackend` (``backend=`` config: ``'flat'``,
  ``'ivf'``, ``'quantized'``).  Backends declare staleness from the store's
  mutation counters; the engine rebuilds at a safe point between batches
  (synchronously, or on a background thread with ``rebuild_mode=
  'background'``) and atomically swaps the index state.  A rebuild doubles
  as tombstone compaction: past ``compact_dead_frac`` dead rows the store's
  buffers are rebuilt without tombstones (live doc ids are REMAPPED —
  ``on_remap`` callbacks let id-holding callers follow).
* **Observability** — per-request latency (queue + compute split), per-batch
  padding waste, rebuild/compaction counts, and a stage-by-stage timing
  profile (``profile_stages``) for roofline work.

The engine is synchronous and single-host by design: ``step()`` is the unit a
driver loop calls, and ``execute_batch()`` is the direct entry point the
async driver (`repro.engine.driver.EngineDriver`) uses for pre-formed
batches.  Every public mutating/serving method is guarded by ``engine.lock``
(a reentrant lock), so client threads may race ``add_docs`` / ``delete_docs``
/ ``submit`` / ``poll`` against the driver thread's dispatches — the lock is
coarse on purpose: one device, one in-flight batch, and stats counters that
must reconcile exactly under concurrency.  `repro.launch.serve` shows the
intended serving loop, and `benchmarks/engine_throughput.py` /
`benchmarks/backend_comparison.py` measure it.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    ProgressiveSchedule,
    make_schedule,
    rescore_candidates,
    stage_dims,
    truncated_search,
)
from repro.engine.adaptive import SearchOverrides
from repro.engine.batching import BucketPolicy, PendingRequest, RequestQueue, pad_batch
from repro.engine.config import EngineConfig, legacy_config
from repro.engine.faults import FaultPlan
from repro.engine.request import SearchRequest
from repro.engine.store import DocStore
from repro.engine.wal import MutationWAL, WALError
from repro.index_backends import IndexBackend, IndexState, make_backend
from repro.obs import (
    NULL_INSTRUMENT,
    MetricsRegistry,
    SlowQueryLog,
    TraceContext,
    TraceRing,
)

Array = jax.Array


class UnknownRequest(KeyError):
    """``poll`` was handed a request id the engine never issued."""


class IndexMismatch(ValueError):
    """A `load_index` / `recover` checkpoint disagrees with the live
    engine configuration (backend kind, embedding dim, metric, capacity) —
    raised eagerly instead of a downstream shape failure mid-search."""


class ResultEvicted(KeyError):
    """The request ran, but its result is no longer available.

    Either the client let it sit past the ``max_unpolled`` eviction bound,
    or it was already polled once (results pop), or it was served through
    the async driver's future path (which never parks results).  Distinct
    from ``poll`` returning None — that means "still pending, ask again" —
    and from ``UnknownRequest`` — that means "this id was never issued".
    A slow HTTP client can therefore tell "gone forever" from "bad id".
    """


@dataclasses.dataclass
class RequestStats:
    """Timing breakdown of one completed request."""

    latency_ms: float          # submit -> result ready
    queue_ms: float            # submit -> batch dispatch
    compute_ms: float          # batch dispatch -> device done (shared by batch)
    bucket: int                # static batch size the request rode in
    batch_fill: int            # real requests in that batch (<= bucket)
    compiled: bool             # this dispatch triggered an XLA compile
    # stage-split timings, present only under ``obs.stage_fences`` (the
    # fenced dispatch syncs once at the stage-0 boundary; the default fast
    # path stays fused and reports them as None)
    stage0_ms: Optional[float] = None     # dispatch -> stage-0 scan done
    rescore_ms: Optional[float] = None    # stage-0 done -> rescore done
    # full trace-mark offsets from submit (``TraceContext.spans_ms``);
    # None when ``obs.enabled=False``
    spans: Optional[Dict[str, float]] = None


@dataclasses.dataclass
class RetrievalResult:
    """Top-k neighbours for one request (k == the request's k, which
    defaults to — and never exceeds — ``engine.out_k``)."""

    request_id: int
    scores: np.ndarray         # (out_k,) ascending; +inf marks empty slots
    doc_ids: np.ndarray        # (out_k,) int32; -1 marks empty slots
    stats: RequestStats
    # DocStore.generation at dispatch.  A compaction bumps the generation
    # and remaps doc ids: a client that holds ids across corpus mutations
    # (concurrent serving) can compare this to the live store generation
    # under ``engine.lock`` to detect that its ids predate a remap it missed
    # (results still parked in ``poll`` are remapped by the engine itself).
    store_generation: int = -1
    # served straight from the driver's query cache (no dispatch ran)
    cached: bool = False
    # adaptive-policy pressure level the search ran at (0 = full quality)
    degraded_level: int = 0


# engine counter attribute -> (registry metric name, help text).  The
# attributes stay plain ``stats.n_x += 1`` call sites everywhere;
# ``EngineStats.publish`` mirrors the totals into the bound registry at
# scrape time (collector path), keeping the increment itself lock-free.
_ENGINE_COUNTERS = {
    "n_submitted": ("repro_engine_requests_submitted_total",
                    "Requests accepted via submit/execute_batch"),
    "n_completed": ("repro_engine_requests_completed_total",
                    "Requests completed with a result"),
    "n_batches": ("repro_engine_batches_total", "Batches dispatched"),
    "n_compiles": ("repro_engine_compiles_total",
                   "Dispatches that triggered an XLA compile"),
    "n_padded_slots": ("repro_engine_padded_slots_total",
                       "Padding rows dispatched (bucket minus fill)"),
    "n_docs_added": ("repro_engine_docs_added_total", "Documents appended"),
    "n_docs_deleted": ("repro_engine_docs_deleted_total",
                       "Documents tombstoned"),
    "n_rebuilds": ("repro_engine_rebuilds_total",
                   "Index (re)builds adopted"),
    "n_compactions": ("repro_engine_compactions_total",
                      "Store compactions run"),
    "n_rebuild_failures": ("repro_engine_rebuild_failures_total",
                           "Background index builds that raised (retried "
                           "at the next safe point)"),
    "n_recoveries": ("repro_engine_recoveries_total",
                     "Successful recover() runs (snapshot restore + WAL "
                     "replay)"),
    "n_replayed": ("repro_engine_wal_replayed_total",
                   "WAL records replayed across all recoveries"),
}


class EngineStats:
    """Aggregated engine counters + latency distributions.

    Distributions are kept in bounded ring buffers (``window`` most recent
    samples) so a long-lived serving loop doesn't grow memory per request;
    counters are lifetime totals.  ``bind(registry)`` allocates registry
    counters/histograms in a `repro.obs.MetricsRegistry`; the plain int
    attributes stay the source of truth (``summary()`` and every existing
    test read them unchanged, and they keep counting with observability
    disabled) — ``publish()`` mirrors them into the registry from the
    engine's scrape-time collector, so counting costs no registry lock.
    """

    def __init__(self, window: int = 16384) -> None:
        for name in _ENGINE_COUNTERS:
            setattr(self, name, 0)
        self._mirror: Dict[str, object] = {}
        self.h_latency = NULL_INSTRUMENT
        self.h_queue = NULL_INSTRUMENT
        self.h_compute = NULL_INSTRUMENT
        self.h_stage0 = NULL_INSTRUMENT
        self.h_rescore = NULL_INSTRUMENT
        self.h_rebuild = NULL_INSTRUMENT
        self.h_compact = NULL_INSTRUMENT
        self.c_batch_bucket = NULL_INSTRUMENT
        self.latency_ms: Deque[float] = deque(maxlen=window)
        self.queue_ms: Deque[float] = deque(maxlen=window)
        self.compute_ms: Deque[float] = deque(maxlen=window)
        self.bucket_counts: Dict[int, int] = {}

    def bind(self, registry: MetricsRegistry) -> None:
        """Mirror counters into ``registry`` and allocate histograms there
        (no-op instruments when the registry is disabled)."""
        for attr, (metric, help_text) in _ENGINE_COUNTERS.items():
            self._mirror[attr] = registry.counter(metric, help_text)
        self.h_latency = registry.histogram(
            "repro_engine_request_latency_ms",
            "Submit-to-result latency; observes every completed request "
            "(compiles included), so its _count equals "
            "repro_engine_requests_completed_total")
        self.h_queue = registry.histogram(
            "repro_engine_request_queue_ms", "Submit-to-dispatch wait")
        self.h_compute = registry.histogram(
            "repro_engine_batch_compute_ms",
            "Dispatch-to-device-done per batch")
        self.h_stage0 = registry.histogram(
            "repro_engine_stage0_ms",
            "Stage-0 scan span (obs.stage_fences only)")
        self.h_rescore = registry.histogram(
            "repro_engine_rescore_ms",
            "Rescore-ladder span (obs.stage_fences only)")
        self.h_rebuild = registry.histogram(
            "repro_engine_rebuild_ms", "Index build duration")
        self.h_compact = registry.histogram(
            "repro_engine_compact_ms", "Store compaction duration")
        self.c_batch_bucket = registry.counter(
            "repro_engine_batch_bucket_total",
            "Batches dispatched per static bucket size", labels=("bucket",))
        self.publish()

    def publish(self) -> None:
        """Mirror counter totals into the bound registry — called from the
        engine's scrape-time collector, never on the request path (the
        plain ints stay the source of truth)."""
        for attr, c in self._mirror.items():
            c.set_total(getattr(self, attr))
        cb = self.c_batch_bucket
        for bucket, n in self.bucket_counts.items():
            cb.set_total(n, bucket=bucket)

    def record_batch(self, bucket: int, fill: int, compute_ms: float,
                     compiled: bool) -> None:
        self.n_batches += 1
        self.n_padded_slots += bucket - fill
        self.n_compiles += int(compiled)
        self.h_compute.observe(compute_ms)
        if not compiled:
            self.compute_ms.append(compute_ms)
        self.bucket_counts[bucket] = self.bucket_counts.get(bucket, 0) + 1

    def record_request(self, st: RequestStats) -> None:
        self.record_requests((st,))

    def record_requests(self, sts) -> None:
        """Record a batch's completed requests in one pass — one registry
        lock round-trip per histogram instead of one per request (the
        obs-overhead budget is per-batch, not per-request)."""
        self.n_completed += len(sts)
        # registry histograms observe EVERY completed request — that keeps
        # the scrape invariant latency_ms_count == requests_completed_total
        self.h_latency.observe_many([st.latency_ms for st in sts])
        self.h_queue.observe_many([st.queue_ms for st in sts])
        if sts and sts[0].stage0_ms is not None:
            # batch-uniform: the fence timestamps come from one dispatch
            self.h_stage0.observe_many([st.stage0_ms for st in sts])
            self.h_rescore.observe_many([st.rescore_ms for st in sts])
        for st in sts:
            if st.compiled:
                # compile-inflated latencies would skew steady-state
                # p50/p95; compile events are tracked via n_compiles
                continue
            self.latency_ms.append(st.latency_ms)
            self.queue_ms.append(st.queue_ms)

    @staticmethod
    def _pct(xs, p: float) -> float:
        return float(np.percentile(list(xs), p)) if xs else float("nan")

    def summary(self) -> Dict:
        return {
            "n_submitted": self.n_submitted,
            "n_completed": self.n_completed,
            "n_batches": self.n_batches,
            "n_compiles": self.n_compiles,
            "n_padded_slots": self.n_padded_slots,
            "n_docs_added": self.n_docs_added,
            "n_docs_deleted": self.n_docs_deleted,
            "n_rebuilds": self.n_rebuilds,
            "n_compactions": self.n_compactions,
            "n_rebuild_failures": self.n_rebuild_failures,
            "n_recoveries": self.n_recoveries,
            "n_replayed": self.n_replayed,
            "latency_ms_p50": self._pct(self.latency_ms, 50),
            "latency_ms_p95": self._pct(self.latency_ms, 95),
            "queue_ms_p50": self._pct(self.queue_ms, 50),
            "compute_ms_p50": self._pct(self.compute_ms, 50),
            "bucket_counts": dict(sorted(self.bucket_counts.items())),
        }


class _BackgroundBuild:
    """One-slot background index build: launch, poll, adopt.

    jax arrays are immutable, so a build thread works on a consistent
    snapshot of the store's buffers while the main thread keeps serving
    (and even mutating the corpus — rows appended mid-build land above the
    snapshot's ``built_size`` and ride the new state's tail window; deletes
    are caught by the live validity mask at search time).
    """

    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self._out: Optional[IndexState] = None
        self._err: Optional[BaseException] = None

    @property
    def idle(self) -> bool:
        return self._thread is None

    @property
    def ready(self) -> bool:
        return self._thread is not None and not self._thread.is_alive()

    def launch(self, fn: Callable[[], IndexState]) -> None:
        assert self._thread is None, "build already in flight"
        self._out, self._err = None, None

        def run():
            try:
                self._out = fn()
            except BaseException as e:            # surfaced on take()
                self._err = e

        self._thread = threading.Thread(
            target=run, name="index-rebuild", daemon=True)
        self._thread.start()

    def take(self) -> Optional[IndexState]:
        """Join the finished thread and return its state (or re-raise)."""
        self._thread.join()
        self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        out, self._out = self._out, None
        return out


class RetrievalEngine:
    """Progressive-search serving engine over a mutable document corpus."""

    def __init__(
        self,
        d_emb: Optional[int] = None,
        *,
        config: Optional[EngineConfig] = None,
        schedule: Optional[ProgressiveSchedule] = None,
        dtype=jnp.float32,
        backend=None,
        **legacy_kwargs,
    ):
        """Construct from a typed ``EngineConfig`` — or the legacy kwargs.

        The blessed surface is ``RetrievalEngine(config=EngineConfig(...))``
        with a typed per-backend block (``FlatConfig``/``IVFConfig``/
        ``QuantizedConfig``).  The legacy keyword form — ``d_emb`` plus any
        of ``d_start``/``k0``/``final_k``/``buckets``/``capacity``/
        ``metric``/``block_n``/``max_unpolled``/``backend``/
        ``backend_opts``/``rebuild_mode``/``compact_dead_frac`` — still
        works: it is folded into the equivalent config through
        `repro.engine.config.legacy_config` (same defaults, now with eager
        option validation), so ``engine.config`` is populated either way.

        ``schedule`` (an explicit ``ProgressiveSchedule`` overriding the
        d_start/k0/final_k derivation), ``dtype`` (device buffer dtype) and
        a pre-constructed ``IndexBackend`` instance as ``backend`` remain
        engine-level arguments — they hold live objects and don't serialize.
        """
        backend_instance: Optional[IndexBackend] = None
        if isinstance(backend, IndexBackend):
            backend_instance, backend = backend, None
            if config is not None:
                raise ValueError(
                    "pass a pre-constructed IndexBackend instance OR a "
                    "config, not both")
            if legacy_kwargs.get("backend_opts") is not None:
                raise ValueError(
                    f"backend_opts {sorted(legacy_kwargs['backend_opts'])} "
                    f"conflict with an already-constructed backend instance")
        if config is None:
            if d_emb is None:
                raise ValueError(
                    "RetrievalEngine needs d_emb (legacy kwargs) or "
                    "config=EngineConfig(...)")
            if backend is not None:
                legacy_kwargs["backend"] = backend
            config = legacy_config(int(d_emb), **legacy_kwargs)
            if backend_instance is not None:
                # the instance itself is wired below; the config records its
                # name only (it may be a user-registered backend the typed
                # config registry has never heard of)
                from repro.engine.config import CustomBackendConfig
                config = dataclasses.replace(
                    config,
                    backend=CustomBackendConfig(backend_instance.name))
        else:
            if legacy_kwargs or backend is not None:
                extra = sorted(legacy_kwargs) + (
                    ["backend"] if backend is not None else [])
                raise ValueError(
                    f"config=EngineConfig(...) conflicts with legacy "
                    f"kwarg(s) {extra}; set them on the config")
            if d_emb is not None and int(d_emb) != config.d_emb:
                raise ValueError(
                    f"d_emb={d_emb} conflicts with config.d_emb="
                    f"{config.d_emb}")
        self.config = config

        self.sched = schedule or make_schedule(
            config.d_start, config.d_emb, config.k0, final_k=config.final_k
        )
        if self.sched.d_max > config.d_emb:
            raise ValueError(
                f"schedule d_max={self.sched.d_max} exceeds "
                f"d_emb={config.d_emb}"
            )
        self.dims = stage_dims(self.sched)
        # actual result width: progressive_search returns stages[-1].k
        # columns (a single-stage schedule keeps k0); slice to final_k so the
        # engine's documented contract holds for every schedule shape
        self.out_k = min(self.sched.final_k, self.sched.stages[-1].k)
        # -- adaptive degradation ladder: one SearchOverrides per pressure
        # level.  A degraded schedule enters the ladder at a LOWER d_start
        # rung (cheaper full-corpus stage-0, same d_max and final_k — the
        # result width never moves), so its stage dims are unioned into
        # self.dims and the store precomputes their sq-prefix columns too
        # (falling back to on-the-fly norms would negate the savings).
        # With adaptive disabled this loop never runs: dims, store layout
        # and every compiled program stay byte-identical to the static path.
        acfg = config.adaptive
        self._level_overrides: Dict[int, SearchOverrides] = {}
        if acfg.enabled:
            all_dims = set(self.dims)
            for lvl in range(1, acfg.levels + 1):
                d_deg = max(acfg.min_d_start,
                            self.sched.d_start >> (lvl * acfg.d_start_shift))
                d_deg = min(d_deg, self.sched.d_start)
                sched_l = None
                if d_deg < self.sched.d_start:
                    sched_l = make_schedule(
                        d_deg, self.sched.d_max, self.sched.k0,
                        final_k=self.sched.final_k)
                    all_dims.update(stage_dims(sched_l))
                self._level_overrides[lvl] = SearchOverrides(
                    level=lvl,
                    n_probe_frac=acfg.n_probe_scale ** lvl,
                    oversample_frac=acfg.oversample_scale ** lvl,
                    sched=sched_l,
                )
            self.dims = tuple(sorted(all_dims))
        self.metric = config.metric
        self.block_n = int(config.block_n)
        self.store = DocStore(config.d_emb, self.dims,
                              capacity=config.capacity, dtype=dtype)
        self.policy = BucketPolicy(config.buckets)
        self.stats = EngineStats()
        # Guards every store/queue/stats mutation and every dispatch: client
        # threads and the async driver thread share the engine through it.
        # Reentrant because step() -> maybe_rebuild() nests, and so callers
        # can compose multi-step critical sections (see EngineDriver).
        self.lock = threading.RLock()
        self._queue = RequestQueue()
        # Completed-but-unpolled results are evicted oldest-first (dicts are
        # insertion-ordered) past max_unpolled, so clients that die between
        # submit() and poll() can't leak memory in a long-lived serving loop
        # (poll() then raises ResultEvicted — distinct from an unknown id).
        self._results: Dict[int, RetrievalResult] = {}
        self._max_unpolled = int(config.max_unpolled)
        self._next_rid = 0
        # queue-path rids not yet parked in _results: lets poll() tell
        # "still pending" (None) from "evicted/consumed" (ResultEvicted)
        self._pending_rids: set = set()
        self._seen_shapes: set = set()

        # -- observability spine: one registry per engine (the driver and
        # HTTP server attach their instruments to it), a bounded ring of
        # recent request traces, and the slow-query log
        obs = config.obs
        self.metrics = MetricsRegistry(enabled=obs.enabled)
        self.stats.bind(self.metrics)
        self.trace_ring = TraceRing(obs.trace_ring)
        self.slow_log = SlowQueryLog(obs.slow_query_ms)
        self._obs_enabled = bool(obs.enabled)
        self._stage_fences = bool(obs.stage_fences and obs.enabled)
        self._c_slow = self.metrics.counter(
            "repro_slow_queries_total",
            "Requests over obs.slow_query_ms (also emitted to the "
            "repro.obs.slowquery logger)")
        self._g_queue_depth = self.metrics.gauge(
            "repro_engine_queue_depth",
            "Requests parked in the engine's own queue")
        self._g_store = self.metrics.gauge(
            "repro_store_state", "DocStore occupancy snapshot",
            labels=("key",))
        self._g_backend = self.metrics.gauge(
            "repro_backend_state",
            "Backend-declared index state gauges (IndexBackend.gauges)",
            labels=("backend", "key"))
        self._c_mask_hits = self.metrics.counter(
            "repro_store_mask_cache_hits_total",
            "Compiled tenant/filter mask cache hits")
        self._c_mask_misses = self.metrics.counter(
            "repro_store_mask_cache_misses_total",
            "Compiled tenant/filter mask cache misses (mask recompiles)")
        self.metrics.register_collector(self._collect_metrics)

        self.backend: IndexBackend = (
            backend_instance if backend_instance is not None
            else make_backend(
                config.backend.name, sched=self.sched, metric=config.metric,
                block_n=self.block_n, **config.backend.opts(),
            ))
        if self._level_overrides and self.dims != self.backend.dims:
            # adaptive added degraded-schedule dims: backends look up
            # sq-prefix columns BY VALUE (dims.index), so handing them the
            # store's superset tuple keeps every lookup exact while the
            # degraded stage-0 dims gain precomputed norms too
            self.backend.dims = self.dims
        self.rebuild_mode = config.rebuild_mode
        self.compact_dead_frac = config.compact_dead_frac
        self.on_remap: List[Callable[[np.ndarray], None]] = []
        self._index_state: Optional[IndexState] = None
        self._bg = _BackgroundBuild()
        # states built from pre-compaction buffers hold remapped-away ids;
        # any state older than this store generation must never be adopted
        self._min_state_generation = 0

        # -- fault tolerance: injection plan (inert unless configured), the
        # mutation WAL (None until enable_durability/recover), and the last
        # recovery report for /healthz?deep=1
        fcfg = config.fault
        self.faults = FaultPlan.parse(fcfg.inject, seed=fcfg.inject_seed)
        self.wal: Optional[MutationWAL] = None
        self.ckpt_dir: Optional[str] = None
        self.last_recovery: Optional[Dict] = None
        self._rebuild_fail_streak = 0
        self._g_wal = self.metrics.gauge(
            "repro_wal_state",
            "Mutation-WAL state (last_seq / lag_records / n_segments)",
            labels=("key",))

    # -- corpus mutation -----------------------------------------------------
    def add_docs(self, vectors, *, tenant: Optional[str] = None,
                 metadata=None) -> np.ndarray:
        """Append document embeddings; returns their stable doc ids.

        ``tenant`` namespaces the rows (searches with ``tenant=`` see only
        their own namespace); ``metadata`` — one dict or a per-row sequence
        of dicts — feeds the per-request filter masks.

        With durability enabled the mutation is WAL-logged (fsync'd) BEFORE
        it is applied or acknowledged: a crash after return can never lose
        it, and a crash before the append means the caller never saw an ack.
        """
        with self.lock:
            if self.wal is not None:
                vec = np.asarray(vectors, np.float32)
                if vec.ndim == 1:
                    vec = vec[None, :]
                if vec.ndim != 2 or vec.shape[1] != self.store.d_emb:
                    raise ValueError(
                        f"expected (B, {self.store.d_emb}) vectors, got "
                        f"shape {vec.shape}")
                # validate metadata BEFORE logging: a record that would be
                # rejected by the store must never enter the log (replay
                # would diverge on it)
                meta_rows = DocStore._check_metadata(metadata, vec.shape[0])
                self.faults.check("wal_write")
                self.wal.append("add", {
                    "start": self.store.size,
                    "v": vec.tobytes(),
                    "shape": list(vec.shape),
                    "tenant": tenant,
                    "metadata": meta_rows,
                })
            ids = self.store.add(vectors, tenant=tenant, metadata=metadata)
            self.stats.n_docs_added += len(ids)
            return ids

    def delete_docs(self, ids) -> int:
        """Tombstone docs by id; they become unreturnable immediately.

        WAL-logged before application, like ``add_docs``."""
        with self.lock:
            if self.wal is not None:
                id_arr = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
                if id_arr.size and (id_arr.min() < 0
                                    or id_arr.max() >= self.store.size):
                    raise IndexError(
                        f"doc ids must be in [0, {self.store.size}), got "
                        f"[{id_arr.min()}, {id_arr.max()}]")
                self.faults.check("wal_write")
                self.wal.append("delete", {"ids": id_arr.tolist()})
            n = self.store.delete(ids)
            self.stats.n_docs_deleted += n
            return n

    @property
    def n_docs(self) -> int:
        with self.lock:
            return self.store.n_active

    # -- index lifecycle -----------------------------------------------------
    def _build_state(self) -> IndexState:
        store = self.store
        self.faults.check("rebuild")
        t0 = time.perf_counter()
        state = self.backend.build(
            store.db, store.valid, sq_prefix=store.sq_prefix,
            stats=store.stats(),
        )
        self.stats.h_rebuild.observe((time.perf_counter() - t0) * 1e3)
        return state

    def _ensure_index(self) -> IndexState:
        if self._index_state is None:
            self._index_state = self._build_state()
            self.stats.n_rebuilds += 1
        return self._index_state

    def _compact(self) -> None:
        """Compact the store and remap every id the engine still holds."""
        if self.wal is not None:
            # compaction is deterministic given the buffers, so the record
            # carries no payload: replay just re-runs store.compact() at
            # the same point in the mutation sequence
            self.wal.append("compact", {})
        t0 = time.perf_counter()
        id_map = self.store.compact()
        self.stats.h_compact.observe((time.perf_counter() - t0) * 1e3)
        self.stats.n_compactions += 1
        self._min_state_generation = self.store.generation
        for res in self._results.values():       # unpolled results follow
            old = res.doc_ids
            res.doc_ids = np.where(
                old >= 0, id_map[np.maximum(old, 0)], -1
            ).astype(old.dtype)
        for cb in self.on_remap:
            cb(id_map)

    def maybe_rebuild(self, *, force: bool = False) -> bool:
        """Rebuild/compact at a safe point if the index state warrants it.

        Called automatically before every dispatch (``step`` /
        ``execute_batch`` / ``search`` / ``warmup``) — under the async driver
        this is what makes rebuild adoption and compaction land *between*
        driver iterations, never mid-batch.  Callable directly to force a
        rebuild.  Returns True if a new state was adopted (or a background
        build launched).
        """
        with self.lock:
            return self._maybe_rebuild_locked(force=force)

    def _maybe_rebuild_locked(self, *, force: bool = False) -> bool:
        # adopt a finished background build first — cheap, and it may
        # satisfy the staleness check below
        adopted = False
        if self._bg.ready:
            try:
                new = self._bg.take()
            except Exception as e:
                # a failed background build must not fail the innocent
                # batch that happened to hit this safe point: count it,
                # leave the old state serving, and let the staleness check
                # below relaunch.  Only a persistent crash loop escalates.
                self.stats.n_rebuild_failures += 1
                self._rebuild_fail_streak += 1
                if (self._rebuild_fail_streak
                        > self.config.fault.rebuild_retries):
                    raise RuntimeError(
                        f"background index rebuild failed "
                        f"{self._rebuild_fail_streak} times in a row"
                    ) from e
                new = None
            else:
                if new is not None:
                    self._rebuild_fail_streak = 0
            # never adopt a state older than what is already serving: a
            # must/forced sync rebuild may have landed while the thread ran
            # (and compaction bumps the floor: pre-compaction ids are dead)
            if (new is not None
                    and new.generation >= self._min_state_generation
                    and (self._index_state is None
                         or new.generation > self._index_state.generation)):
                self._index_state = new
                self.stats.n_rebuilds += 1
                adopted = True

        # incremental maintenance first: backends that can absorb appended
        # rows into the live index (IVF nearest-centroid spare slots) do it
        # here, at the same safe point — absorbed rows stop counting against
        # the tail window, so the staleness checks below see the post-absorb
        # load and append-heavy workloads stop forcing early rebuilds
        if self._index_state is not None:
            store = self.store
            self.backend.absorb_appends(
                self._index_state, store.db, store.valid,
                sq_prefix=store.sq_prefix, stats=store.stats(),
            )

        st = self.store.stats()
        state = self._index_state
        must = state is not None and self.backend.must_rebuild(state, st)
        stale = (state is None or must
                 or self.backend.needs_rebuild(state, st))
        wants_compact = (
            self.compact_dead_frac is not None
            and st.n_dead > 0
            and st.dead_frac >= self.compact_dead_frac
        )
        if self.rebuild_mode == "off" and not (must or state is None or force):
            return adopted
        if not (force or stale or wants_compact):
            return adopted

        if wants_compact:
            # compaction invalidates every id a pre-compaction state holds:
            # it must pair with an immediate synchronous rebuild.  The
            # rebuild lives in a finally so a raising on_remap callback
            # cannot leave the old state serving remapped buffers (it would
            # silently return wrong documents); the callback's exception
            # still propagates to the caller afterwards.
            self._index_state = None
            try:
                self._compact()
            finally:
                self._index_state = self._build_state()
                self.stats.n_rebuilds += 1
            return True
        if state is None:
            self._ensure_index()                  # first build is sync
            return True
        if self.rebuild_mode == "background" and not must and not force:
            if self._bg.idle:
                # snapshot on THIS thread so (buffers, stats) are a
                # consistent pair even if the corpus mutates mid-build
                store = self.store
                db, valid = store.db, store.valid
                sq, snap = store.sq_prefix, store.stats()
                h_rebuild = self.stats.h_rebuild

                def _bg_build():
                    self.faults.check("rebuild")
                    t0 = time.perf_counter()
                    state = self.backend.build(
                        db, valid, sq_prefix=sq, stats=snap)
                    h_rebuild.observe((time.perf_counter() - t0) * 1e3)
                    return state

                self._bg.launch(_bg_build)
                return True
            return adopted                        # build already in flight
        # sync (or correctness-mandated while a background build lags)
        self._index_state = self._build_state()
        self.stats.n_rebuilds += 1
        return True

    @property
    def index_state(self) -> Optional[IndexState]:
        """The live index state (None until the first build)."""
        return self._index_state

    # -- index persistence ---------------------------------------------------
    def save_index(self, ckpt_dir: str, *, keep: int = 3) -> str:
        """Persist the live index state through `repro.checkpoint`.

        Writes the backend's ``state_dict`` (centroids, packed member
        slabs, int8 scales, PQ codebooks — whatever the backend built)
        with the atomic tmp-dir + fsynced-manifest protocol, so a serving
        restart can `load_index` instead of re-running k-means / codebook
        builds.  Builds the index first if none is live yet.
        """
        from repro.checkpoint import save_arrays

        with self.lock:
            state = self._ensure_index()
            payload = self.backend.state_dict(state)
            extra = dict(payload["meta"])
            extra["engine_meta"] = self._index_meta()
            return save_arrays(
                ckpt_dir, state.generation, payload["arrays"],
                extra=extra, keep=keep)

    def _index_meta(self) -> Dict:
        """The engine-identity fingerprint recorded next to persisted index
        state, so a restart with a different configuration fails loudly."""
        return {
            "backend": self.backend.name,
            "d_emb": self.store.d_emb,
            "capacity": self.store.capacity,
            "metric": self.metric,
        }

    def _check_index_meta(self, saved: Optional[Dict], where: str,
                          keys: Tuple[str, ...] = ("backend", "d_emb",
                                                   "metric"),
                          ) -> None:
        """Raise ``IndexMismatch`` when ``saved`` (an ``engine_meta`` dict)
        disagrees with the live engine.  Only identity keys are compared —
        ``capacity`` rides along in the meta for diagnostics but is a
        dynamic buffer size (it doubles with corpus growth; restore adopts
        the snapshot's), not identity.  Pre-``engine_meta`` checkpoints
        (saved is None) skip the check for back-compat."""
        if not saved:
            return
        live = self._index_meta()
        diffs = [
            f"{key}: checkpoint has {saved[key]!r}, engine has "
            f"{live[key]!r}"
            for key in keys
            if key in saved and saved[key] != live[key]
        ]
        if diffs:
            raise IndexMismatch(
                f"{where} does not match the live EngineConfig — "
                + "; ".join(diffs))

    def load_index(self, ckpt_dir: str, *, step: Optional[int] = None) -> bool:
        """Adopt a `save_index` checkpoint as the live index state.

        Contract: the store must already hold the same rows
        ``[0, built_size)`` the checkpoint was built over (the usual
        serving restart re-adds the identical corpus before loading).
        Rows added beyond that ride the tail window exactly like rows
        appended after a build; staleness counters restart clean.  Returns
        False when ``ckpt_dir`` holds no checkpoint; raises
        ``IndexMismatch`` when the checkpoint was saved under a different
        backend kind / embedding dim / capacity / metric, and
        ``CorruptCheckpoint`` when the newest step fails verification.
        """
        from repro.checkpoint import load_arrays

        arrays, meta, _ = load_arrays(ckpt_dir, step=step)
        if arrays is None:
            return False
        with self.lock:
            self._check_index_meta(meta.get("engine_meta"),
                                   f"index checkpoint in {ckpt_dir}")
            store = self.store
            state = self.backend.load_state(
                {"meta": meta, "arrays": arrays},
                db=store.db, valid=store.valid, sq_prefix=store.sq_prefix,
                stats=store.stats(),
            )
            self._index_state = state
            return True

    # -- durability: WAL + snapshots + crash recovery ------------------------
    def enable_durability(self, ckpt_dir: str) -> None:
        """Open (or create) the mutation WAL under ``ckpt_dir/wal``.

        From this point every ``add_docs`` / ``delete_docs`` / compaction
        is logged-then-applied, so ``recover(ckpt_dir)`` in a fresh process
        reconstructs the acknowledged corpus exactly.  ``recover`` calls
        this implicitly; call it directly on a brand-new deployment.
        """
        import os

        with self.lock:
            if self.wal is not None:
                return
            os.makedirs(ckpt_dir, exist_ok=True)
            self.ckpt_dir = ckpt_dir
            self.wal = MutationWAL(
                os.path.join(ckpt_dir, "wal"),
                fsync=self.config.fault.wal_fsync)

    def save_snapshot(self, *, keep: Optional[int] = None) -> str:
        """Durably snapshot store + index state; rotate and prune the WAL.

        The snapshot captures the corpus at WAL seq S (its step number IS
        S, so steps are unique and monotonic across restarts); recovery
        restores the newest valid snapshot and replays records with
        ``seq > S``.  Old WAL segments are pruned only past the *oldest
        retained* snapshot, so a torn-newest fallback still replays.
        """
        from repro.checkpoint import all_steps, save_arrays

        with self.lock:
            if self.wal is None or self.ckpt_dir is None:
                raise RuntimeError(
                    "durability is not enabled — call "
                    "enable_durability(ckpt_dir) or recover(ckpt_dir) first")
            self.faults.check("ckpt_save")
            keep = self.config.fault.snapshot_keep if keep is None else keep
            wal_seq = self.wal.last_seq
            store_arrays, store_meta = self.store.snapshot_state()
            arrays = {f"store/{k}": v for k, v in store_arrays.items()}
            extra: Dict = {
                "wal_seq": wal_seq,
                "store_meta": store_meta,
                "engine_meta": self._index_meta(),
            }
            state = self._index_state
            if state is not None:
                payload = self.backend.state_dict(state)
                arrays.update(
                    {f"index/{k}": v for k, v in payload["arrays"].items()})
                extra["index_meta"] = payload["meta"]
            # step number = wal seq + 1 so the empty-log snapshot (seq -1)
            # still gets a valid step 0
            path = save_arrays(self.ckpt_dir, wal_seq + 1, arrays,
                               extra=extra, keep=keep)
            self.wal.rotate()
            steps = all_steps(self.ckpt_dir)
            if steps:
                self.wal.prune(min(steps) - 1)
            return path

    def recover(self, ckpt_dir: str) -> Dict:
        """Restore state from ``ckpt_dir``: newest valid snapshot + WAL tail.

        Walks snapshots newest-to-oldest, skipping any that fail checksum
        verification (``CorruptCheckpoint``); restores the store and index
        from the first valid one; then replays every WAL record past that
        snapshot's sequence number.  A torn WAL tail (crash mid-append)
        truncates cleanly — the lost suffix was never acknowledged.  Leaves
        durability enabled and returns a report dict (also kept as
        ``engine.last_recovery`` for ``/healthz?deep=1``).
        """
        import os

        t0 = time.perf_counter()
        with self.lock:
            self.faults.check("ckpt_load")
            report: Dict = {
                "status": "ok", "snapshot_step": None, "fallbacks": 0,
                "replayed": 0, "wal_truncated": False, "duration_ms": 0.0,
            }
            wal_seq = self._restore_newest_snapshot(ckpt_dir, report)
            # open the WAL (truncating any torn tail) and replay the rest
            os.makedirs(ckpt_dir, exist_ok=True)
            self.ckpt_dir = ckpt_dir
            self.wal = MutationWAL(
                os.path.join(ckpt_dir, "wal"),
                fsync=self.config.fault.wal_fsync)
            for rec in self.wal.replay(after_seq=wal_seq):
                self._apply_record(rec)
                report["replayed"] += 1
            report["wal_truncated"] = self.wal.torn_tail
            report["duration_ms"] = (time.perf_counter() - t0) * 1e3
            self.stats.n_recoveries += 1
            self.stats.n_replayed += report["replayed"]
            self.last_recovery = report
            return report

    def _restore_newest_snapshot(self, ckpt_dir: str, report: Dict) -> int:
        """Restore store + index from the newest checksum-valid snapshot
        under ``ckpt_dir`` (corrupt snapshots fall back a step).

        Shared by ``recover`` (primary restart) and the replication
        follower bootstrap — the follower restores read-only and must NOT
        open the WAL, so this helper deliberately touches neither
        ``self.wal`` nor ``self.ckpt_dir``.  Mutates ``report``
        (``snapshot_step`` / ``fallbacks``) and returns the snapshot's WAL
        seq, -1 when no usable snapshot exists.  Caller holds the lock.
        """
        from repro.checkpoint import CorruptCheckpoint, all_steps, load_arrays

        loaded = None
        for step in sorted(all_steps(ckpt_dir), reverse=True):
            try:
                arrays, extra, _ = load_arrays(ckpt_dir, step=step)
            except CorruptCheckpoint:
                report["fallbacks"] += 1
                continue
            loaded = (step, arrays, extra)
            break
        if loaded is None:
            return -1
        step, arrays, extra = loaded
        # capacity is NOT checked here: restore_state adopts the
        # snapshot's buffer capacity, so only identity keys matter
        self._check_index_meta(extra.get("engine_meta"),
                               f"snapshot step {step} in {ckpt_dir}",
                               keys=("backend", "d_emb", "metric"))
        store_arrays = {
            k[len("store/"):]: v for k, v in arrays.items()
            if k.startswith("store/")}
        self.store.restore_state(store_arrays, extra["store_meta"])
        self._index_state = None
        self._min_state_generation = 0
        index_arrays = {
            k[len("index/"):]: v for k, v in arrays.items()
            if k.startswith("index/")}
        if index_arrays and "index_meta" in extra:
            self._index_state = self.backend.load_state(
                {"meta": extra["index_meta"],
                 "arrays": index_arrays},
                db=self.store.db, valid=self.store.valid,
                sq_prefix=self.store.sq_prefix,
                stats=self.store.stats(),
            )
        report["snapshot_step"] = step
        return int(extra["wal_seq"])

    def apply_replicated(self, rec) -> None:
        """Apply one WAL record shipped from a primary (follower path).

        Goes through the exact same ``_apply_record`` used by crash
        recovery — tail injection, capacity doubling, and rebuild
        scheduling all behave as if the mutation happened locally — but is
        never re-logged: a follower must not own a WAL over the primary's
        log directory (it would truncate or extend the live segment).
        """
        with self.lock:
            if self.wal is not None:
                raise WALError(
                    "apply_replicated on an engine with its own WAL open — "
                    "followers replicate, they do not log")
            self._apply_record(rec)

    def _apply_record(self, rec) -> None:
        """Re-apply one WAL record during recovery (never re-logged)."""
        store = self.store
        if rec.kind == "add":
            p = rec.payload
            if int(p["start"]) != store.size:
                raise WALError(
                    f"WAL replay divergence at seq {rec.seq}: record "
                    f"expects start id {p['start']}, store is at "
                    f"{store.size}")
            vec = np.frombuffer(p["v"], np.float32).reshape(p["shape"])
            meta_rows = p.get("metadata")
            store.add(vec, tenant=p.get("tenant"), metadata=meta_rows)
            self.stats.n_docs_added += int(p["shape"][0])
        elif rec.kind == "delete":
            n = store.delete(np.asarray(rec.payload["ids"], np.int64))
            self.stats.n_docs_deleted += n
        elif rec.kind == "compact":
            # a replayed compaction invalidates any snapshot-loaded index
            # state (its ids predate the remap); the next dispatch rebuilds
            store.compact()
            self.stats.n_compactions += 1
            self._index_state = None
            self._min_state_generation = store.generation
        else:
            raise WALError(
                f"unknown WAL record kind {rec.kind!r} at seq {rec.seq}")

    # -- request path --------------------------------------------------------
    def check_query(self, query) -> np.ndarray:
        """Validate/normalize one query to a (D,) float32 vector (no lock)."""
        q = np.asarray(query, np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1 or q.shape[0] != self.store.d_emb:
            raise ValueError(
                f"expected one (D={self.store.d_emb},) query vector, got "
                f"shape {q.shape}"
            )
        return q

    def check_request(self, request) -> PendingRequest:
        """Validate a raw query vector or `SearchRequest` into an unstamped
        ``PendingRequest`` (no lock; request_id assigned at enqueue).

        This is the one normalization point for the typed request surface —
        the engine's own ``submit``/``search`` and the async driver both
        route through it, so a raw array behaves exactly like
        ``SearchRequest(query)`` everywhere.
        """
        if not isinstance(request, SearchRequest):
            request = SearchRequest(request)
        q = self.check_query(request.query)
        k = self.out_k if request.k is None else int(request.k)
        if not 1 <= k <= self.out_k:
            raise ValueError(
                f"k={k} outside [1, {self.out_k}]; the engine dispatches a "
                f"static result width — configure final_k for the largest "
                f"k it should serve")
        mask_key = self.store.compile_mask(request.tenant, request.filter)
        now = time.perf_counter()
        deadline = (None if request.deadline_ms is None
                    else now + float(request.deadline_ms) / 1e3)
        trace = TraceContext(now) if self._obs_enabled else None
        return PendingRequest(-1, q, now, k=k, mask_key=mask_key,
                              deadline=deadline, trace=trace)

    def submit(self, request) -> int:
        """Enqueue one request — a raw (D,)/(1, D) query vector or a
        `SearchRequest` carrying per-request k/tenant/filter — and return a
        request id for ``poll``.  (The async driver does not pass through
        here — it forms its own batches and enters via ``execute_batch``,
        stamping each request's client-side submit time itself.)"""
        req = self.check_request(request)
        with self.lock:
            req.request_id = self._next_rid
            self._next_rid += 1
            self._queue.push(req)
            if req.trace is not None:
                req.trace.mark("admit")
            self._pending_rids.add(req.request_id)
            self.stats.n_submitted += 1
            return req.request_id

    def poll(self, request_id: int) -> Optional[RetrievalResult]:
        """Pop the result for ``request_id`` if its batch has run.

        Returns None while the request is still pending.  Raises
        ``UnknownRequest`` for an id the engine never issued, and
        ``ResultEvicted`` for one whose result is gone — evicted past
        ``max_unpolled``, already polled (results pop once), or served
        through the driver's future path.  A slow client can therefore
        distinguish "ask again" (None) from "gone forever" from "bad id".
        """
        with self.lock:
            res = self._results.pop(request_id, None)
            if res is not None:
                return res
            if not 0 <= int(request_id) < self._next_rid:
                raise UnknownRequest(
                    f"request id {request_id} was never issued "
                    f"(ids so far: [0, {self._next_rid}))")
            if request_id in self._pending_rids:
                return None
            raise ResultEvicted(
                f"request {request_id} has no parked result: it was "
                f"evicted, already polled, or driver-served")

    @property
    def n_pending(self) -> int:
        with self.lock:
            return len(self._queue)

    def _execute(self, reqs: List[PendingRequest],
                 overrides: Optional[SearchOverrides] = None,
                 ) -> List[RetrievalResult]:
        """Run one bucket-shaped batch (caller holds ``self.lock``).

        Every request in the chunk must share one ``mask_key`` — the batch
        dispatches with a single row bitmask AND-ed into the validity mask.
        ``step``/``execute_batch`` group by key before calling here.
        ``overrides`` (adaptive policy) degrades the whole batch's search
        knobs; ``None`` is the static full-quality path.
        """
        self._maybe_rebuild_locked()              # safe point between batches
        # compile AFTER the rebuild safe point: appends/compaction already
        # landed, so the mask matches the buffers this dispatch will scan
        mask = self.store.mask_for_key(reqs[0].mask_key)
        bucket = self.policy.bucket_for(len(reqs))
        t_dispatch = time.perf_counter()
        qb = pad_batch(np.stack([r.query for r in reqs]), bucket)
        if self._stage_fences:
            scores, ids, compiled, t_stage0 = self._dispatch_fenced(
                qb, mask=mask, overrides=overrides)
        else:
            scores, ids, compiled = self._dispatch(
                qb, mask=mask, overrides=overrides)
            t_stage0 = None
        t_done = time.perf_counter()
        compute_ms = (t_done - t_dispatch) * 1e3
        stage0_ms = (None if t_stage0 is None
                     else (t_stage0 - t_dispatch) * 1e3)
        rescore_ms = (None if t_stage0 is None
                      else (t_done - t_stage0) * 1e3)
        self.stats.record_batch(bucket, len(reqs), compute_ms, compiled)
        out = []
        sts = []
        records = []
        for j, r in enumerate(reqs):
            spans = None
            if r.trace is not None:
                # inline span build (pipeline order): this loop runs per
                # request under engine.lock, so it stays call-free —
                # dispatch/deliver go straight into the spans dict instead
                # of through mark()/spans_ms()
                m = r.trace.marks
                t0_req = m["submit"]
                spans = {"submit": 0.0}
                t = m.get("admit")
                if t is not None:
                    spans["admit"] = (t - t0_req) * 1e3
                t = m.get("batch")
                if t is not None:
                    spans["batch"] = (t - t0_req) * 1e3
                spans["dispatch"] = (t_dispatch - t0_req) * 1e3
                if t_stage0 is not None:
                    spans["stage0"] = (t_stage0 - t0_req) * 1e3
                    spans["rescore"] = (t_done - t0_req) * 1e3
                spans["deliver"] = (t_done - t0_req) * 1e3
            st = RequestStats(
                latency_ms=(t_done - r.t_submit) * 1e3,
                queue_ms=(t_dispatch - r.t_submit) * 1e3,
                compute_ms=compute_ms,
                bucket=bucket,
                batch_fill=len(reqs),
                compiled=compiled,
                stage0_ms=stage0_ms,
                rescore_ms=rescore_ms,
                spans=spans,
            )
            sts.append(st)
            k = self.out_k if r.k is None else r.k
            out.append(RetrievalResult(
                r.request_id, scores[j][:k], ids[j][:k], st,
                store_generation=self.store.generation,
                degraded_level=0 if overrides is None else overrides.level,
            ))
            if spans is not None:
                records.append({
                    "request_id": r.request_id,
                    "latency_ms": st.latency_ms,
                    "queue_ms": st.queue_ms,
                    "compute_ms": compute_ms,
                    "bucket": bucket,
                    "batch_fill": len(reqs),
                    "compiled": compiled,
                    "spans": spans,
                })
        self.stats.record_requests(sts)
        if records:
            self.trace_ring.push_many(records)
            if self.slow_log.enabled:
                n_slow = sum(self.slow_log.maybe_log(rec)
                             for rec in records)
                if n_slow:
                    self._c_slow.inc(n_slow)
        return out

    def step(self) -> int:
        """Dispatch one bucket-shaped batch from the queue head.

        Requests sharing the head's (tenant, filter) mask key batch
        together; others stay queued for the next ``step`` in arrival
        order.  Returns the number of requests completed (0 if the queue
        is empty).
        """
        with self.lock:
            n = len(self._queue)
            if n == 0:
                return 0
            bucket = self.policy.bucket_for(min(n, self.policy.max_size))
            reqs = self._queue.pop_group(min(n, bucket))
            if self._obs_enabled:
                t_batch = time.perf_counter()
                for r in reqs:
                    if r.trace is not None:
                        r.trace.marks["batch"] = t_batch
            for res in self._execute(reqs):
                self._results[res.request_id] = res
                self._pending_rids.discard(res.request_id)
            while len(self._results) > self._max_unpolled:
                self._results.pop(next(iter(self._results)))
            return len(reqs)

    def execute_batch(
        self, reqs: Sequence[PendingRequest],
        overrides: Optional[SearchOverrides] = None,
    ) -> List[RetrievalResult]:
        """Dispatch pre-formed requests immediately, bypassing the queue.

        The async driver's entry point: its requests already waited out the
        deadline policy in the driver's own queue, so they dispatch now —
        split into consecutive same-``mask_key`` runs (each run shares one
        filter bitmask; the driver's batch formation already groups, so a
        mixed chunk only costs extra dispatches, never reorders results)
        and along the bucket ladder when a run exceeds the top bucket.
        Results return in request order and are never parked in the
        ``poll`` map — the driver resolves its futures directly, so the
        ``max_unpolled`` eviction can't drop them.  Requests with a negative
        ``request_id`` are assigned the next engine id.
        """
        # fault site OUTSIDE the lock: an injected hang here wedges only
        # this thread, so a supervised replacement driver can still dispatch
        self.faults.check("dispatch", queries=[r.query for r in reqs])
        out: List[RetrievalResult] = []
        with self.lock:
            fresh = sum(1 for r in reqs if r.request_id < 0)
            for r in reqs:
                if r.request_id < 0:
                    r.request_id = self._next_rid
                    self._next_rid += 1
            # count only first-time requests: a bisection retry re-enters
            # with its engine id already assigned and must not inflate the
            # submitted/completed reconciliation
            self.stats.n_submitted += fresh
            off = 0
            while off < len(reqs):
                chunk = [reqs[off]]
                off += 1
                while (off < len(reqs)
                       and len(chunk) < self.policy.max_size
                       and reqs[off].mask_key == chunk[0].mask_key):
                    chunk.append(reqs[off])
                    off += 1
                out.extend(self._execute(chunk, overrides=overrides))
        return out

    def run_until_idle(self) -> int:
        """Drain the whole queue; returns total requests completed."""
        done = 0
        while self.n_pending:
            done += self.step()
        return done

    def warmup(self) -> None:
        """Compile every bucket shape at the current corpus capacity.

        Call after (re)building the corpus and before measuring latency:
        compile events are excluded from the stats percentiles, and warming
        here keeps steady-state dispatches compile-free.  Idempotent; cheap
        when shapes are already cached.
        """
        with self.lock:
            self._maybe_rebuild_locked()
            probe = np.zeros((1, self.store.d_emb), np.float32)
            # warm the static path AND every adaptive degradation level:
            # each level is one extra compiled program per bucket (knobs are
            # static argnames), so pressure transitions never compile
            for ov in (None, *self._level_overrides.values()):
                for b in self.policy.sizes:
                    qb = np.repeat(probe, b, axis=0)
                    # warm whichever dispatch path requests actually take
                    if self._stage_fences:
                        self._dispatch_fenced(qb, overrides=ov)
                    else:
                        self._dispatch(qb, overrides=ov)

    # -- synchronous batch API (pipeline / benchmarks) ------------------------
    def search(self, queries, *, k: Optional[int] = None,
               tenant: Optional[str] = None,
               filter: Optional[Dict] = None,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Bucketed search for a (B, D) query batch, bypassing the queue.

        ``k``/``tenant``/``filter`` apply to the whole batch (the
        per-request variants ride `SearchRequest` through ``submit``).
        With the default ``flat`` backend and no filter, results are
        identical to calling ``progressive_search`` directly on the live
        corpus (padding queries are per-query-independent and sliced off);
        the ``ivf`` and ``quantized`` backends return their approximate
        results, exactly as the queued request path would.
        """
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.shape[1] != self.store.d_emb:
            raise ValueError(
                f"query dim {q.shape[1]} != corpus dim {self.store.d_emb}"
            )
        out_k = self.out_k if k is None else int(k)
        if not 1 <= out_k <= self.out_k:
            raise ValueError(f"k={k} outside [1, {self.out_k}]")
        mask_key = self.store.compile_mask(tenant, filter)
        if q.shape[0] == 0:
            return (np.zeros((0, out_k), np.float32),
                    np.zeros((0, out_k), np.int32))
        with self.lock:
            self._maybe_rebuild_locked()          # safe point: whole batch
            mask = self.store.mask_for_key(mask_key)
            # Overlap: issue every chunk's dispatch before syncing any of
            # them — XLA executes them back-to-back while the host keeps
            # padding and enqueueing (only step() needs a per-batch sync,
            # for timing).
            pend = []
            off = 0
            for bucket in self.policy.plan(q.shape[0]):
                take = min(bucket, q.shape[0] - off)
                s, i, _ = self._dispatch_async(
                    pad_batch(q[off:off + take], bucket), mask=mask)
                pend.append((s, i, take))
                off += take
            jax.block_until_ready([p[0] for p in pend])
        out_s = [np.asarray(s)[:take, :out_k] for s, _, take in pend]
        out_i = [np.asarray(i)[:take, :out_k] for _, i, take in pend]
        return np.concatenate(out_s), np.concatenate(out_i)

    def overrides_for_level(self, level: int) -> Optional[SearchOverrides]:
        """Degradation knobs for an adaptive pressure level (None for
        level 0 / adaptive disabled; deeper-than-configured levels clamp
        to the deepest configured one)."""
        if level <= 0 or not self._level_overrides:
            return None
        return self._level_overrides.get(
            min(level, max(self._level_overrides)))

    def cache_stamp(self) -> Tuple[int, int, int]:
        """The query cache's staleness stamp: (store generation, mask
        epoch, rebuild count) read atomically under ``engine.lock``.  Any
        component moving invalidates every cached result."""
        with self.lock:
            return (self.store.generation, self.store.mask_epoch,
                    self.stats.n_rebuilds)

    def _dispatch_async(self, q_pad: np.ndarray, mask=None, overrides=None):
        """Hand one padded bucket to the backend; returns device arrays
        without forcing a sync (the caller decides when to block).

        ``mask`` is a compiled (capacity,) tenant/metadata bitmask — it is
        AND-ed into the store's validity mask here, and that single AND is
        the entire filtered-search integration: every backend already
        treats a cleared validity bit as "unreturnable", so no backend
        grows any filter code (and the traced program is byte-identical —
        the mask is data, not shape).
        """
        store = self.store
        state = self._ensure_index()
        shape_key = (q_pad.shape[0], store.capacity, state.shape_key,
                     overrides)
        compiled = shape_key not in self._seen_shapes
        self._seen_shapes.add(shape_key)
        valid = (store.valid if mask is None
                 else jnp.logical_and(store.valid, mask))
        # overrides passed only when set: pre-existing custom backends that
        # never heard of the kwarg keep working on the static path
        kw = {} if overrides is None else {"overrides": overrides}
        s, i = self.backend.search(
            jnp.asarray(q_pad), state, store.db, valid,
            sq_prefix=store.sq_prefix,
            n_total=store.size,
            k=self.out_k,
            **kw,
        )
        return s, i, compiled

    def _dispatch(self, q_pad: np.ndarray, mask=None, overrides=None):
        s, i, compiled = self._dispatch_async(q_pad, mask=mask,
                                              overrides=overrides)
        jax.block_until_ready((s, i))
        return np.asarray(s), np.asarray(i), compiled

    def _dispatch_fenced(self, q_pad: np.ndarray, mask=None, overrides=None):
        """Dispatch with a ``block_until_ready`` fence at the stage-0
        boundary (``obs.stage_fences``), so the stage-0 / rescore split is
        measurable.  Two device round trips instead of one fused program —
        an opt-in diagnostic path with its own compile-cache entries (the
        ``"fenced"`` tag keeps its shape keys apart from the fused path's).
        Returns (scores, ids, compiled, t_stage0)."""
        store = self.store
        state = self._ensure_index()
        shape_key = ("fenced", q_pad.shape[0], store.capacity,
                     state.shape_key, overrides)
        compiled = shape_key not in self._seen_shapes
        self._seen_shapes.add(shape_key)
        valid = (store.valid if mask is None
                 else jnp.logical_and(store.valid, mask))
        marks: Dict[str, float] = {}

        def fence(arrays) -> None:
            jax.block_until_ready(arrays)
            marks["stage0"] = time.perf_counter()

        kw = {} if overrides is None else {"overrides": overrides}
        s, i = self.backend.search_fenced(
            jnp.asarray(q_pad), state, store.db, valid,
            sq_prefix=store.sq_prefix,
            n_total=store.size,
            k=self.out_k,
            fence=fence,
            **kw,
        )
        jax.block_until_ready((s, i))
        return (np.asarray(s), np.asarray(i), compiled,
                marks.get("stage0"))

    # -- observability --------------------------------------------------------
    def _collect_metrics(self) -> None:
        """Scrape-time collector: counter totals + point-in-time gauges
        under ``engine.lock``.

        Registered on ``self.metrics``; runs only when something renders
        the registry (never per request).  Lock order is engine.lock ->
        registry lock — the same order every hot-path instrument uses, so
        a scrape can never deadlock against a dispatch.
        """
        with self.lock:
            store = self.store
            self.stats.publish()
            self._g_queue_depth.set(float(len(self._queue)))
            # the store keeps plain ints under engine.lock; mirror the
            # lifetime totals instead of double-counting increments
            self._c_mask_hits.set_total(store.mask_cache_hits)
            self._c_mask_misses.set_total(store.mask_cache_misses)
            st = store.stats()
            for key, val in (
                ("size", st.size), ("n_active", st.n_active),
                ("n_dead", st.n_dead), ("capacity", st.capacity),
                ("generation", st.generation),
                ("total_added", st.total_added),
                ("total_deleted", st.total_deleted),
            ):
                self._g_store.set(float(val), key=key)
            state = self._index_state
            if state is not None:
                for key, val in self.backend.gauges(state, st).items():
                    self._g_backend.set(
                        float(val), backend=self.backend.name, key=key)
            if self.wal is not None:
                w = self.wal.summary()
                for key in ("last_seq", "lag_records", "n_segments"):
                    self._g_wal.set(float(w[key]), key=key)

    def profile_stages(self, queries, *, runs: int = 3) -> List[Dict]:
        """Per-stage wall time for a representative batch (post-warmup).

        Runs the schedule stage by stage (stage-0 full scan, then each
        rescore) so the cost split across dims is visible — the fused
        ``progressive_search`` program hides it.  Always profiles the flat
        schedule path regardless of the configured backend: it answers
        "where does the schedule spend", not "what does this backend cost"
        (the backend split lives in ``benchmarks/backend_comparison.py``).
        """
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        store = self.store
        block_n = min(self.block_n, store.capacity)
        dims_t = self.dims
        out = []
        cand = None
        for si, stage in enumerate(self.sched.stages):
            col = dims_t.index(stage.dim)

            if si == 0:
                def fn(c=None, _s=stage):
                    return truncated_search(
                        q, store.db, dim=_s.dim, k=_s.k,
                        db_sq_at_dim=store.sq_prefix[:, col],
                        valid=store.valid, block_n=block_n,
                        metric=self.metric,
                    )
            else:
                def fn(c=cand, _s=stage):
                    return rescore_candidates(
                        q, store.db, c, dim=_s.dim, k=_s.k,
                        db_sq_at_dim=store.sq_prefix[:, col],
                        valid=store.valid, metric=self.metric,
                    )
            res = fn()
            jax.block_until_ready(res)          # warmup/compile
            ts = []
            for _ in range(runs):
                t0 = time.perf_counter()
                res = fn()
                jax.block_until_ready(res)
                ts.append(time.perf_counter() - t0)
            cand = res[1]
            out.append({
                "stage": si,
                "dim": stage.dim,
                "k": stage.k,
                "pool": stage.pool,
                "ms": float(np.median(ts) * 1e3),
            })
        return out

    def describe(self) -> str:
        return (
            f"RetrievalEngine(docs={self.store.n_active}/"
            f"cap={self.store.capacity}, buckets={self.policy.sizes}, "
            f"metric={self.metric}, backend={self.backend.describe()}, "
            f"rebuild={self.rebuild_mode}, sched: {self.sched.describe()})"
        )

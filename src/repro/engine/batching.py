"""Request coalescing for the retrieval engine: FIFO queue + static buckets.

Serving traffic arrives as single queries at arbitrary times, but XLA wants a
small, fixed set of batch shapes — every distinct (batch, corpus-capacity)
pair is a separate compilation.  A ``BucketPolicy`` quantizes batch sizes to a
static ladder (powers of two by default): the engine drains its queue in
chunks, pads each chunk up to the nearest bucket, and therefore compiles each
bucket exactly once per corpus capacity.  Padding rows are zero queries whose
results are discarded — progressive search is per-query, so they cannot
perturb real requests.

``DeadlineBatcher`` is the *when* to the BucketPolicy's *what shape*: the
latency/throughput knob for the async driver (`repro.engine.driver`).  A
request waits at most ``max_wait_s`` for companions before its partial batch
is flushed, and a full top bucket flushes immediately.  It is a pure decision
function over (queue depth, oldest arrival, now) — no clock of its own, no
thread state — so the deadline policy is unit-testable with a fake clock
while the driver thread feeds it real time.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Static batch-size ladder for shape-bucketed dispatch.

    Attributes:
      sizes: ascending, unique, positive batch sizes.  A pending chunk of
             ``n`` requests is padded to the smallest bucket >= n; chunks
             larger than the top bucket are split.
    """

    sizes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)

    def __post_init__(self):
        if not self.sizes:
            raise ValueError("BucketPolicy needs at least one bucket size")
        if any(s <= 0 for s in self.sizes):
            raise ValueError(f"bucket sizes must be positive, got {self.sizes}")
        if list(self.sizes) != sorted(set(self.sizes)):
            raise ValueError(
                f"bucket sizes must be ascending and unique, got {self.sizes}"
            )

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (top bucket for oversized n; caller splits)."""
        if n <= 0:
            raise ValueError(f"need a positive batch, got {n}")
        for s in self.sizes:
            if s >= n:
                return s
        return self.max_size

    def plan(self, n: int) -> List[int]:
        """Bucket sequence covering ``n`` requests.

        Full top-size batches first (best MXU utilization), then one padded
        bucket for the remainder — at most ``max_size - 1`` padded slots total.
        """
        if n <= 0:
            return []
        out = [self.max_size] * (n // self.max_size)
        rem = n % self.max_size
        if rem:
            out.append(self.bucket_for(rem))
        return out


@dataclasses.dataclass(frozen=True)
class BatchDecision:
    """What the driver loop should do right now.

    ``action`` is one of:
      * ``'flush'`` — dispatch ``n`` requests from the queue head (``reason``
        says why: ``'full'`` bucket or ``'deadline'`` expiry).
      * ``'wait'``  — nothing is due; sleep at most ``wait_s`` (an earlier
        arrival can only shorten the deadline, so waking on new submissions
        and re-deciding is always safe).
      * ``'idle'``  — queue is empty; block until something arrives.
    """

    action: str
    n: int = 0
    wait_s: float = 0.0
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class DeadlineBatcher:
    """Deadline-based flush policy over a ``BucketPolicy`` ladder.

    A partial batch is held back for up to ``max_wait_s`` after its *oldest*
    request arrived (more companions => bigger bucket => better device
    utilization); a full top-size bucket flushes immediately (waiting longer
    cannot improve its shape).  ``max_wait_s=0`` degenerates to
    flush-on-arrival: minimum latency, singleton batches under light load.
    """

    policy: BucketPolicy
    max_wait_s: float = 0.002

    def __post_init__(self):
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")

    def decide(self, n_pending: int, oldest_arrival: float,
               now: float) -> BatchDecision:
        """Pure policy step: all time flows in through the arguments."""
        if n_pending <= 0:
            return BatchDecision("idle")
        if n_pending >= self.policy.max_size:
            return BatchDecision("flush", n=self.policy.max_size, reason="full")
        deadline = oldest_arrival + self.max_wait_s
        if now >= deadline:
            return BatchDecision("flush", n=n_pending, reason="deadline")
        return BatchDecision("wait", wait_s=deadline - now)


@dataclasses.dataclass
class PendingRequest:
    """A submitted query waiting for dispatch.

    ``k``/``mask_key`` carry the per-request options of the typed
    `repro.engine.request.SearchRequest` surface; the defaults are exactly
    the legacy raw-vector request (engine-default k, no tenant/filter).
    Requests sharing a ``mask_key`` can ride the same dispatch — the batch
    applies one row bitmask — so batch formation groups by it.
    """

    request_id: int
    query: np.ndarray           # (D,) float32
    t_submit: float             # perf_counter seconds
    k: Optional[int] = None     # result width; None = engine default
    mask_key: Optional[Tuple] = None   # DocStore.compile_mask identity
    deadline: Optional[float] = None   # absolute perf_counter deadline
    # repro.obs.TraceContext stamped by the engine/driver along the way
    # (None when observability is disabled); typed loosely so this module
    # keeps zero obs imports
    trace: Optional[object] = None


class RequestQueue:
    """FIFO of pending requests (arrival order == dispatch order)."""

    def __init__(self) -> None:
        self._q: Deque[PendingRequest] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: PendingRequest) -> None:
        self._q.append(req)

    def pop_chunk(self, max_n: int) -> List[PendingRequest]:
        """Pop up to ``max_n`` requests in arrival order."""
        out = []
        while self._q and len(out) < max_n:
            out.append(self._q.popleft())
        return out

    def pop_group(self, max_n: int) -> List[PendingRequest]:
        """Pop up to ``max_n`` requests sharing the head's ``mask_key``.

        A batch dispatches with ONE row bitmask, so only same-key requests
        may share it.  The head's key always progresses (no starvation —
        this is still FIFO by key-of-the-oldest); non-matching requests
        keep their relative order for the next pop.
        """
        if not self._q:
            return []
        key = self._q[0].mask_key
        out: List[PendingRequest] = []
        skipped: List[PendingRequest] = []
        while self._q and len(out) < max_n:
            req = self._q.popleft()
            if req.mask_key == key:
                out.append(req)
            else:
                skipped.append(req)
        self._q.extendleft(reversed(skipped))
        return out


def pad_batch(queries: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad a (B, D) query batch up to (bucket, D)."""
    b, d = queries.shape
    if b > bucket:
        raise ValueError(f"batch {b} exceeds bucket {bucket}")
    if b == bucket:
        return queries
    out = np.zeros((bucket, d), queries.dtype)
    out[:b] = queries
    return out

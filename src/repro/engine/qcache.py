"""Mutation-aware query-result cache consulted before batch formation.

Repeated hot queries are a dominant serving cost in RAG (PAPERS.md:
Gao et al. 2023; Huang & Huang 2024); a hit here skips stage-0, the
rescore ladder, and the driver queue entirely.  The hard part is
staleness, and it is handled structurally rather than by TTL: every
entry is stamped with ``(store.generation, store.mask_epoch,
n_rebuilds)`` at insert time, and the whole cache is flushed the moment
any component moves (``_sync_stamp``).  A cached result therefore can
never be served across an add/delete/compact (``generation``), a
tenant/filter-mask change (``mask_epoch``), or an index rebuild — the
invariant the hypothesis property in tests/test_adaptive.py pins across
all six backend variants.

Keys are ``(query bytes, mask key, degradation level)`` — a degraded
(level > 0) answer is never replayed to a full-quality request or vice
versa, and tenants/filters can't alias.  Optionally (``near_eps > 0``)
a miss falls back to a near-duplicate scan: squared-L2 distance against
the cached queries of the same (mask key, level), served when within
``near_eps``.  The scan is vectorised over a preallocated ``(capacity,
d)`` matrix — O(capacity · d) numpy per miss, intended for modest
capacities (hot-query working sets), not as an ANN index.

Thread-safe behind its own lock; it must never be entered while holding
``engine.lock`` order-sensitively (callers take ``engine.lock`` only to
read the stamp, then release before touching the cache).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from ..obs import NULL_INSTRUMENT

Stamp = Tuple[int, int, int]  # (store_generation, mask_epoch, n_rebuilds)


@dataclass
class _Entry:
    k: int
    scores: np.ndarray  # (k,) float32 copy
    ids: np.ndarray     # (k,) int32 copy
    slot: int           # row in the query matrix (near-dup scan)


class QueryCache:
    """Exact + near-duplicate query-result LRU with structural
    invalidation.  See module docstring for the staleness contract."""

    def __init__(self, d: int, capacity: int = 1024,
                 near_eps: float = 0.0) -> None:
        if capacity <= 0:
            raise ValueError("cache capacity must be positive")
        self.d = int(d)
        self.capacity = int(capacity)
        self.near_eps = float(near_eps)
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        # preallocated query rows for the near-dup distance scan; slot i
        # is live iff some entry points at it
        self._qmat = np.zeros((self.capacity, self.d), dtype=np.float32)
        self._slot_key: Dict[int, tuple] = {}
        self._free = list(range(self.capacity - 1, -1, -1))
        self._stamp: Optional[Stamp] = None
        # plain-int counters, published at scrape time (EngineStats
        # discipline); mutated only under self._lock
        self.hits_exact = 0
        self.hits_near = 0
        self.misses = 0
        self.invalidations = 0
        self._c_hits = NULL_INSTRUMENT
        self._c_misses = NULL_INSTRUMENT
        self._c_inval = NULL_INSTRUMENT
        self._g_size = NULL_INSTRUMENT

    # -- staleness ----------------------------------------------------
    def _sync_stamp_locked(self, stamp: Stamp) -> None:
        if self._stamp == stamp:
            return
        if self._entries:
            self.invalidations += 1
        self._entries.clear()
        self._slot_key.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self._stamp = stamp

    # -- lookup / insert ---------------------------------------------
    @staticmethod
    def _key(q: np.ndarray, mask_key, level: int) -> tuple:
        return (q.tobytes(), mask_key, level)

    def lookup(self, q: np.ndarray, k: int, mask_key, level: int,
               stamp: Stamp) -> Optional[Tuple[np.ndarray, np.ndarray, str]]:
        """Return ``(scores[:k], ids[:k], 'exact'|'near')`` or None.

        ``stamp`` must be the store/backend generation triple read under
        ``engine.lock`` *by the caller, just before calling* — passing a
        fresh stamp is what makes a stale hit structurally impossible.
        """
        q = np.ascontiguousarray(q, dtype=np.float32)
        with self._lock:
            self._sync_stamp_locked(stamp)
            key = self._key(q, mask_key, level)
            e = self._entries.get(key)
            if e is not None and e.k >= k:
                self._entries.move_to_end(key)
                self.hits_exact += 1
                return e.scores[:k].copy(), e.ids[:k].copy(), "exact"
            if self.near_eps > 0.0 and self._entries:
                hit = self._near_locked(q, k, mask_key, level)
                if hit is not None:
                    self.hits_near += 1
                    return hit
            self.misses += 1
            return None

    def _near_locked(self, q, k, mask_key, level):
        slots = [s for s, sk in self._slot_key.items()
                 if sk[1] == mask_key and sk[2] == level
                 and self._entries[sk].k >= k]
        if not slots:
            return None
        rows = np.asarray(slots)
        d2 = ((self._qmat[rows] - q[None, :]) ** 2).sum(axis=1)
        j = int(np.argmin(d2))
        if d2[j] > self.near_eps:
            return None
        key = self._slot_key[slots[j]]
        e = self._entries[key]
        self._entries.move_to_end(key)
        return e.scores[:k].copy(), e.ids[:k].copy(), "near"

    def insert(self, q: np.ndarray, scores: np.ndarray, ids: np.ndarray,
               mask_key, level: int, stamp: Stamp) -> None:
        """Insert a delivered result.  ``stamp`` must be read under
        ``engine.lock`` AFTER the batch executed; if a mutation landed
        mid-window the stamps differ and the entry is dropped with the
        rest of the flush — never inserted stale."""
        q = np.ascontiguousarray(q, dtype=np.float32)
        scores = np.asarray(scores, dtype=np.float32).copy()
        ids = np.asarray(ids, dtype=np.int32).copy()
        with self._lock:
            self._sync_stamp_locked(stamp)
            key = self._key(q, mask_key, level)
            old = self._entries.pop(key, None)
            if old is not None:
                slot = old.slot
            elif self._free:
                slot = self._free.pop()
            else:  # LRU eviction
                _, victim = self._entries.popitem(last=False)
                self._slot_key.pop(victim.slot, None)
                slot = victim.slot
            self._qmat[slot] = q
            self._slot_key[slot] = key
            self._entries[key] = _Entry(k=len(ids), scores=scores, ids=ids,
                                        slot=slot)

    # -- observability ------------------------------------------------
    def bind(self, registry) -> None:
        self._c_hits = registry.counter(
            "repro_qcache_hits_total",
            "Query-cache hits served without dispatch", labels=("kind",))
        self._c_misses = registry.counter(
            "repro_qcache_misses_total", "Query-cache misses")
        self._c_inval = registry.counter(
            "repro_qcache_invalidations_total",
            "Whole-cache flushes on store/mask/rebuild generation bumps")
        self._g_size = registry.gauge(
            "repro_qcache_size", "Live cached query results")
        self.publish()

    def publish(self) -> None:
        with self._lock:
            hits_exact, hits_near = self.hits_exact, self.hits_near
            misses, inval = self.misses, self.invalidations
            size = len(self._entries)
        self._c_hits.set_total(hits_exact, kind="exact")
        self._c_hits.set_total(hits_near, kind="near")
        self._c_misses.set_total(misses)
        self._c_inval.set_total(inval)
        self._g_size.set(size)

    def summary(self) -> Dict:
        with self._lock:
            n = self.hits_exact + self.hits_near + self.misses
            hits = self.hits_exact + self.hits_near
            return {
                "enabled": True,
                "size": len(self._entries),
                "capacity": self.capacity,
                "near_eps": self.near_eps,
                "hits_exact": self.hits_exact,
                "hits_near": self.hits_near,
                "misses": self.misses,
                "invalidations": self.invalidations,
                "hit_rate": (hits / n) if n else 0.0,
            }

"""Deterministic fault injection for the serving stack's chaos tests.

A ``FaultPlan`` is a parsed set of rules, each bound to a *named site* the
engine/driver consult at the failure-prone moments:

    dispatch    — driver batch execution (before ``engine.lock``)
    rebuild     — index (re)build, sync or background
    wal_write   — WAL append on the mutation path
    ckpt_save   — snapshot/index save
    ckpt_load   — snapshot/index load during recovery
    wal_ship    — replication: follower polling the primary's WAL tail
    replica_apply — replication: follower applying one shipped record

Spec grammar (``FaultToleranceConfig.inject`` / ``--inject``)::

    site:action[@key=value[,key=value...]][;site:action...]

Actions:
    error   — raise ``InjectedFault`` (an ordinary Exception: exercised
              error paths, batch failure, rebuild retry)
    crash   — raise ``InjectedCrash`` (a BaseException that escapes
              ``except Exception`` handlers — simulates the driver thread
              dying mid-loop; the supervisor's restart path)
    hang    — sleep ``s`` seconds (default 30): a wedged thread for the
              heartbeat watchdog to detect
    exit    — ``os._exit(code)`` (default 17): hard process death for the
              subprocess chaos tests
    poison  — raise ``PoisonError`` iff a query in the batch carries the
              marker value in component 0 (``v=``): content-determined, so
              batch bisection isolates exactly the offender

Firing qualifiers (count-based rules are exact; ``p=`` draws from a
per-rule RNG seeded by ``(seed, site, action)`` so a given plan replays
identically):
    once=K  — fire on exactly the Kth check of the site (1-based)
    first=K — fire on the first K checks
    every=K — fire on every Kth check
    p=F     — fire with probability F per check

The plan keeps per-site call and fire counters (``summary()``) so tests and
the chaos benchmark can assert exactly what fired.  ``FaultPlan.parse("")``
yields an inert plan — the production configuration; its ``check`` is two
dict lookups.
"""

from __future__ import annotations

import os
import random
import threading
import time
from typing import Dict, List, Optional, Sequence

SITES = ("dispatch", "rebuild", "wal_write", "ckpt_save", "ckpt_load",
         "wal_ship", "replica_apply")
ACTIONS = ("error", "crash", "hang", "exit", "poison")


class InjectedFault(RuntimeError):
    """An injected ordinary failure (the ``error`` action)."""


class PoisonError(InjectedFault):
    """An injected per-request failure: the batch contains a poison query
    (the ``poison`` action).  Content-determined — re-dispatching any
    subset containing the marker fails again, so bisection converges on
    exactly the poisoned request."""


class InjectedCrash(BaseException):
    """An injected catastrophic failure.  Deliberately NOT an Exception:
    it sails through ``except Exception`` recovery code exactly like a
    genuine interpreter-level death would, killing the driver thread."""


class FaultRule:
    """One parsed ``site:action@...`` clause."""

    __slots__ = ("site", "action", "once", "first", "every", "p",
                 "hang_s", "marker", "code", "_rng")

    def __init__(self, site: str, action: str, *, once: int = 0,
                 first: int = 0, every: int = 0, p: float = 0.0,
                 hang_s: float = 30.0, marker: Optional[float] = None,
                 code: int = 17, seed: int = 0):
        if site not in SITES:
            raise ValueError(
                f"unknown fault site {site!r}; sites: {SITES}")
        if action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {action!r}; actions: {ACTIONS}")
        if action == "poison" and site != "dispatch":
            raise ValueError("poison rules only apply to the dispatch site")
        if action == "poison" and marker is None:
            raise ValueError("poison rules need a marker value (v=...)")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"p must lie in [0, 1], got {p}")
        if action != "poison" and once <= 0 and first <= 0 \
                and every <= 0 and p <= 0.0:
            raise ValueError(
                f"rule {site}:{action} never fires; give it once=/first=/"
                f"every=/p=")
        self.site, self.action = site, action
        self.once, self.first, self.every, self.p = once, first, every, p
        self.hang_s, self.marker, self.code = hang_s, marker, code
        self._rng = random.Random(f"{seed}:{site}:{action}")

    def fires(self, n_call: int) -> bool:
        """Does this rule fire on the ``n_call``-th (1-based) site check?"""
        if self.once and n_call == self.once:
            return True
        if self.first and n_call <= self.first:
            return True
        if self.every and n_call % self.every == 0:
            return True
        if self.p and self._rng.random() < self.p:
            return True
        return False


def _parse_clause(clause: str, seed: int) -> FaultRule:
    head, _, tail = clause.partition("@")
    site, _, action = head.partition(":")
    kw: Dict = {}
    if tail:
        for pair in tail.split(","):
            key, _, val = pair.partition("=")
            key, val = key.strip(), val.strip()
            if key in ("once", "first", "every", "code"):
                kw[key] = int(val)
            elif key == "p":
                kw["p"] = float(val)
            elif key == "s":
                kw["hang_s"] = float(val)
            elif key == "v":
                kw["marker"] = float(val)
            else:
                raise ValueError(
                    f"unknown fault qualifier {key!r} in {clause!r}")
    return FaultRule(site.strip(), action.strip(), seed=seed, **kw)


class FaultPlan:
    """A seeded, thread-safe set of fault rules the serving stack consults.

    ``check(site, queries=...)`` is called at each named site; it raises /
    hangs / exits according to the matching rules.  With no rules for the
    site it is nearly free, so production engines carry an empty plan
    rather than branching around the calls.
    """

    def __init__(self, rules: Sequence[FaultRule] = (), *, seed: int = 0):
        self.seed = int(seed)
        self._rules: Dict[str, List[FaultRule]] = {}
        for r in rules:
            self._rules.setdefault(r.site, []).append(r)
        self._lock = threading.Lock()
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    @classmethod
    def parse(cls, spec: Optional[str], *, seed: int = 0) -> "FaultPlan":
        spec = (spec or "").strip()
        if not spec:
            return cls((), seed=seed)
        rules = [_parse_clause(c.strip(), seed)
                 for c in spec.split(";") if c.strip()]
        return cls(rules, seed=seed)

    @property
    def empty(self) -> bool:
        return not self._rules

    def check(self, site: str, *, queries=None) -> None:
        """Consult the plan at ``site``; raises/hangs/exits when a rule
        fires.  ``queries`` (a sequence of (D,) vectors) is only read by
        poison rules."""
        rules = self._rules.get(site)
        if not rules:
            return
        with self._lock:
            n = self.calls.get(site, 0) + 1
            self.calls[site] = n
            todo = []
            for r in rules:
                if r.action == "poison":
                    if queries is not None and any(
                            abs(float(q[0]) - r.marker) < 1e-6
                            for q in queries):
                        todo.append(r)
                elif r.fires(n):
                    todo.append(r)
            for r in todo:
                key = f"{site}:{r.action}"
                self.fired[key] = self.fired.get(key, 0) + 1
        for r in todo:
            if r.action == "error":
                raise InjectedFault(f"injected error at {site} (call {n})")
            if r.action == "poison":
                raise PoisonError(
                    f"injected poison in batch at {site} "
                    f"(marker {r.marker})")
            if r.action == "hang":
                time.sleep(r.hang_s)
            elif r.action == "exit":
                os._exit(r.code)
            elif r.action == "crash":
                raise InjectedCrash(
                    f"injected crash at {site} (call {n})")

    def summary(self) -> Dict:
        with self._lock:
            return {"calls": dict(self.calls), "fired": dict(self.fired)}


NULL_PLAN = FaultPlan()

"""Typed per-request search API: ``SearchRequest`` + the metadata filter spec.

Until PR 6 every per-query knob was frozen at engine construction: ``k`` was
the engine-global ``final_k`` and ``submit()`` took only a raw vector.  A
serving front-end needs per-request options — a different ``k``, a tenant
namespace, a metadata filter over a sub-corpus, a client deadline — so this
module defines the one blessed way to express them end-to-end:

    SearchRequest(query, k=5, tenant="acme", filter={"lang": "en"})

is accepted by ``RetrievalEngine.submit()`` / ``.search()`` and
``EngineDriver.submit()`` / ``.retrieve()`` alongside the existing raw-array
form (a raw array is exactly ``SearchRequest(query)``, so every pre-existing
call site keeps working unchanged).

**Filter spec.**  A filter is a dict mapping metadata fields to either a
scalar (equality) or an operator dict, MongoDB-style:

    {"lang": "en"}                            # equality
    {"year": {"$gte": 2020, "$lt": 2025}}     # range
    {"topic": {"$in": [1, 2, 3]}}             # membership
    {"flag": {"$ne": "spam"}}                 # != (missing field matches)
    {"score": {"$exists": True}}              # field presence

Fields are AND-ed.  ``canonical_filter`` validates the spec eagerly (raising
``FilterError`` with a pointed message — the HTTP layer maps it to a 400)
and folds it into a hashable canonical tuple.  That tuple does double duty:

  * it is the *mask key* — together with the tenant it identifies the
    compiled row bitmask, so `DocStore`'s mask cache and the batch-formation
    grouping (requests sharing a mask key ride the same dispatch) both hash
    it instead of re-walking dicts;
  * it survives submission-to-dispatch delays — masks are (re)compiled from
    the key at dispatch time, so rows added after ``submit`` are visible to
    the filtered search exactly as they are to an unfiltered one.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

# Operators the mask compiler understands (MongoDB-style names).
FILTER_OPS = ("$eq", "$ne", "$in", "$nin", "$gt", "$gte", "$lt", "$lte",
              "$exists")
_SCALAR_TYPES = (str, int, float, bool)
_ORDER_OPS = ("$gt", "$gte", "$lt", "$lte")


class FilterError(ValueError):
    """Malformed metadata-filter spec (client error — HTTP 400)."""


def _check_scalar(field: str, op: str, value: Any) -> Any:
    if value is None or isinstance(value, _SCALAR_TYPES):
        if op in _ORDER_OPS and not isinstance(value, (int, float)):
            raise FilterError(
                f"filter field {field!r}: {op} needs a numeric bound, got "
                f"{value!r}")
        if op in _ORDER_OPS and isinstance(value, bool):
            raise FilterError(
                f"filter field {field!r}: {op} needs a numeric bound, got "
                f"a bool")
        return value
    raise FilterError(
        f"filter field {field!r}: values must be str/int/float/bool/None, "
        f"got {type(value).__name__}")


def canonical_filter(filt: Optional[Dict]) -> Optional[Tuple]:
    """Validate a filter spec and fold it into a hashable canonical tuple.

    Returns None for an empty/absent filter.  The canonical form is
    ``((field, ((op, value), ...)), ...)`` with fields and ops sorted, so
    two specs that mean the same thing hash identically (mask-cache hits,
    shared batches).
    """
    if filt is None:
        return None
    if not isinstance(filt, dict):
        raise FilterError(
            f"filter must be a dict of field -> value/operators, got "
            f"{type(filt).__name__}")
    if not filt:
        return None
    fields = []
    for field, spec in filt.items():
        if not isinstance(field, str) or not field:
            raise FilterError(
                f"filter field names must be non-empty strings, got "
                f"{field!r}")
        if field.startswith("$"):
            raise FilterError(
                f"unsupported top-level operator {field!r}; filters are a "
                f"dict of field -> value/operators")
        if isinstance(spec, dict):
            if not spec:
                raise FilterError(f"filter field {field!r}: empty operator "
                                  f"dict")
            ops = []
            for op, value in spec.items():
                if op not in FILTER_OPS:
                    raise FilterError(
                        f"filter field {field!r}: unknown operator {op!r}; "
                        f"supported: {', '.join(FILTER_OPS)}")
                if op in ("$in", "$nin"):
                    if not isinstance(value, (list, tuple)):
                        raise FilterError(
                            f"filter field {field!r}: {op} needs a list")
                    value = tuple(_check_scalar(field, "$eq", v)
                                  for v in value)
                elif op == "$exists":
                    if not isinstance(value, bool):
                        raise FilterError(
                            f"filter field {field!r}: $exists needs a bool")
                else:
                    value = _check_scalar(field, op, value)
                ops.append((op, value))
            fields.append((field, tuple(sorted(ops))))
        else:
            fields.append(
                (field, (("$eq", _check_scalar(field, "$eq", spec)),)))
    return tuple(sorted(fields))


def filter_to_dict(canon: Optional[Tuple]) -> Optional[Dict]:
    """Canonical tuple back to the client-facing dict form (stats/debug)."""
    if canon is None:
        return None
    out: Dict[str, Any] = {}
    for field, ops in canon:
        if len(ops) == 1 and ops[0][0] == "$eq":
            out[field] = ops[0][1]
        else:
            out[field] = {op: (list(v) if isinstance(v, tuple) else v)
                          for op, v in ops}
    return out


@dataclasses.dataclass
class SearchRequest:
    """One typed retrieval request.

    Attributes:
      query:       the query vector — anything ``np.asarray`` accepts,
                   shaped (D,) or (1, D).
      k:           neighbours to return; None means the engine's configured
                   ``final_k``.  Must not exceed it (the dispatch shape is
                   static — configure the engine with the largest ``k`` it
                   should serve).
      tenant:      namespace the search is confined to.  A named tenant sees
                   exactly the docs added under that tenant (strict
                   isolation — never another tenant's, never the tenantless
                   pool).  None is the unconstrained admin/legacy view over
                   the whole corpus; the HTTP layer refuses it unless the
                   server was configured with ``require_tenant=False``.
      filter:      metadata filter spec (see module docstring); AND-ed with
                   the tenant constraint and the store's validity mask.
      deadline_ms: client latency budget.  The async driver drops requests
                   whose budget expired before dispatch (their futures raise
                   ``DeadlineExceeded``); the synchronous queue path ignores
                   it (the caller paces dispatch there).
    """

    query: Any
    k: Optional[int] = None
    tenant: Optional[str] = None
    filter: Optional[Dict] = None
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.k is not None and int(self.k) < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.tenant is not None and (
                not isinstance(self.tenant, str) or not self.tenant):
            raise ValueError(
                f"tenant must be a non-empty string or None, got "
                f"{self.tenant!r}")
        if self.deadline_ms is not None and float(self.deadline_ms) < 0:
            raise ValueError(
                f"deadline_ms must be >= 0, got {self.deadline_ms}")

    def mask_key(self) -> Optional[Tuple]:
        """Hashable (tenant, canonical-filter) identity of this request's
        row bitmask; None when the request constrains nothing (fast path:
        no mask is compiled or AND-ed at all)."""
        canon = canonical_filter(self.filter)
        if self.tenant is None and canon is None:
            return None
        return (self.tenant, canon)

"""Driver-thread supervision: heartbeat watchdog + capped-backoff restarts.

The `repro.engine.driver.EngineDriver` thread is a single point of failure:
if it dies (an escaped exception) or wedges (a hung device call, a
pathological dispatch), every client blocks and the queue grows until
backpressure freezes the front-end.  The ``Supervisor`` closes that hole:

* **Detection** — the driver stamps a heartbeat each loop iteration.  A
  thread that is not alive while the driver is RUNNING is *dead*; one that
  is alive but has both a stale heartbeat AND a pending request waiting
  longer than ``heartbeat_timeout_s`` is *hung* (the double condition keeps
  an idle driver — stale heartbeat, empty queue — from tripping it).
* **Restart** — ``driver.restart()`` spawns a replacement thread under a
  new epoch; a hung-but-alive old thread notices the stale epoch at its
  next safe point and stands down.  Pending requests survive the swap.
* **Backoff** — consecutive restarts back off exponentially
  (``backoff_initial_s * 2**n``, capped at ``backoff_max_s``); a stretch of
  healthy uptime resets the streak.  Past ``max_restarts`` consecutive
  failures the supervisor gives up: ``driver.kill`` fails everything
  pending and the crash loop surfaces instead of spinning forever.

Wiring: ``Supervisor(driver).start()`` after ``driver.start(
supervised=True)``; ``launch.serve --supervise`` does both.  Restart
counters live in ``driver.stats`` (``repro_driver_restarts_total``);
``summary()`` feeds ``/healthz?deep=1``.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.engine.config import FaultToleranceConfig

_RUNNING = "running"


class SupervisorGaveUp(RuntimeError):
    """The driver kept dying past ``max_restarts`` consecutive restarts —
    the supervisor stopped reviving it and failed pending requests."""


class Supervisor:
    """Watchdog thread restarting a dead/hung ``EngineDriver``."""

    def __init__(self, driver, *,
                 config: Optional[FaultToleranceConfig] = None,
                 poll_s: Optional[float] = None):
        self.driver = driver
        self.cfg = config if config is not None \
            else driver.engine.config.fault
        # poll a few times per timeout window so detection latency is a
        # fraction of the threshold, not a multiple of it
        self.poll_s = (poll_s if poll_s is not None
                       else max(0.01, self.cfg.heartbeat_timeout_s / 4))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.consecutive = 0
        self.gave_up = False
        self.last_cause: Optional[str] = None
        self._healthy_since: Optional[float] = None
        driver.supervisor = self
        self._c_restarts = driver.engine.metrics.counter(
            "repro_supervisor_restarts_total",
            "Driver restarts by the supervisor, by cause",
            labels=("cause",))

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Supervisor":
        if self._thread is not None:
            raise RuntimeError("supervisor already started")
        self._thread = threading.Thread(
            target=self._run, name="driver-supervisor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- watchdog loop ------------------------------------------------------
    def _verdict(self, h: Dict) -> Optional[str]:
        """None = healthy; otherwise the failure cause ('dead'/'hung')."""
        if not h["thread_alive"]:
            return "dead"
        t = self.cfg.heartbeat_timeout_s
        if (h["n_pending"] > 0 and h["oldest_wait_s"] > t
                and h["heartbeat_age_s"] > t):
            return "hung"
        return None

    def _run(self) -> None:
        d = self.driver
        clock = d._clock
        while not self._stop.wait(self.poll_s):
            h = d.health()
            if h["state"] != _RUNNING:
                if h["state"] == "stopped":
                    return                 # clean shutdown: nothing to do
                continue                   # new/stopping: not ours yet
            cause = self._verdict(h)
            if cause is None:
                now = clock()
                if self._healthy_since is None:
                    self._healthy_since = now
                elif (self.consecutive
                      and now - self._healthy_since
                      > 2 * self.cfg.heartbeat_timeout_s):
                    self.consecutive = 0   # earned a clean slate
                continue
            self._healthy_since = None
            self.last_cause = cause
            if self.consecutive >= self.cfg.max_restarts:
                self.gave_up = True
                d.kill(SupervisorGaveUp(
                    f"driver failed ({cause}) {self.consecutive + 1} "
                    f"consecutive times; giving up after "
                    f"{self.cfg.max_restarts} restarts"))
                return
            backoff = min(
                self.cfg.backoff_initial_s * (2 ** self.consecutive),
                self.cfg.backoff_max_s)
            if self._stop.wait(backoff):
                return
            if d.restart():
                self.consecutive += 1
                self._c_restarts.inc(cause=cause)

    def summary(self) -> Dict:
        return {
            "attached": True,
            "running": (self._thread is not None
                        and self._thread.is_alive()),
            "consecutive_failures": self.consecutive,
            "gave_up": self.gave_up,
            "last_cause": self.last_cause,
            "heartbeat_timeout_s": self.cfg.heartbeat_timeout_s,
            "max_restarts": self.cfg.max_restarts,
        }

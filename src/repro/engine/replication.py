"""WAL-shipped replication: a primary engine's mutation log replayed onto
read-only follower engines.

The primary needs no new machinery at all — its ``MutationWAL`` (PR 9's
durability log) *is* the replication stream.  Followers share the primary's
state directory (or a mirror of it) and:

1. **bootstrap** — restore the newest checksum-valid snapshot via the same
   ``_restore_newest_snapshot`` path ``recover()`` uses, but *without*
   opening the WAL (a follower must never truncate or extend the primary's
   live segment);
2. **catch up** — tail the WAL directory with a seq-keyed ``WALCursor`` and
   apply each record through ``engine.apply_replicated`` (the normal
   ``_apply_record`` mutation path, so tail injection, capacity doubling,
   and rebuild scheduling behave exactly as on the primary);
3. **report** — ``replica_lag`` (seq delta to the primary's durable tail)
   and an ``applied_seq`` high-water mark, surfaced in
   ``/healthz?deep=1`` and used for read-your-writes ``min_seq`` routing.

If the primary's snapshot retention prunes records the follower has not
read yet (``WALGap`` — the follower fell too far behind), the applier
re-bootstraps from the newest snapshot instead of silently skipping
mutations.

``PrimaryReplication`` is the trivial counterpart a primary serves behind:
``applied_seq`` is the WAL's own durable tail, so one uniform object
answers readiness, deep health, and ``min_seq`` waits on every role.

Fault sites: ``wal_ship`` fires before each tail poll, ``replica_apply``
before each record application — both consulted through the follower
engine's own ``FaultPlan`` so chaos tests inject deterministically.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, Optional

from repro.engine.wal import WALCursor, WALGap

__all__ = ["PrimaryReplication", "ReplicaApplier"]


class PrimaryReplication:
    """The primary's (degenerate) replication surface.

    Every sequence number the primary ever acknowledged is by definition
    already applied locally, so readiness is unconditional and ``min_seq``
    waits resolve instantly — the object exists so the HTTP server treats
    primaries and followers uniformly.
    """

    role = "primary"

    def __init__(self, engine):
        if engine.wal is None:
            raise RuntimeError(
                "PrimaryReplication needs durability enabled — call "
                "engine.recover()/enable_durability() first")
        self.engine = engine

    @property
    def applied_seq(self) -> int:
        return self.engine.wal.last_seq

    def lag(self) -> int:
        return 0

    def ready(self) -> bool:
        return True

    def wait_for_seq(self, min_seq: int, timeout_s: float) -> bool:
        # a seq token can only come from an acked mutation, which the
        # primary applied before acking; anything larger is a client bug
        return self.engine.wal.last_seq >= int(min_seq)

    def status(self) -> Dict:
        return {
            "role": self.role,
            "applied_seq": self.applied_seq,
            "replica_lag": 0,
            "ready": True,
        }


class ReplicaApplier:
    """Tails a primary's WAL directory and applies records to a follower.

    ``bootstrap()`` restores the newest valid snapshot (tolerating an empty
    state dir — WAL-only startup) and positions the cursor just past it;
    ``start()`` then polls ``wal/`` every ``poll_s`` on a background thread,
    applying new records under ``engine.lock``.  ``wait_for_seq`` blocks a
    serving thread until the follower has applied at least ``min_seq``
    (read-your-writes), bounded by the caller's deadline.

    Transient apply/poll errors (including injected ``wal_ship`` /
    ``replica_apply`` faults) are counted and retried on the next tick; a
    ``WALGap`` triggers a re-bootstrap from the newest snapshot.
    """

    role = "follower"

    def __init__(self, engine, state_dir: str, *,
                 poll_s: Optional[float] = None,
                 ready_lag_max: Optional[int] = None):
        rcfg = engine.config.replication
        self.engine = engine
        self.state_dir = state_dir
        self.wal_dir = os.path.join(state_dir, "wal")
        self.poll_s = float(rcfg.poll_s if poll_s is None else poll_s)
        self.ready_lag_max = int(rcfg.ready_lag_max if ready_lag_max is None
                                 else ready_lag_max)
        self._cursor = WALCursor(self.wal_dir)
        self._cv = threading.Condition()
        self._applied_seq = -1
        self._bootstrapped = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last_bootstrap: Optional[Dict] = None
        self.last_error: Optional[str] = None
        self.n_applied = 0
        self.n_bootstraps = 0
        self.n_poll_errors = 0
        self.n_apply_errors = 0

    # -- lifecycle -----------------------------------------------------------
    def bootstrap(self) -> Dict:
        """Restore the newest valid snapshot and position the WAL cursor.

        An empty state dir is fine (the follower starts from nothing and
        replays the whole WAL); so is WAL-only startup (no snapshot yet).
        Returns a report like ``recover()``'s, kept as ``last_bootstrap``.
        """
        t0 = time.perf_counter()
        report: Dict = {"status": "ok", "snapshot_step": None,
                        "fallbacks": 0, "duration_ms": 0.0}
        with self.engine.lock:
            if self.engine.wal is not None:
                raise RuntimeError(
                    "follower engine has its own WAL open — followers "
                    "replicate the primary's log, they do not write one")
            wal_seq = self.engine._restore_newest_snapshot(
                self.state_dir, report)
            with self._cv:
                self._cursor.seek(wal_seq)
                self._applied_seq = wal_seq
                self._bootstrapped = True
                self._cv.notify_all()
        report["duration_ms"] = (time.perf_counter() - t0) * 1e3
        self.last_bootstrap = report
        self.n_bootstraps += 1
        return report

    def start(self) -> None:
        """Start the background tailing thread (bootstraps first if the
        caller has not)."""
        if not self._bootstrapped:
            self.bootstrap()
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="replica-applier", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self._thread = None

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.catch_up()
            except Exception as e:             # keep tailing: transient
                self.last_error = f"{type(e).__name__}: {e}"
                self.n_poll_errors += 1
            self._stop.wait(self.poll_s)

    # -- applying ------------------------------------------------------------
    def catch_up(self, max_records: Optional[int] = None) -> int:
        """Poll the WAL tail once and apply what arrived; returns the
        number of records applied.  Called by the background thread every
        ``poll_s``, or directly for deterministic tests."""
        try:
            self.engine.faults.check("wal_ship")
            records = self._cursor.poll(max_records)
        except WALGap:
            # pruned past our position: the snapshot we need is newer than
            # our cursor — re-bootstrap and continue from there
            self.bootstrap()
            return 0
        applied = 0
        for rec in records:
            try:
                self.engine.faults.check("replica_apply")
                self.engine.apply_replicated(rec)
            except Exception as e:
                # rewind so the record is re-applied next tick — an
                # injected/transient failure must not skip a mutation
                self.last_error = f"{type(e).__name__}: {e}"
                self.n_apply_errors += 1
                self._cursor.seek(rec.seq - 1)
                break
            applied += 1
            self.n_applied += 1
            with self._cv:
                self._applied_seq = rec.seq
                self._cv.notify_all()
        return applied

    # -- read side -----------------------------------------------------------
    @property
    def applied_seq(self) -> int:
        return self._applied_seq

    def lag(self) -> int:
        """Durable records on the primary not yet applied here."""
        return max(0, self._cursor.last_available_seq() - self._applied_seq)

    def ready(self) -> bool:
        """Bootstrapped and caught up to within ``ready_lag_max``."""
        return self._bootstrapped and self.lag() <= self.ready_lag_max

    def wait_for_seq(self, min_seq: int, timeout_s: float) -> bool:
        """Block until ``applied_seq >= min_seq`` (read-your-writes); False
        on timeout."""
        min_seq = int(min_seq)
        deadline = time.monotonic() + max(0.0, timeout_s)
        with self._cv:
            while self._applied_seq < min_seq:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cv.wait(left)
            return True

    def status(self) -> Dict:
        return {
            "role": self.role,
            "applied_seq": self._applied_seq,
            "replica_lag": self.lag(),
            "ready": self.ready(),
            "bootstrapped": self._bootstrapped,
            "n_applied": self.n_applied,
            "n_bootstraps": self.n_bootstraps,
            "n_poll_errors": self.n_poll_errors,
            "n_apply_errors": self.n_apply_errors,
            "last_error": self.last_error,
            "last_bootstrap": self.last_bootstrap,
        }

"""Mutable corpus storage: capacity-doubling device buffers + validity mask.

The search path wants static shapes, but a serving corpus is mutable.  The
classic resolution (dynamic arrays, amortized O(1) append) carries over to
device memory: the store holds a (capacity, D) embedding buffer, the matching
(capacity, n_dims) prefix-norm table, and a (capacity,) bool validity mask.
Appends write into the tail with ``dynamic_update_slice``; when full, capacity
doubles (one recompile of the search program per doubling — O(log N) distinct
shapes over the corpus lifetime).  Deletes just clear the validity bit: the
mask is threaded through stage-0 scoring and candidate rescoring
(`repro.core.truncated`), so a dead row is unreturnable the moment the bit
flips, with no compaction pause.

Doc ids are append-only row positions (never reused), so ids held by callers
— e.g. the RAG pipeline's doc-token table — stay stable across mutations.
The one exception is ``compact()``: when the dead fraction is high the engine
rebuilds the buffers without tombstoned rows, which *remaps* every live id
(the returned old->new map lets callers follow; the engine fires its
``on_remap`` callbacks with it).

**Tenants + metadata (PR 6).**  Each row optionally carries a tenant
namespace and a flat metadata dict, stored host-side in columnar form
alongside the device buffers: an int32 tenant-id column plus one object
column per metadata field.  ``compile_mask`` compiles a (tenant, filter)
request constraint into a (capacity,) device bool mask — the search path
ANDs it with the validity mask and nothing else changes: one mask AND, zero
new search code in any backend.  Compiled masks are cached by their
canonical key and invalidated by ``mask_epoch`` (bumped on append / growth /
compaction — deletes don't invalidate, the validity AND already hides dead
rows).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.index import prefix_squared_norms
from repro.engine.request import FilterError, canonical_filter
from repro.index_backends.base import StoreStats

Array = jax.Array

# Rows added without a tenant land in this namespace id.
NO_TENANT = -1


class DocStore:
    """Append-only document store with tombstone deletes."""

    def __init__(
        self,
        d_emb: int,
        dims: Sequence[int],
        *,
        capacity: int = 1024,
        dtype=jnp.float32,
    ):
        if d_emb < 1:
            raise ValueError(f"d_emb must be >= 1, got {d_emb}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.d_emb = int(d_emb)
        self.dims: Tuple[int, ...] = tuple(int(x) for x in dims)
        # prefix_squared_norms is jitted: an out-of-range dim would CLAMP its
        # column gather (wrong norms, no error), so validate eagerly here.
        if list(self.dims) != sorted(set(self.dims)):
            raise ValueError(f"dims must be ascending/unique, got {self.dims}")
        if self.dims and (self.dims[0] < 1 or self.dims[-1] > self.d_emb):
            raise ValueError(
                f"dims must lie in [1, {self.d_emb}], got {self.dims}"
            )
        self.capacity = int(capacity)
        self._db = jnp.zeros((self.capacity, self.d_emb), dtype)
        self._sq = jnp.zeros((self.capacity, len(self.dims)), jnp.float32)
        self._valid = jnp.zeros((self.capacity,), bool)
        self.size = 0          # high-water mark; ids are 0..size-1
        self.n_active = 0      # rows with the validity bit set
        self.n_grows = 0
        self.n_compactions = 0
        self.generation = 0    # bumped on every mutation
        self.total_added = 0   # lifetime appends (monotonic across compaction)
        self.total_deleted = 0  # lifetime tombstones (monotonic)
        # -- tenancy + metadata (host-side, columnar) -----------------------
        self._tenant_col = np.full((self.capacity,), NO_TENANT, np.int32)
        self._tenant_ids: Dict[str, int] = {}       # name -> dense id
        self._tenant_names: List[str] = []          # dense id -> name
        self._tenant_active: Dict[str, int] = {}    # name -> live rows
        self._meta_cols: Dict[str, np.ndarray] = {}  # field -> (capacity,) obj
        # mask cache: canonical (tenant, filter) key -> (epoch, device mask).
        # mask_epoch tracks row-set/shape changes only (append/grow/compact);
        # tombstones are handled by the validity AND at dispatch.
        self.mask_epoch = 0
        self._mask_cache: "OrderedDict[Tuple, Tuple[int, Array]]" = (
            OrderedDict())
        self._mask_cache_cap = 256
        # hit/miss counters under the EngineStats discipline: plain ints
        # are the source of truth, mutated ONLY under engine.lock (every
        # mask_for_key caller — _execute / search — already holds it) and
        # published into the registry at scrape time by the engine's
        # collector, which also takes engine.lock.  Readers outside the
        # lock (e.g. /v1/stats) must go through mask_cache_stats() /
        # the collector — never the raw attributes
        self.mask_cache_hits = 0
        self.mask_cache_misses = 0

    # -- views the search path consumes ------------------------------------
    @property
    def db(self) -> Array:
        return self._db

    @property
    def sq_prefix(self) -> Array:
        return self._sq

    @property
    def valid(self) -> Array:
        return self._valid

    def __len__(self) -> int:
        return self.n_active

    def stats(self) -> StoreStats:
        """Mutation-counter snapshot (feeds backend ``needs_rebuild``)."""
        return StoreStats(
            size=self.size,
            n_active=self.n_active,
            capacity=self.capacity,
            generation=self.generation,
            total_added=self.total_added,
            total_deleted=self.total_deleted,
        )

    # -- mutation -----------------------------------------------------------
    def _grow_to(self, new_capacity: int) -> None:
        extra = new_capacity - self.capacity
        self._db = jnp.pad(self._db, ((0, extra), (0, 0)))
        self._sq = jnp.pad(self._sq, ((0, extra), (0, 0)))
        self._valid = jnp.pad(self._valid, (0, extra))
        self._tenant_col = np.concatenate(
            [self._tenant_col, np.full((extra,), NO_TENANT, np.int32)])
        for field, col in self._meta_cols.items():
            self._meta_cols[field] = np.concatenate(
                [col, np.full((extra,), None, object)])
        self.capacity = new_capacity
        self.n_grows += 1

    def add(self, vectors, *, tenant: Optional[str] = None,
            metadata=None) -> np.ndarray:
        """Append rows; returns their (stable) int64 doc ids.

        ``tenant`` namespaces the new rows (None = the tenantless pool);
        ``metadata`` is one flat dict applied to every row, or a sequence of
        per-row dicts.  Values must be str/int/float/bool/None — the same
        scalar universe the filter spec accepts.
        """
        vectors = jnp.asarray(vectors, self._db.dtype)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        b, d = vectors.shape
        if d != self.d_emb:
            raise ValueError(f"got dim {d}, store holds dim {self.d_emb}")
        metadata = self._check_metadata(metadata, b)
        new_cap = self.capacity
        while self.size + b > new_cap:
            new_cap *= 2
        if new_cap != self.capacity:
            self._grow_to(new_cap)

        start = self.size
        self._db = jax.lax.dynamic_update_slice(self._db, vectors, (start, 0))
        self._sq = jax.lax.dynamic_update_slice(
            self._sq, prefix_squared_norms(vectors, self.dims), (start, 0)
        )
        self._valid = jax.lax.dynamic_update_slice(
            self._valid, jnp.ones((b,), bool), (start,)
        )
        tid = self.tenant_id(tenant, create=True)
        self._tenant_col[start:start + b] = tid
        if tenant is not None:
            self._tenant_active[tenant] = (
                self._tenant_active.get(tenant, 0) + b)
        if metadata is not None:
            for j, row_meta in enumerate(metadata):
                for field, value in row_meta.items():
                    self._meta_col(field)[start + j] = value
        self.size += b
        self.n_active += b
        self.total_added += b
        self.generation += 1
        self.mask_epoch += 1
        return np.arange(start, start + b, dtype=np.int64)

    def delete(self, ids) -> int:
        """Tombstone rows by id; returns how many were live before the call."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        if ids.size == 0:
            return 0
        if ids.min() < 0 or ids.max() >= self.size:
            raise IndexError(
                f"doc ids must be in [0, {self.size}), got "
                f"[{ids.min()}, {ids.max()}]"
            )
        dev_ids = jnp.asarray(ids)
        was_live = np.asarray(self._valid[dev_ids])
        n_live = int(was_live.sum())
        self._valid = self._valid.at[dev_ids].set(False)
        for tid, cnt in zip(*np.unique(
                self._tenant_col[ids[was_live]], return_counts=True)):
            if tid != NO_TENANT:
                name = self._tenant_names[tid]
                self._tenant_active[name] -= int(cnt)
        self.n_active -= n_live
        self.total_deleted += n_live
        self.generation += 1
        return n_live

    def compact(self) -> np.ndarray:
        """Rebuild the buffers without tombstoned rows; REMAPS live doc ids.

        Live rows slide down to the front (order preserved), the buffers
        shrink to the smallest power-of-two capacity that holds them, and
        every previously-issued doc id becomes invalid.  Returns the
        (old_size,) int64 old->new id map, -1 for dead rows — callers that
        hold ids (the engine's unpolled results, the RAG pipeline's
        doc-token table) must apply it.

        Index-backend states built before a compaction reference old ids;
        the engine rebuilds immediately after compacting, never serving a
        pre-compaction state against post-compaction buffers.
        """
        valid_np = np.asarray(self._valid[: self.size])
        live = np.nonzero(valid_np)[0]
        n_live = int(live.size)
        id_map = np.full((self.size,), -1, np.int64)
        id_map[live] = np.arange(n_live)

        new_cap = 1
        while new_cap < max(n_live, 1):
            new_cap *= 2
        gather = jnp.asarray(live, jnp.int32)
        pad = new_cap - n_live
        self._db = jnp.pad(self._db[gather], ((0, pad), (0, 0)))
        self._sq = jnp.pad(self._sq[gather], ((0, pad), (0, 0)))
        self._valid = jnp.pad(jnp.ones((n_live,), bool), (0, pad))
        tenants = np.full((new_cap,), NO_TENANT, np.int32)
        tenants[:n_live] = self._tenant_col[live]
        self._tenant_col = tenants
        for field, col in self._meta_cols.items():
            packed = np.full((new_cap,), None, object)
            packed[:n_live] = col[live]
            self._meta_cols[field] = packed
        self.capacity = new_cap
        self.size = n_live
        self.n_active = n_live
        self.n_compactions += 1
        self.generation += 1
        self.mask_epoch += 1
        return id_map

    def is_live(self, doc_id: int) -> bool:
        if not 0 <= doc_id < self.size:
            return False
        return bool(self._valid[doc_id])

    # -- snapshot / restore (crash recovery) --------------------------------
    def snapshot_state(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Host-side snapshot of the full store state: (arrays, meta).

        ``arrays`` is npz-friendly (db/valid/tenant rows up to ``size``);
        ``meta`` is msgpack-friendly (counters, tenant names, metadata
        columns as plain lists).  Prefix norms are NOT saved — they are a
        pure function of db and are recomputed on restore, which also
        makes a snapshot portable across engines whose ``dims`` differ.
        """
        arrays = {
            "db": np.asarray(self._db[: self.size]),
            "valid": np.asarray(self._valid[: self.size]),
            "tenant_col": np.asarray(self._tenant_col[: self.size]),
        }
        meta = {
            "d_emb": self.d_emb,
            "capacity": self.capacity,
            "size": self.size,
            "n_active": self.n_active,
            "generation": self.generation,
            "total_added": self.total_added,
            "total_deleted": self.total_deleted,
            "n_compactions": self.n_compactions,
            "tenant_names": list(self._tenant_names),
            "meta_cols": {
                field: list(col[: self.size])
                for field, col in self._meta_cols.items()
            },
        }
        return arrays, meta

    def restore_state(self, arrays: Dict[str, np.ndarray],
                      meta: Dict) -> None:
        """Replace the store's contents with a ``snapshot_state`` capture.

        Buffers are rebuilt at the smallest power-of-two capacity >= the
        snapshot size (never below the configured capacity), prefix norms
        are recomputed, and the mask cache is invalidated.
        """
        if int(meta["d_emb"]) != self.d_emb:
            raise ValueError(
                f"snapshot holds d_emb={meta['d_emb']}, store expects "
                f"{self.d_emb}")
        db = np.asarray(arrays["db"])
        valid = np.asarray(arrays["valid"], bool)
        tenant_col = np.asarray(arrays["tenant_col"], np.int32)
        size = int(meta["size"])
        if db.shape != (size, self.d_emb) or valid.shape != (size,) \
                or tenant_col.shape != (size,):
            raise ValueError(
                f"snapshot arrays inconsistent with size={size}: "
                f"db {db.shape}, valid {valid.shape}, "
                f"tenant_col {tenant_col.shape}")
        # adopt the snapshot's capacity when it fits (keeps compiled-shape
        # reuse and saved index states consistent across the restart);
        # otherwise grow a doubling at a time as add() would
        new_cap = max(self.capacity, int(meta.get("capacity", 0)))
        while new_cap < max(size, 1):
            new_cap *= 2
        pad = new_cap - size
        self._db = jnp.pad(jnp.asarray(db, self._db.dtype),
                           ((0, pad), (0, 0)))
        self._sq = prefix_squared_norms(self._db, self.dims)
        self._valid = jnp.pad(jnp.asarray(valid), (0, pad))
        self.capacity = new_cap
        self.size = size
        self.n_active = int(valid.sum())
        if self.n_active != int(meta["n_active"]):
            raise ValueError(
                f"snapshot n_active={meta['n_active']} disagrees with its "
                f"validity mask ({self.n_active} live rows)")
        self.generation = int(meta["generation"])
        self.total_added = int(meta["total_added"])
        self.total_deleted = int(meta["total_deleted"])
        self.n_compactions = int(meta.get("n_compactions", 0))
        col = np.full((new_cap,), NO_TENANT, np.int32)
        col[:size] = tenant_col
        self._tenant_col = col
        self._tenant_names = [str(t) for t in meta.get("tenant_names", [])]
        self._tenant_ids = {t: i for i, t in enumerate(self._tenant_names)}
        self._tenant_active = {}
        live_tids = tenant_col[valid]
        for tid, cnt in zip(*np.unique(live_tids, return_counts=True)):
            if tid != NO_TENANT:
                self._tenant_active[self._tenant_names[tid]] = int(cnt)
        self._meta_cols = {}
        for field, values in meta.get("meta_cols", {}).items():
            packed = np.full((new_cap,), None, object)
            packed[:size] = values
            self._meta_cols[field] = packed
        self.mask_epoch += 1
        self._mask_cache.clear()

    # -- tenancy + metadata --------------------------------------------------
    @staticmethod
    def _check_metadata(metadata, batch: int):
        """Normalize add()'s metadata arg to a per-row list of dicts."""
        if metadata is None:
            return None
        if isinstance(metadata, dict):
            metadata = [metadata] * batch
        metadata = list(metadata)
        if len(metadata) != batch:
            raise ValueError(
                f"metadata holds {len(metadata)} rows for {batch} vectors")
        for row_meta in metadata:
            if row_meta is None:
                continue
            if not isinstance(row_meta, dict):
                raise FilterError(
                    f"metadata rows must be dicts, got "
                    f"{type(row_meta).__name__}")
            for field, value in row_meta.items():
                if not isinstance(field, str) or not field:
                    raise FilterError(
                        f"metadata field names must be non-empty strings, "
                        f"got {field!r}")
                if value is not None and not isinstance(
                        value, (str, int, float, bool)):
                    raise FilterError(
                        f"metadata field {field!r}: values must be "
                        f"str/int/float/bool/None, got "
                        f"{type(value).__name__}")
        return [m or {} for m in metadata]

    def _meta_col(self, field: str) -> np.ndarray:
        col = self._meta_cols.get(field)
        if col is None:
            col = np.full((self.capacity,), None, object)
            self._meta_cols[field] = col
        return col

    def tenant_id(self, tenant: Optional[str], *,
                  create: bool = False) -> int:
        """Dense id for a tenant name (NO_TENANT for None; -2 for a name
        that was never used and ``create=False`` — matches no row)."""
        if tenant is None:
            return NO_TENANT
        tid = self._tenant_ids.get(tenant)
        if tid is None:
            if not create:
                return -2
            tid = len(self._tenant_names)
            self._tenant_ids[tenant] = tid
            self._tenant_names.append(tenant)
        return tid

    def tenant_of(self, doc_id: int) -> Optional[str]:
        """Tenant name of one row (None for the tenantless pool)."""
        if not 0 <= doc_id < self.size:
            raise IndexError(f"doc id {doc_id} out of range [0, {self.size})")
        tid = int(self._tenant_col[doc_id])
        return None if tid == NO_TENANT else self._tenant_names[tid]

    def tenant_doc_count(self, tenant: str) -> int:
        """Live rows currently held by ``tenant`` (quota accounting)."""
        return self._tenant_active.get(tenant, 0)

    def tenants(self) -> Dict[str, int]:
        """Snapshot of {tenant: live rows} for every tenant ever seen."""
        return dict(self._tenant_active)

    def metadata_of(self, doc_id: int) -> Dict:
        """The metadata fields set on one row (empty dict when none)."""
        if not 0 <= doc_id < self.size:
            raise IndexError(f"doc id {doc_id} out of range [0, {self.size})")
        out = {}
        for field, col in self._meta_cols.items():
            if col[doc_id] is not None:
                out[field] = col[doc_id]
        return out

    # -- filter-mask compiler ------------------------------------------------
    def compile_mask(self, tenant: Optional[str] = None,
                     filt=None) -> Optional[Tuple]:
        """Validate a (tenant, filter) constraint; returns its mask key.

        The key is hashable — batch formation groups requests by it — and
        ``mask_for_key`` turns it into the (capacity,) device bool mask at
        dispatch time.  None means "no constraint" (nothing is compiled and
        the dispatch skips the AND entirely).
        """
        canon = canonical_filter(filt)
        if tenant is None and canon is None:
            return None
        return (tenant, canon)

    def mask_for_key(self, key: Optional[Tuple]) -> Optional[Array]:
        """(capacity,) device bool mask for a ``compile_mask`` key.

        Cached per key and recompiled when ``mask_epoch`` moved (rows were
        appended, buffers grew, or a compaction reshuffled them) — so a mask
        compiled at submit time can never be stale or mis-shaped by the time
        its batch dispatches.  Tombstones don't invalidate: the dispatch
        ANDs the live validity mask on top.
        """
        if key is None:
            return None
        hit = self._mask_cache.get(key)
        if hit is not None and hit[0] == self.mask_epoch:
            self.mask_cache_hits += 1
            self._mask_cache.move_to_end(key)
            return hit[1]
        self.mask_cache_misses += 1
        tenant, canon = key
        mask = np.ones((self.size,), bool)
        if tenant is not None:
            mask &= self._tenant_col[:self.size] == self.tenant_id(tenant)
        if canon is not None:
            for field, ops in canon:
                col = self._meta_cols.get(field)
                for op, value in ops:
                    mask &= self._field_mask(col, op, value)
        dev = jnp.asarray(np.pad(mask, (0, self.capacity - self.size)))
        self._mask_cache[key] = (self.mask_epoch, dev)
        self._mask_cache.move_to_end(key)
        while len(self._mask_cache) > self._mask_cache_cap:
            self._mask_cache.popitem(last=False)
        return dev

    def mask_cache_stats(self) -> Dict[str, int]:
        """Snapshot of the mask-cache counters.  Call under ``engine.lock``
        (the counters mutate there); the dict itself is then safe to hand
        to any thread."""
        return {
            "hits": self.mask_cache_hits,
            "misses": self.mask_cache_misses,
            "entries": len(self._mask_cache),
            "epoch": self.mask_epoch,
        }

    def _field_mask(self, col: Optional[np.ndarray], op: str,
                    value) -> np.ndarray:
        """(size,) bool mask for one (field op value) term.

        Missing-field semantics follow MongoDB: a row without the field
        matches ``$ne`` / ``$nin`` / ``$exists: False`` and nothing else.
        """
        n = self.size
        if col is None:                      # field never set on any row
            if op in ("$ne", "$nin"):
                return np.ones((n,), bool)
            if op == "$exists":
                return np.full((n,), not value)
            return np.zeros((n,), bool)
        vals = col[:n]
        present = np.array([v is not None for v in vals], bool)
        if op == "$exists":
            return present if value else ~present
        if op in ("$eq", "$ne"):
            eq = np.array([v is not None and v == value for v in vals], bool)
            return eq if op == "$eq" else ~eq
        if op in ("$in", "$nin"):
            allowed = set(value)
            isin = np.array(
                [v is not None and v in allowed for v in vals], bool)
            return isin if op == "$in" else ~isin
        cmp = {"$gt": lambda v: v > value, "$gte": lambda v: v >= value,
               "$lt": lambda v: v < value, "$lte": lambda v: v <= value}[op]
        return np.array(
            [v is not None and not isinstance(v, (str, bool)) and cmp(v)
             for v in vals], bool)

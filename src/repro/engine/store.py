"""Mutable corpus storage: capacity-doubling device buffers + validity mask.

The search path wants static shapes, but a serving corpus is mutable.  The
classic resolution (dynamic arrays, amortized O(1) append) carries over to
device memory: the store holds a (capacity, D) embedding buffer, the matching
(capacity, n_dims) prefix-norm table, and a (capacity,) bool validity mask.
Appends write into the tail with ``dynamic_update_slice``; when full, capacity
doubles (one recompile of the search program per doubling — O(log N) distinct
shapes over the corpus lifetime).  Deletes just clear the validity bit: the
mask is threaded through stage-0 scoring and candidate rescoring
(`repro.core.truncated`), so a dead row is unreturnable the moment the bit
flips, with no compaction pause.

Doc ids are append-only row positions (never reused), so ids held by callers
— e.g. the RAG pipeline's doc-token table — stay stable across mutations.
The one exception is ``compact()``: when the dead fraction is high the engine
rebuilds the buffers without tombstoned rows, which *remaps* every live id
(the returned old->new map lets callers follow; the engine fires its
``on_remap`` callbacks with it).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.index import prefix_squared_norms
from repro.index_backends.base import StoreStats

Array = jax.Array


class DocStore:
    """Append-only document store with tombstone deletes."""

    def __init__(
        self,
        d_emb: int,
        dims: Sequence[int],
        *,
        capacity: int = 1024,
        dtype=jnp.float32,
    ):
        if d_emb < 1:
            raise ValueError(f"d_emb must be >= 1, got {d_emb}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.d_emb = int(d_emb)
        self.dims: Tuple[int, ...] = tuple(int(x) for x in dims)
        # prefix_squared_norms is jitted: an out-of-range dim would CLAMP its
        # column gather (wrong norms, no error), so validate eagerly here.
        if list(self.dims) != sorted(set(self.dims)):
            raise ValueError(f"dims must be ascending/unique, got {self.dims}")
        if self.dims and (self.dims[0] < 1 or self.dims[-1] > self.d_emb):
            raise ValueError(
                f"dims must lie in [1, {self.d_emb}], got {self.dims}"
            )
        self.capacity = int(capacity)
        self._db = jnp.zeros((self.capacity, self.d_emb), dtype)
        self._sq = jnp.zeros((self.capacity, len(self.dims)), jnp.float32)
        self._valid = jnp.zeros((self.capacity,), bool)
        self.size = 0          # high-water mark; ids are 0..size-1
        self.n_active = 0      # rows with the validity bit set
        self.n_grows = 0
        self.n_compactions = 0
        self.generation = 0    # bumped on every mutation
        self.total_added = 0   # lifetime appends (monotonic across compaction)
        self.total_deleted = 0  # lifetime tombstones (monotonic)

    # -- views the search path consumes ------------------------------------
    @property
    def db(self) -> Array:
        return self._db

    @property
    def sq_prefix(self) -> Array:
        return self._sq

    @property
    def valid(self) -> Array:
        return self._valid

    def __len__(self) -> int:
        return self.n_active

    def stats(self) -> StoreStats:
        """Mutation-counter snapshot (feeds backend ``needs_rebuild``)."""
        return StoreStats(
            size=self.size,
            n_active=self.n_active,
            capacity=self.capacity,
            generation=self.generation,
            total_added=self.total_added,
            total_deleted=self.total_deleted,
        )

    # -- mutation -----------------------------------------------------------
    def _grow_to(self, new_capacity: int) -> None:
        extra = new_capacity - self.capacity
        self._db = jnp.pad(self._db, ((0, extra), (0, 0)))
        self._sq = jnp.pad(self._sq, ((0, extra), (0, 0)))
        self._valid = jnp.pad(self._valid, (0, extra))
        self.capacity = new_capacity
        self.n_grows += 1

    def add(self, vectors) -> np.ndarray:
        """Append rows; returns their (stable) int64 doc ids."""
        vectors = jnp.asarray(vectors, self._db.dtype)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        b, d = vectors.shape
        if d != self.d_emb:
            raise ValueError(f"got dim {d}, store holds dim {self.d_emb}")
        new_cap = self.capacity
        while self.size + b > new_cap:
            new_cap *= 2
        if new_cap != self.capacity:
            self._grow_to(new_cap)

        start = self.size
        self._db = jax.lax.dynamic_update_slice(self._db, vectors, (start, 0))
        self._sq = jax.lax.dynamic_update_slice(
            self._sq, prefix_squared_norms(vectors, self.dims), (start, 0)
        )
        self._valid = jax.lax.dynamic_update_slice(
            self._valid, jnp.ones((b,), bool), (start,)
        )
        self.size += b
        self.n_active += b
        self.total_added += b
        self.generation += 1
        return np.arange(start, start + b, dtype=np.int64)

    def delete(self, ids) -> int:
        """Tombstone rows by id; returns how many were live before the call."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        if ids.size == 0:
            return 0
        if ids.min() < 0 or ids.max() >= self.size:
            raise IndexError(
                f"doc ids must be in [0, {self.size}), got "
                f"[{ids.min()}, {ids.max()}]"
            )
        dev_ids = jnp.asarray(ids)
        n_live = int(self._valid[dev_ids].sum())
        self._valid = self._valid.at[dev_ids].set(False)
        self.n_active -= n_live
        self.total_deleted += n_live
        self.generation += 1
        return n_live

    def compact(self) -> np.ndarray:
        """Rebuild the buffers without tombstoned rows; REMAPS live doc ids.

        Live rows slide down to the front (order preserved), the buffers
        shrink to the smallest power-of-two capacity that holds them, and
        every previously-issued doc id becomes invalid.  Returns the
        (old_size,) int64 old->new id map, -1 for dead rows — callers that
        hold ids (the engine's unpolled results, the RAG pipeline's
        doc-token table) must apply it.

        Index-backend states built before a compaction reference old ids;
        the engine rebuilds immediately after compacting, never serving a
        pre-compaction state against post-compaction buffers.
        """
        valid_np = np.asarray(self._valid[: self.size])
        live = np.nonzero(valid_np)[0]
        n_live = int(live.size)
        id_map = np.full((self.size,), -1, np.int64)
        id_map[live] = np.arange(n_live)

        new_cap = 1
        while new_cap < max(n_live, 1):
            new_cap *= 2
        gather = jnp.asarray(live, jnp.int32)
        pad = new_cap - n_live
        self._db = jnp.pad(self._db[gather], ((0, pad), (0, 0)))
        self._sq = jnp.pad(self._sq[gather], ((0, pad), (0, 0)))
        self._valid = jnp.pad(jnp.ones((n_live,), bool), (0, pad))
        self.capacity = new_cap
        self.size = n_live
        self.n_active = n_live
        self.n_compactions += 1
        self.generation += 1
        return id_map

    def is_live(self, doc_id: int) -> bool:
        if not 0 <= doc_id < self.size:
            return False
        return bool(self._valid[doc_id])

"""Typed engine/backend configuration: eager validation + serialization.

PR 6 replaces the stringly-typed ``RetrievalEngine(backend="ivf",
backend_opts={...})`` surface (and the engine's 18-kwarg ``__init__``) with
config dataclasses:

    cfg = EngineConfig(d_emb=256, final_k=10,
                       backend=IVFConfig(n_lists=64, n_probe=8))
    engine = RetrievalEngine(config=cfg)

* **Eager validation** — a typo'd backend option used to surface as a
  ``TypeError`` deep inside ``make_backend`` (or silently at first build);
  config construction now rejects it immediately, with the field named.
* **Serialization** — ``to_dict()`` / ``from_dict()`` round-trip through
  JSON, which is what the HTTP ``stats`` endpoint reports and what
  ``from_flags`` (the shared CLI surface for ``launch.serve`` and the
  benchmarks) builds.
* **Back-compat** — the old kwargs keep working: ``RetrievalEngine(d_emb,
  backend="ivf", backend_opts={...})`` constructs the equivalent
  ``EngineConfig`` through ``legacy_config`` under the hood, so callers
  migrate incrementally (``engine.config`` is always populated either way).
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple, Union


def _validate_choice(obj, field: str, choices) -> None:
    if getattr(obj, field) not in choices:
        raise ValueError(
            f"{type(obj).__name__}.{field} must be one of {choices}, got "
            f"{getattr(obj, field)!r}")


def _validate_positive(obj, *fields: str) -> None:
    for field in fields:
        value = getattr(obj, field)
        if value is not None and value < 1:
            raise ValueError(
                f"{type(obj).__name__}.{field} must be >= 1, got {value}")


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Base for per-backend option blocks (see `repro.index_backends`)."""

    name: ClassVar[str] = "?"

    def opts(self) -> Dict:
        """The backend-constructor kwargs this config carries."""
        return dataclasses.asdict(self)

    def to_dict(self) -> Dict:
        return {"backend": self.name, **self.opts()}


@dataclasses.dataclass(frozen=True)
class FlatConfig(BackendConfig):
    """Exact flat scan — the paper's progressive search, no build artifact."""

    name: ClassVar[str] = "flat"


@dataclasses.dataclass(frozen=True)
class IVFConfig(BackendConfig):
    """IVF coarse quantizer (optionally fused-Pallas / int8 / PQ stage-0)."""

    name: ClassVar[str] = "ivf"

    n_lists: Optional[int] = None
    n_probe: int = 12
    probe_dim: Optional[int] = None
    balance_factor: Optional[float] = 2.0
    assign_m: int = 8
    kmeans_iters: int = 10
    train_rows: int = 131072
    assign_block: int = 65536
    rebuild_frac: float = 0.25
    min_rebuild_rows: int = 64
    tail_window: int = 512
    min_index_rows: int = 64
    append_spare: int = 8
    use_kernel: Union[str, bool] = "auto"
    stage0_dtype: str = "float32"
    kernel_block_m: int = 128
    kernel_merge: str = "sort"
    pq_m: Optional[int] = None
    pq_codes: int = 256
    pq_iters: int = 10
    pq_oversample: int = 4
    seed: int = 0

    def __post_init__(self):
        _validate_choice(self, "stage0_dtype", ("float32", "int8", "pq"))
        _validate_choice(self, "use_kernel", ("auto", True, False))
        _validate_choice(self, "kernel_merge", ("sort", "select"))
        _validate_positive(
            self, "n_lists", "n_probe", "kmeans_iters", "train_rows",
            "tail_window", "kernel_block_m", "pq_m", "pq_codes",
            "pq_oversample")
        if not 0 < self.rebuild_frac:
            raise ValueError(
                f"IVFConfig.rebuild_frac must be > 0, got "
                f"{self.rebuild_frac}")
        if not 1 <= self.pq_codes <= 256:
            raise ValueError(
                f"IVFConfig.pq_codes must lie in [1, 256], got "
                f"{self.pq_codes}")


@dataclasses.dataclass(frozen=True)
class QuantizedConfig(BackendConfig):
    """Quantized stage-0 block (int8 per-dim or PQ/ADC), exact rescore."""

    name: ClassVar[str] = "quantized"

    rebuild_frac: float = 0.25
    min_rebuild_rows: int = 64
    tail_window: int = 512
    codec: str = "int8"
    pq_m: Optional[int] = None
    pq_codes: int = 256
    pq_iters: int = 10
    pq_train_rows: int = 65536
    pq_oversample: int = 4
    encode_appends: bool = True
    use_kernel: Union[str, bool] = "auto"
    kernel_block_m: int = 128
    kernel_merge: str = "sort"
    seed: int = 0

    def __post_init__(self):
        _validate_choice(self, "codec", ("int8", "pq"))
        _validate_choice(self, "use_kernel", ("auto", True, False))
        _validate_choice(self, "kernel_merge", ("sort", "select"))
        _validate_positive(
            self, "tail_window", "kernel_block_m", "pq_m", "pq_codes",
            "pq_train_rows", "pq_oversample")
        if not 0 < self.rebuild_frac:
            raise ValueError(
                f"QuantizedConfig.rebuild_frac must be > 0, got "
                f"{self.rebuild_frac}")
        if not 1 <= self.pq_codes <= 256:
            raise ValueError(
                f"QuantizedConfig.pq_codes must lie in [1, 256], got "
                f"{self.pq_codes}")


@dataclasses.dataclass(frozen=True)
class CustomBackendConfig(BackendConfig):
    """Name-only record of a pre-constructed ``IndexBackend`` instance.

    User-registered backends plug into the engine as live instances (the
    protocol's extension point); this block keeps ``engine.config``
    populated and serializable for them, but carries no options and cannot
    reconstruct the backend.
    """

    custom_name: str = "?"

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.custom_name

    def opts(self) -> Dict:
        return {}


_BACKEND_CONFIGS: Dict[str, type] = {
    cls.name: cls for cls in (FlatConfig, IVFConfig, QuantizedConfig)
}


def backend_config(name: str, opts: Optional[Dict] = None) -> BackendConfig:
    """Build the typed config for a named backend from legacy-style opts.

    Raises the same "unknown index backend" ``ValueError`` the registry
    would, and a pointed error for an option the backend doesn't take —
    eagerly, instead of a ``TypeError`` inside ``make_backend``.
    """
    cls = _BACKEND_CONFIGS.get(name)
    if cls is None:
        from repro.index_backends import backend_names
        raise ValueError(
            f"unknown index backend {name!r}; available: {backend_names()}")
    opts = dict(opts or {})
    known = {f.name for f in dataclasses.fields(cls)}
    bad = sorted(set(opts) - known)
    if bad:
        raise ValueError(
            f"{cls.__name__} does not take option(s) {bad}; known options: "
            f"{sorted(known)}")
    return cls(**opts)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability section of `EngineConfig` (see `repro.obs`).

    * ``enabled`` — master switch.  ``False`` degrades every metric
      instrument to a shared no-op and skips trace contexts entirely,
      restoring the uninstrumented fast path (the overhead benchmark's
      baseline).
    * ``slow_query_ms`` — latency threshold for the structured JSON
      slow-query log (None disables the log).
    * ``trace_ring`` — capacity of the in-memory ring of recent request
      traces (0 disables it).
    * ``stage_fences`` — opt-in ``block_until_ready`` fence between the
      stage-0 scan and the rescore ladder on the batched (driver) path, so
      traces carry a real stage-0/rescore split.  Off by default: the
      fence costs one extra host sync per batch, and the default path
      stays fused exactly as before.
    """

    enabled: bool = True
    slow_query_ms: Optional[float] = None
    trace_ring: int = 256
    stage_fences: bool = False

    def __post_init__(self):
        if self.slow_query_ms is not None and self.slow_query_ms < 0:
            raise ValueError(
                f"ObsConfig.slow_query_ms must be >= 0 or None, got "
                f"{self.slow_query_ms}")
        if self.trace_ring < 0:
            raise ValueError(
                f"ObsConfig.trace_ring must be >= 0, got {self.trace_ring}")

    @classmethod
    def from_dict(cls, d: Dict) -> "ObsConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"ObsConfig does not take field(s) {bad}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Load-adaptive search policy section (see `repro.engine.adaptive`).

    Level ``L >= 1`` is entered when driver queue depth reaches
    ``depth_high * escalate_factor**(L-1)`` (or queue-wait p95 reaches the
    analogous ``wait_high_ms`` rung); each level scales the per-dispatch
    knobs by ``n_probe_scale**L`` / ``oversample_scale**L`` and enters the
    progressive ladder ``d_start_shift * L`` doublings higher (clamped to
    ``min_d_start``..d_start).  Recovery steps down one level after
    ``hysteresis_s`` seconds of continuous calm below ``recover_frac`` of
    the current level's entry thresholds.  ``enabled=False`` (default)
    keeps the static path byte-identical — no degraded schedules are
    built and no overrides ever reach a backend.
    """

    enabled: bool = False
    levels: int = 2
    depth_high: int = 32
    wait_high_ms: Optional[float] = 50.0
    escalate_factor: float = 2.0
    recover_frac: float = 0.5
    hysteresis_s: float = 2.0
    n_probe_scale: float = 0.5
    oversample_scale: float = 0.5
    d_start_shift: int = 1
    min_d_start: int = 8

    def __post_init__(self):
        _validate_positive(self, "levels", "depth_high", "min_d_start")
        if self.wait_high_ms is not None and self.wait_high_ms <= 0:
            raise ValueError(
                f"AdaptiveConfig.wait_high_ms must be > 0 or None, got "
                f"{self.wait_high_ms}")
        if self.escalate_factor < 1.0:
            raise ValueError(
                f"AdaptiveConfig.escalate_factor must be >= 1, got "
                f"{self.escalate_factor}")
        if not 0 < self.recover_frac <= 1:
            raise ValueError(
                f"AdaptiveConfig.recover_frac must lie in (0, 1], got "
                f"{self.recover_frac}")
        if self.hysteresis_s < 0:
            raise ValueError(
                f"AdaptiveConfig.hysteresis_s must be >= 0, got "
                f"{self.hysteresis_s}")
        for f in ("n_probe_scale", "oversample_scale"):
            if not 0 < getattr(self, f) <= 1:
                raise ValueError(
                    f"AdaptiveConfig.{f} must lie in (0, 1], got "
                    f"{getattr(self, f)}")
        if self.d_start_shift < 0:
            raise ValueError(
                f"AdaptiveConfig.d_start_shift must be >= 0, got "
                f"{self.d_start_shift}")

    @classmethod
    def from_dict(cls, d: Dict) -> "AdaptiveConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"AdaptiveConfig does not take field(s) {bad}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Query-result cache section (see `repro.engine.qcache`).

    ``capacity`` bounds live entries (LRU beyond it); ``near_eps > 0``
    additionally serves near-duplicate queries within that squared-L2
    distance of a cached query (same tenant/filter mask and degradation
    level only).  Invalidation is structural — any store generation /
    mask-epoch / rebuild bump flushes the cache — so no TTL knob exists.
    """

    enabled: bool = False
    capacity: int = 1024
    near_eps: float = 0.0

    def __post_init__(self):
        _validate_positive(self, "capacity")
        if self.near_eps < 0:
            raise ValueError(
                f"CacheConfig.near_eps must be >= 0, got {self.near_eps}")

    @classmethod
    def from_dict(cls, d: Dict) -> "CacheConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"CacheConfig does not take field(s) {bad}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Replication section (see `repro.engine.replication`).

    * ``role`` — ``single`` (no replication), ``primary`` (owns the WAL;
      mutations land here), or ``follower`` (read-only; bootstraps from the
      shared state dir's newest snapshot and tails the primary's WAL).
    * ``poll_s`` — follower WAL-tail poll interval.
    * ``ready_lag_max`` — readiness bound: a follower reports ready only
      once bootstrapped and within this many records of the primary's tail
      (``/healthz?ready=1``).
    * ``min_seq_wait_s`` — serving-side cap on how long a search holding a
      ``min_seq`` consistency token waits for catch-up before returning a
      retryable 503 (bounded further by the request deadline).
    """

    role: str = "single"
    poll_s: float = 0.05
    ready_lag_max: int = 0
    min_seq_wait_s: float = 1.0

    def __post_init__(self):
        _validate_choice(self, "role", ("single", "primary", "follower"))
        if self.poll_s <= 0:
            raise ValueError(
                f"ReplicationConfig.poll_s must be > 0, got {self.poll_s}")
        if self.ready_lag_max < 0:
            raise ValueError(
                f"ReplicationConfig.ready_lag_max must be >= 0, got "
                f"{self.ready_lag_max}")
        if self.min_seq_wait_s < 0:
            raise ValueError(
                f"ReplicationConfig.min_seq_wait_s must be >= 0, got "
                f"{self.min_seq_wait_s}")

    @classmethod
    def from_dict(cls, d: Dict) -> "ReplicationConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(
                f"ReplicationConfig does not take field(s) {bad}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultToleranceConfig:
    """Fault-tolerance section (see `repro.engine.wal` / ``.supervise`` /
    ``.faults``).

    * ``wal_fsync`` — fsync every WAL append before acknowledging the
      mutation (the durability guarantee; turn off only for benchmarks).
    * ``snapshot_keep`` — snapshots retained by ``save_snapshot``; WAL
      segments covered by the oldest retained snapshot are pruned, so a
      torn-newest fallback can still replay.
    * ``heartbeat_timeout_s`` — driver heartbeat age AND oldest-pending
      wait beyond which the supervisor declares the thread hung.
    * ``max_restarts`` — consecutive restarts before the supervisor gives
      up and fails pending requests (the crash loop is then fatal).
    * ``backoff_initial_s`` / ``backoff_max_s`` — capped exponential
      restart backoff.
    * ``rebuild_retries`` — consecutive background-rebuild failures
      tolerated (relaunched at the next safe point) before the error
      escalates to the dispatch path.
    * ``poison_bisect`` — isolate a failing batch by bisection so only the
      offending request fails (``RequestFailed`` / HTTP 503).
    * ``inject`` / ``inject_seed`` — deterministic fault-injection spec
      (see `repro.engine.faults.FaultPlan.parse`); empty = inert.
    """

    wal_fsync: bool = True
    snapshot_keep: int = 3
    heartbeat_timeout_s: float = 5.0
    max_restarts: int = 5
    backoff_initial_s: float = 0.05
    backoff_max_s: float = 2.0
    rebuild_retries: int = 3
    poison_bisect: bool = True
    inject: str = ""
    inject_seed: int = 0

    def __post_init__(self):
        _validate_positive(self, "snapshot_keep")
        for f in ("heartbeat_timeout_s", "backoff_initial_s",
                  "backoff_max_s"):
            if getattr(self, f) <= 0:
                raise ValueError(
                    f"FaultToleranceConfig.{f} must be > 0, got "
                    f"{getattr(self, f)}")
        if self.max_restarts < 0 or self.rebuild_retries < 0:
            raise ValueError(
                f"FaultToleranceConfig.max_restarts/rebuild_retries must "
                f"be >= 0, got {self.max_restarts}/{self.rebuild_retries}")
        # parse eagerly so a typo'd spec fails at config time, not at the
        # first fault-site check deep inside a dispatch
        from repro.engine.faults import FaultPlan
        FaultPlan.parse(self.inject, seed=self.inject_seed)

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultToleranceConfig":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(
                f"FaultToleranceConfig does not take field(s) {bad}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Full static configuration of a `RetrievalEngine`.

    ``backend`` is a typed per-backend block (``FlatConfig`` / ``IVFConfig``
    / ``QuantizedConfig``).  Everything validates at construction; the
    schedule itself is derived from (d_start, k0, final_k) exactly as the
    legacy kwargs did (pass ``schedule=`` to the engine to override).
    """

    d_emb: int
    d_start: int = 32
    k0: int = 32
    final_k: int = 1
    buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    capacity: int = 1024
    metric: str = "l2"
    block_n: int = 65536
    max_unpolled: int = 65536
    backend: BackendConfig = dataclasses.field(default_factory=FlatConfig)
    rebuild_mode: str = "sync"
    compact_dead_frac: Optional[float] = 0.3
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    adaptive: AdaptiveConfig = dataclasses.field(
        default_factory=AdaptiveConfig)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    fault: FaultToleranceConfig = dataclasses.field(
        default_factory=FaultToleranceConfig)
    replication: ReplicationConfig = dataclasses.field(
        default_factory=ReplicationConfig)

    def __post_init__(self):
        _validate_positive(self, "d_emb", "d_start", "k0", "final_k",
                           "capacity", "block_n", "max_unpolled")
        if not isinstance(self.obs, ObsConfig):
            raise ValueError(
                f"EngineConfig.obs must be an ObsConfig, got "
                f"{type(self.obs).__name__}")
        if not isinstance(self.adaptive, AdaptiveConfig):
            raise ValueError(
                f"EngineConfig.adaptive must be an AdaptiveConfig, got "
                f"{type(self.adaptive).__name__}")
        if not isinstance(self.cache, CacheConfig):
            raise ValueError(
                f"EngineConfig.cache must be a CacheConfig, got "
                f"{type(self.cache).__name__}")
        if not isinstance(self.fault, FaultToleranceConfig):
            raise ValueError(
                f"EngineConfig.fault must be a FaultToleranceConfig, got "
                f"{type(self.fault).__name__}")
        if not isinstance(self.replication, ReplicationConfig):
            raise ValueError(
                f"EngineConfig.replication must be a ReplicationConfig, "
                f"got {type(self.replication).__name__}")
        if self.d_start > self.d_emb:
            raise ValueError(
                f"EngineConfig.d_start={self.d_start} exceeds "
                f"d_emb={self.d_emb}")
        _validate_choice(self, "rebuild_mode", ("sync", "background", "off"))
        _validate_choice(self, "metric", ("l2", "cosine"))
        if not isinstance(self.backend, BackendConfig):
            raise ValueError(
                f"EngineConfig.backend must be a BackendConfig "
                f"(FlatConfig/IVFConfig/QuantizedConfig), got "
                f"{type(self.backend).__name__}; legacy name+opts callers "
                f"go through backend_config()")
        object.__setattr__(
            self, "buckets", tuple(int(b) for b in self.buckets))
        if not self.buckets or any(b < 1 for b in self.buckets) or (
                list(self.buckets) != sorted(set(self.buckets))):
            raise ValueError(
                f"EngineConfig.buckets must be ascending unique positive "
                f"sizes, got {self.buckets}")
        if self.compact_dead_frac is not None and not (
                0 < self.compact_dead_frac <= 1):
            raise ValueError(
                f"EngineConfig.compact_dead_frac must lie in (0, 1] or be "
                f"None, got {self.compact_dead_frac}")

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict:
        """JSON-able dict (the HTTP ``stats`` endpoint reports this)."""
        out = dataclasses.asdict(self)
        out["buckets"] = list(self.buckets)
        out["backend"] = self.backend.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Dict) -> "EngineConfig":
        d = dict(d)
        be = dict(d.pop("backend", {"backend": "flat"}))
        name = be.pop("backend")
        d["backend"] = backend_config(name, be)
        if "obs" in d:
            d["obs"] = ObsConfig.from_dict(d["obs"])
        if "adaptive" in d:
            d["adaptive"] = AdaptiveConfig.from_dict(d["adaptive"])
        if "cache" in d:
            d["cache"] = CacheConfig.from_dict(d["cache"])
        if "fault" in d:
            d["fault"] = FaultToleranceConfig.from_dict(d["fault"])
        if "replication" in d:
            d["replication"] = ReplicationConfig.from_dict(d["replication"])
        if "buckets" in d:
            d["buckets"] = tuple(d["buckets"])
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(d) - known)
        if bad:
            raise ValueError(f"EngineConfig does not take field(s) {bad}")
        return cls(**d)

    # -- CLI surface ---------------------------------------------------------
    @staticmethod
    def add_flags(ap) -> None:
        """Register the shared engine flags on an argparse parser (the one
        surface ``launch.serve`` and the HTTP benchmarks draw from)."""
        ap.add_argument("--d-start", type=int, default=32)
        ap.add_argument("--k0", type=int, default=32)
        ap.add_argument("--final-k", type=int, default=1)
        ap.add_argument("--buckets", type=str, default="1,2,4,8,16,32",
                        help="comma-separated static retrieval batch sizes")
        ap.add_argument("--backend", type=str, default="flat",
                        choices=tuple(sorted(_BACKEND_CONFIGS)),
                        help="index backend behind the retrieval engine")
        ap.add_argument("--use-kernel", type=str, default="auto",
                        choices=("auto", "true", "false"),
                        help="ivf/quantized-pq: fused Pallas stage-0 kernel "
                             "(auto = TPU only; true forces interpret mode "
                             "on CPU)")
        ap.add_argument("--stage0-dtype", type=str, default="float32",
                        choices=("float32", "int8", "pq"),
                        help="ivf only: member-slab dtype for the fused "
                             "kernel (pq = ADC LUT scan over PQ codes)")
        ap.add_argument("--codec", type=str, default="int8",
                        choices=("int8", "pq"),
                        help="quantized only: stage-0 code block codec")
        ap.add_argument("--pq-m", type=int, default=0,
                        help="PQ subspaces per row (0 = auto, aim 8-dim "
                             "subspaces); must divide the stage-0 dim")
        ap.add_argument("--rebuild-mode", type=str, default="sync",
                        choices=("sync", "background", "off"))
        ap.add_argument("--no-obs", action="store_true",
                        help="disable metrics/traces (uninstrumented fast "
                             "path; the overhead-benchmark baseline)")
        ap.add_argument("--slow-query-ms", type=float, default=0.0,
                        help="log a structured JSON record for requests "
                             "slower than this (0 = disabled)")
        ap.add_argument("--trace-ring", type=int, default=256,
                        help="recent-request trace ring capacity")
        ap.add_argument("--stage-fences", action="store_true",
                        help="fence stage-0 vs rescore on the batched path "
                             "so traces carry the split (extra host sync)")
        ap.add_argument("--adaptive", action="store_true",
                        help="enable the load-adaptive search policy "
                             "(degrade recall instead of availability "
                             "under queue pressure)")
        ap.add_argument("--adaptive-levels", type=int, default=2,
                        help="number of degradation levels")
        ap.add_argument("--adaptive-depth-high", type=int, default=32,
                        help="driver queue depth entering level 1")
        ap.add_argument("--adaptive-wait-high-ms", type=float, default=50.0,
                        help="queue-wait p95 (ms) entering level 1 "
                             "(0 = depth-only)")
        ap.add_argument("--adaptive-hysteresis-s", type=float, default=2.0,
                        help="continuous calm time before stepping one "
                             "level back down")
        ap.add_argument("--qcache", action="store_true",
                        help="enable the mutation-aware query-result cache "
                             "in front of the driver queue")
        ap.add_argument("--qcache-capacity", type=int, default=1024,
                        help="cached query results (LRU beyond this)")
        ap.add_argument("--qcache-near-eps", type=float, default=0.0,
                        help="serve near-duplicate queries within this "
                             "squared-L2 distance (0 = exact-only)")
        ap.add_argument("--ft-heartbeat-timeout-s", type=float, default=5.0,
                        help="driver heartbeat age declaring the thread "
                             "hung (supervisor restart trigger)")
        ap.add_argument("--ft-max-restarts", type=int, default=5,
                        help="consecutive driver restarts before the "
                             "supervisor gives up")
        ap.add_argument("--ft-backoff-initial-s", type=float, default=0.05,
                        help="initial restart backoff (doubles per "
                             "consecutive restart)")
        ap.add_argument("--ft-backoff-max-s", type=float, default=2.0,
                        help="restart backoff cap")
        ap.add_argument("--ft-rebuild-retries", type=int, default=3,
                        help="consecutive background-rebuild failures "
                             "retried before escalating")
        ap.add_argument("--ft-snapshot-keep", type=int, default=3,
                        help="snapshots retained (older WAL segments "
                             "pruned past the oldest)")
        ap.add_argument("--no-poison-bisect", action="store_true",
                        help="fail a whole batch on dispatch error instead "
                             "of bisecting to isolate the poison request")
        ap.add_argument("--wal-no-fsync", action="store_true",
                        help="skip the per-append WAL fsync (benchmarks "
                             "only: acked mutations may be lost on crash)")
        ap.add_argument("--inject", type=str, default="",
                        help="deterministic fault-injection spec, e.g. "
                             "'dispatch:crash@once=3;rebuild:error@first=2' "
                             "(chaos testing; empty = inert)")
        ap.add_argument("--inject-seed", type=int, default=0,
                        help="seed for probabilistic (p=) fault rules")
        ap.add_argument("--role", type=str, default="single",
                        choices=("single", "primary", "follower", "router"),
                        help="replication role: primary owns the WAL, "
                             "followers tail it read-only from the shared "
                             "--state-dir, router fronts --replicas")
        ap.add_argument("--replica-poll-s", type=float, default=0.05,
                        help="follower WAL-tail poll interval")
        ap.add_argument("--ready-lag-max", type=int, default=0,
                        help="follower readiness: max records behind the "
                             "primary's tail for /healthz?ready=1")
        ap.add_argument("--min-seq-wait-s", type=float, default=1.0,
                        help="max wait for a min_seq consistency token "
                             "before a retryable 503")

    @classmethod
    def from_flags(cls, args, *, d_emb: int,
                   capacity: Optional[int] = None) -> "EngineConfig":
        """Build an EngineConfig from ``add_flags`` argparse output."""
        use_kernel = {"auto": "auto", "true": True,
                      "false": False}[args.use_kernel]
        pq_m = args.pq_m or None
        if args.backend == "ivf":
            be = IVFConfig(use_kernel=use_kernel,
                           stage0_dtype=args.stage0_dtype,
                           pq_m=pq_m if args.stage0_dtype == "pq" else None)
        elif args.backend == "quantized":
            be = QuantizedConfig(codec=args.codec, use_kernel=use_kernel,
                                 pq_m=pq_m if args.codec == "pq" else None)
        else:
            be = FlatConfig()
        d_start = min(args.d_start, d_emb)
        return cls(
            d_emb=d_emb,
            d_start=d_start,
            k0=args.k0,
            final_k=args.final_k,
            buckets=tuple(int(x) for x in args.buckets.split(",")),
            capacity=capacity if capacity is not None else 1024,
            backend=be,
            rebuild_mode=args.rebuild_mode,
            obs=ObsConfig(
                enabled=not args.no_obs,
                slow_query_ms=args.slow_query_ms or None,
                trace_ring=args.trace_ring,
                stage_fences=args.stage_fences,
            ),
            adaptive=AdaptiveConfig(
                enabled=args.adaptive,
                levels=args.adaptive_levels,
                depth_high=args.adaptive_depth_high,
                wait_high_ms=args.adaptive_wait_high_ms or None,
                hysteresis_s=args.adaptive_hysteresis_s,
            ),
            cache=CacheConfig(
                enabled=args.qcache,
                capacity=args.qcache_capacity,
                near_eps=args.qcache_near_eps,
            ),
            fault=FaultToleranceConfig(
                wal_fsync=not args.wal_no_fsync,
                snapshot_keep=args.ft_snapshot_keep,
                heartbeat_timeout_s=args.ft_heartbeat_timeout_s,
                max_restarts=args.ft_max_restarts,
                backoff_initial_s=args.ft_backoff_initial_s,
                backoff_max_s=args.ft_backoff_max_s,
                rebuild_retries=args.ft_rebuild_retries,
                poison_bisect=not args.no_poison_bisect,
                inject=args.inject,
                inject_seed=args.inject_seed,
            ),
            replication=ReplicationConfig(
                # the router role builds no engine of its own
                role=(args.role if args.role in ("primary", "follower")
                      else "single"),
                poll_s=args.replica_poll_s,
                ready_lag_max=args.ready_lag_max,
                min_seq_wait_s=args.min_seq_wait_s,
            ),
        )


def legacy_config(
    d_emb: int,
    *,
    d_start: int = 32,
    k0: int = 32,
    final_k: int = 1,
    buckets=(1, 2, 4, 8, 16, 32),
    capacity: int = 1024,
    metric: str = "l2",
    block_n: int = 65536,
    max_unpolled: int = 65536,
    backend="flat",
    backend_opts: Optional[Dict] = None,
    rebuild_mode: str = "sync",
    compact_dead_frac: Optional[float] = 0.3,
    obs: Optional[ObsConfig] = None,
    adaptive: Optional[AdaptiveConfig] = None,
    cache: Optional[CacheConfig] = None,
    fault: Optional[FaultToleranceConfig] = None,
    replication: Optional[ReplicationConfig] = None,
) -> "EngineConfig":
    """The deprecation shim: old-style engine kwargs -> ``EngineConfig``.

    ``RetrievalEngine``'s legacy keyword path routes through here, so the
    stringly-typed surface keeps working while gaining the typed configs'
    eager validation.  A pre-constructed ``IndexBackend`` instance (also
    legacy) is handled by the engine itself and never reaches this shim.
    """
    return EngineConfig(
        d_emb=d_emb, d_start=min(d_start, d_emb), k0=k0, final_k=final_k,
        buckets=tuple(buckets), capacity=capacity, metric=metric,
        block_n=block_n, max_unpolled=max_unpolled,
        backend=(backend if isinstance(backend, BackendConfig)
                 else backend_config(backend, backend_opts)),
        rebuild_mode=rebuild_mode, compact_dead_frac=compact_dead_frac,
        obs=obs if obs is not None else ObsConfig(),
        adaptive=adaptive if adaptive is not None else AdaptiveConfig(),
        cache=cache if cache is not None else CacheConfig(),
        fault=fault if fault is not None else FaultToleranceConfig(),
        replication=(replication if replication is not None
                     else ReplicationConfig()),
    )

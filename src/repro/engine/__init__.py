"""Retrieval serving engine: request queueing, shape-bucketed batching, and a
mutable (add/delete) corpus on top of pluggable index backends.

Public API:
  RetrievalEngine                — submit/poll/step serving loop + batch search
                                   (``backend='flat'|'ivf'|'quantized'``,
                                   rebuild/compaction lifecycle)
  RetrievalResult, RequestStats  — per-request outputs and timing breakdown
  EngineStats                    — aggregate counters / latency percentiles
  DocStore                       — capacity-doubling device buffers + validity
                                   mask + tombstone compaction
  BucketPolicy                   — static batch-size ladder

The backend protocol and implementations live in `repro.index_backends`.
"""

from repro.engine.batching import BucketPolicy, PendingRequest, RequestQueue, pad_batch
from repro.engine.engine import (
    EngineStats,
    RequestStats,
    RetrievalEngine,
    RetrievalResult,
)
from repro.engine.store import DocStore
from repro.index_backends import StoreStats

__all__ = [
    "BucketPolicy", "PendingRequest", "RequestQueue", "pad_batch",
    "DocStore", "EngineStats", "RequestStats", "RetrievalEngine",
    "RetrievalResult", "StoreStats",
]

"""Retrieval serving engine: request queueing, shape-bucketed batching, an
async deadline-batching driver, and a mutable (add/delete) corpus on top of
pluggable index backends.

Public API:
  RetrievalEngine                — submit/poll/step serving loop + batch search
                                   (typed ``EngineConfig`` or legacy kwargs,
                                   rebuild/compaction lifecycle); thread-safe
                                   behind ``engine.lock``
  SearchRequest                  — typed per-request options (k, tenant,
                                   metadata filter, deadline); accepted by
                                   every submit/retrieve/search entry point
                                   alongside raw query vectors
  EngineConfig + FlatConfig/IVFConfig/QuantizedConfig
                                 — eager-validating, serializable engine and
                                   per-backend configuration
  EngineDriver                   — background thread owning batch formation:
                                   deadline-based flushes, futures,
                                   backpressure, drain/abort shutdown
  RetrievalFuture                — write-once result handle from ``submit``
  DriverStopped, DriverQueueFull,
  DeadlineExceeded               — driver client-facing exceptions
  UnknownRequest, ResultEvicted  — ``poll`` signals: never-issued id vs a
                                   result that is gone for good
  FilterError                    — malformed metadata-filter spec (HTTP 400)
  RetrievalResult, RequestStats  — per-request outputs and timing breakdown
  EngineStats, DriverStats       — aggregate counters / latency percentiles
  DocStore                       — capacity-doubling device buffers + validity
                                   mask, tombstone compaction, tenant
                                   namespaces + metadata filter masks
  BucketPolicy                   — static batch-size ladder
  DeadlineBatcher, BatchDecision — pure deadline-flush policy (fake-clock
                                   testable) the driver thread consults
  AdaptivePolicy, SearchOverrides
                                 — load-adaptive degradation: queue pressure
                                   -> per-dispatch search-knob overrides,
                                   restored hysteretically when idle
  QueryCache                     — exact + near-duplicate query-result cache
                                   in front of the driver queue, invalidated
                                   structurally by store/mask/rebuild bumps
  MutationWAL, WALError          — fsync'd mutation write-ahead log behind
                                   ``enable_durability``/``recover``
  WALCursor, WALGap              — seq-keyed tailing reader over a WAL
                                   directory (replication shipping:
                                   rotate/prune-safe, gap detection)
  ReplicaApplier,
  PrimaryReplication             — WAL-shipped replication: follower
                                   snapshot bootstrap + tail catch-up,
                                   replica_lag, min_seq waits
  ReplicationConfig              — role/poll/lag-bound knobs on
                                   ``EngineConfig.replication``
  FaultToleranceConfig           — WAL/supervision/injection knobs on
                                   ``EngineConfig.fault``
  FaultPlan, InjectedFault,
  InjectedCrash, PoisonError     — deterministic fault-injection harness
  Supervisor, SupervisorGaveUp   — driver watchdog: heartbeat detection,
                                   capped-backoff restarts
  RequestFailed                  — request isolated by poison-batch bisection
                                   (HTTP 503, fails alone)
  IndexMismatch                  — loaded index incompatible with live config
  CorruptCheckpoint              — checksum/parse failure in a saved step
                                   (``recover`` falls back a step)

The backend protocol and implementations live in `repro.index_backends`;
the HTTP serving front-end on top of all this lives in `repro.serve`.
"""

from repro.engine.adaptive import AdaptivePolicy, SearchOverrides
from repro.engine.batching import (
    BatchDecision,
    BucketPolicy,
    DeadlineBatcher,
    PendingRequest,
    RequestQueue,
    pad_batch,
)
from repro.engine.config import (
    AdaptiveConfig,
    BackendConfig,
    CacheConfig,
    EngineConfig,
    FaultToleranceConfig,
    FlatConfig,
    IVFConfig,
    QuantizedConfig,
    ReplicationConfig,
    backend_config,
)
from repro.engine.driver import (
    DeadlineExceeded,
    DriverQueueFull,
    DriverStats,
    DriverStopped,
    EngineDriver,
    RequestFailed,
    RetrievalFuture,
)
from repro.engine.engine import (
    EngineStats,
    IndexMismatch,
    RequestStats,
    ResultEvicted,
    RetrievalEngine,
    RetrievalResult,
    UnknownRequest,
)
from repro.engine.faults import (
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    PoisonError,
)
from repro.engine.replication import PrimaryReplication, ReplicaApplier
from repro.engine.supervise import Supervisor, SupervisorGaveUp
from repro.engine.wal import MutationWAL, WALCursor, WALError, WALGap
from repro.checkpoint import CorruptCheckpoint
from repro.engine.qcache import QueryCache
from repro.engine.request import FilterError, SearchRequest, canonical_filter
from repro.engine.store import DocStore
from repro.index_backends import StoreStats

__all__ = [
    "AdaptivePolicy", "SearchOverrides", "QueryCache",
    "BatchDecision", "BucketPolicy", "DeadlineBatcher", "PendingRequest",
    "RequestQueue", "pad_batch",
    "AdaptiveConfig", "BackendConfig", "CacheConfig", "EngineConfig",
    "FaultToleranceConfig", "FlatConfig", "IVFConfig", "QuantizedConfig",
    "backend_config",
    "DeadlineExceeded", "DriverQueueFull", "DriverStats", "DriverStopped",
    "EngineDriver", "RequestFailed", "RetrievalFuture",
    "DocStore", "EngineStats", "FilterError", "IndexMismatch",
    "RequestStats",
    "ResultEvicted", "RetrievalEngine", "RetrievalResult", "SearchRequest",
    "StoreStats", "UnknownRequest", "canonical_filter",
    "CorruptCheckpoint", "FaultPlan", "InjectedCrash", "InjectedFault",
    "MutationWAL", "PoisonError", "PrimaryReplication", "ReplicaApplier",
    "ReplicationConfig", "Supervisor", "SupervisorGaveUp",
    "WALCursor", "WALError", "WALGap",
]

"""Retrieval serving engine: request queueing, shape-bucketed batching, and a
mutable (add/delete) corpus on top of progressive search.

Public API:
  RetrievalEngine                — submit/poll/step serving loop + batch search
  RetrievalResult, RequestStats  — per-request outputs and timing breakdown
  EngineStats                    — aggregate counters / latency percentiles
  DocStore                       — capacity-doubling device buffers + validity
  BucketPolicy                   — static batch-size ladder
"""

from repro.engine.batching import BucketPolicy, PendingRequest, RequestQueue, pad_batch
from repro.engine.engine import (
    EngineStats,
    RequestStats,
    RetrievalEngine,
    RetrievalResult,
)
from repro.engine.store import DocStore

__all__ = [
    "BucketPolicy", "PendingRequest", "RequestQueue", "pad_batch",
    "DocStore", "EngineStats", "RequestStats", "RetrievalEngine",
    "RetrievalResult",
]

"""Retrieval serving engine: request queueing, shape-bucketed batching, an
async deadline-batching driver, and a mutable (add/delete) corpus on top of
pluggable index backends.

Public API:
  RetrievalEngine                — submit/poll/step serving loop + batch search
                                   (``backend='flat'|'ivf'|'quantized'``,
                                   rebuild/compaction lifecycle); thread-safe
                                   behind ``engine.lock``
  EngineDriver                   — background thread owning batch formation:
                                   deadline-based flushes, futures,
                                   backpressure, drain/abort shutdown
  RetrievalFuture                — write-once result handle from ``submit``
  DriverStopped, DriverQueueFull — driver client-facing exceptions
  RetrievalResult, RequestStats  — per-request outputs and timing breakdown
  EngineStats, DriverStats       — aggregate counters / latency percentiles
  DocStore                       — capacity-doubling device buffers + validity
                                   mask + tombstone compaction
  BucketPolicy                   — static batch-size ladder
  DeadlineBatcher, BatchDecision — pure deadline-flush policy (fake-clock
                                   testable) the driver thread consults

The backend protocol and implementations live in `repro.index_backends`.
"""

from repro.engine.batching import (
    BatchDecision,
    BucketPolicy,
    DeadlineBatcher,
    PendingRequest,
    RequestQueue,
    pad_batch,
)
from repro.engine.driver import (
    DriverQueueFull,
    DriverStats,
    DriverStopped,
    EngineDriver,
    RetrievalFuture,
)
from repro.engine.engine import (
    EngineStats,
    RequestStats,
    RetrievalEngine,
    RetrievalResult,
)
from repro.engine.store import DocStore
from repro.index_backends import StoreStats

__all__ = [
    "BatchDecision", "BucketPolicy", "DeadlineBatcher", "PendingRequest",
    "RequestQueue", "pad_batch",
    "DriverQueueFull", "DriverStats", "DriverStopped", "EngineDriver",
    "RetrievalFuture",
    "DocStore", "EngineStats", "RequestStats", "RetrievalEngine",
    "RetrievalResult", "StoreStats",
]

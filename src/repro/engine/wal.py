"""Mutation write-ahead log: the durability half of crash recovery.

Every corpus mutation (``add_docs`` / ``delete_docs`` / compaction) appends
one framed record here *before* the engine acknowledges it, so an
acknowledged mutation survives a process crash: recovery restores the newest
valid snapshot and replays the WAL tail on top (see
``RetrievalEngine.recover``).

Format — append-only segment files ``wal-<firstseq>.log``:

    [8B magic "RWAL0001"]                      (once per segment)
    [u32 payload len][u32 crc32(payload)][msgpack payload] ...

Each payload carries a monotonic ``seq`` plus the mutation (add payloads
store the raw vector bytes + dtype/shape so replay is bit-exact).  A crash
mid-write leaves a *torn tail*: the length/CRC framing detects it, replay
stops at the last intact record, and ``open`` truncates the torn bytes so
new appends never land after garbage.

Lifecycle: ``rotate()`` at each snapshot starts a fresh segment (records up
to the snapshot's ``wal_seq`` live in older segments); ``prune(upto_seq)``
deletes segments entirely covered by the *oldest retained* snapshot — a
torn-newest-snapshot fallback can therefore still replay the older
snapshot's tail.  Thread safety is the engine's job: every append happens
under ``engine.lock``.
"""

from __future__ import annotations

import os
import struct
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import msgpack

_MAGIC = b"RWAL0001"
_HEADER = struct.Struct("<II")        # payload length, crc32(payload)
_MAX_RECORD = 1 << 30                 # sanity bound against garbage lengths


class WALError(RuntimeError):
    """The WAL is unusable (replay divergence, bad directory, ...) —
    distinct from a torn tail, which is an expected crash artifact and is
    truncated silently."""


class WALRecord:
    """One replayable mutation."""

    __slots__ = ("seq", "kind", "payload")

    def __init__(self, seq: int, kind: str, payload: Dict):
        self.seq = seq
        self.kind = kind
        self.payload = payload

    def __repr__(self) -> str:
        return f"WALRecord(seq={self.seq}, kind={self.kind!r})"


def _segment_name(first_seq: int) -> str:
    return f"wal-{first_seq:012d}.log"


def _scan_segment(path: str) -> Tuple[List[WALRecord], int, bool]:
    """Read one segment; returns (records, clean_byte_length, torn).

    ``clean_byte_length`` is the offset just past the last intact record —
    the truncation point for a torn tail.  ``torn`` is True when trailing
    bytes had to be discarded (partial frame, short payload, CRC mismatch).
    """
    records: List[WALRecord] = []
    with open(path, "rb") as f:
        blob = f.read()
    if blob[: len(_MAGIC)] != _MAGIC:
        # unreadable header: treat the whole segment as torn
        return records, 0, True
    off = len(_MAGIC)
    clean = off
    while off + _HEADER.size <= len(blob):
        length, crc = _HEADER.unpack_from(blob, off)
        start = off + _HEADER.size
        end = start + length
        if length > _MAX_RECORD or end > len(blob):
            return records, clean, True           # partial frame
        payload = blob[start:end]
        if zlib.crc32(payload) != crc:
            return records, clean, True           # corrupt record
        rec = msgpack.unpackb(payload)
        records.append(WALRecord(int(rec["seq"]), rec["kind"], rec))
        off = end
        clean = off
    return records, clean, off != len(blob)


class MutationWAL:
    """Framed, CRC-checked, fsync'd mutation log under ``wal_dir``."""

    def __init__(self, wal_dir: str, *, fsync: bool = True):
        self.wal_dir = wal_dir
        self.fsync = bool(fsync)
        os.makedirs(wal_dir, exist_ok=True)
        self.last_seq = -1                 # highest durable seq
        self.torn_tail = False             # open/replay found torn bytes
        self.n_appended = 0                # records appended this process
        self._since_rotate = 0             # records in the active segment
        self._fh = None
        segs = self._segments()
        if segs:
            # recover the active (newest) segment: find the clean length,
            # truncate any torn tail so appends go after intact records
            for first_seq, path in segs:
                recs, clean, torn = _scan_segment(path)
                if recs:
                    self.last_seq = max(self.last_seq, recs[-1].seq)
                elif not torn:
                    self.last_seq = max(self.last_seq, first_seq - 1)
                if path == segs[-1][1]:
                    self._since_rotate = len(recs)
                    if torn:
                        self.torn_tail = True
                        with open(path, "r+b") as f:
                            f.truncate(max(clean, len(_MAGIC)))
                            f.flush()
                            os.fsync(f.fileno())
            self._open_segment(segs[-1][1], fresh=False)
        else:
            self._start_segment(0)

    # -- segment plumbing ---------------------------------------------------
    def _segments(self) -> List[Tuple[int, str]]:
        out = []
        for name in os.listdir(self.wal_dir):
            if name.startswith("wal-") and name.endswith(".log"):
                try:
                    first = int(name[4:-4])
                except ValueError:
                    continue
                out.append((first, os.path.join(self.wal_dir, name)))
        return sorted(out)

    def _open_segment(self, path: str, *, fresh: bool) -> None:
        self._fh = open(path, "ab")
        if fresh:
            self._fh.write(_MAGIC)
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def _start_segment(self, first_seq: int) -> None:
        path = os.path.join(self.wal_dir, _segment_name(first_seq))
        self._open_segment(path, fresh=not os.path.exists(path)
                           or os.path.getsize(path) == 0)

    # -- client surface -----------------------------------------------------
    def append(self, kind: str, payload: Dict) -> int:
        """Durably append one record; returns its seq number.

        The record is on disk (fsync'd when ``fsync=True``) before this
        returns — the engine acknowledges the mutation only after that, so
        "acked" implies "replayable".
        """
        if self._fh is None:
            raise WALError("WAL is closed")
        seq = self.last_seq + 1
        body = dict(payload)
        body["seq"] = seq
        body["kind"] = kind
        blob = msgpack.packb(body)
        self._fh.write(_HEADER.pack(len(blob), zlib.crc32(blob)))
        self._fh.write(blob)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.last_seq = seq
        self.n_appended += 1
        self._since_rotate += 1
        return seq

    def replay(self, after_seq: int = -1) -> Iterator[WALRecord]:
        """Yield intact records with ``seq > after_seq`` in order.

        Stops at the first torn/corrupt record (sets ``torn_tail``) —
        everything after a tear is untrustworthy by construction.
        """
        for _first, path in self._segments():
            recs, _clean, torn = _scan_segment(path)
            for rec in recs:
                if rec.seq > after_seq:
                    yield rec
            if torn:
                self.torn_tail = True
                return

    def rotate(self) -> None:
        """Start a fresh segment (called at snapshot points): records up to
        ``last_seq`` stay in older segments, prunable once no retained
        snapshot needs them."""
        if self._fh is not None:
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        self._start_segment(self.last_seq + 1)
        self._since_rotate = 0

    def prune(self, upto_seq: int) -> int:
        """Delete segments whose every record has ``seq <= upto_seq``;
        returns how many were removed.  The active segment is never
        pruned."""
        segs = self._segments()
        removed = 0
        for i, (first, path) in enumerate(segs):
            nxt = segs[i + 1][0] if i + 1 < len(segs) else None
            if nxt is None:                       # active segment
                break
            if nxt - 1 <= upto_seq:               # fully covered
                os.remove(path)
                removed += 1
            else:
                break
        return removed

    @property
    def lag(self) -> int:
        """Records appended since the last rotate (≈ replay length if the
        process died right now)."""
        return self._since_rotate

    @property
    def n_segments(self) -> int:
        return len(self._segments())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None

    def summary(self) -> Dict:
        return {
            "last_seq": self.last_seq,
            "lag_records": self.lag,
            "n_segments": self.n_segments,
            "torn_tail": self.torn_tail,
            "fsync": self.fsync,
        }

    def describe(self) -> str:
        return (f"MutationWAL(dir={self.wal_dir!r}, last_seq={self.last_seq}, "
                f"lag={self.lag}, segments={self.n_segments})")

"""Mutation write-ahead log: the durability half of crash recovery.

Every corpus mutation (``add_docs`` / ``delete_docs`` / compaction) appends
one framed record here *before* the engine acknowledges it, so an
acknowledged mutation survives a process crash: recovery restores the newest
valid snapshot and replays the WAL tail on top (see
``RetrievalEngine.recover``).

Format — append-only segment files ``wal-<firstseq>.log``:

    [8B magic "RWAL0001"]                      (once per segment)
    [u32 payload len][u32 crc32(payload)][msgpack payload] ...

Each payload carries a monotonic ``seq`` plus the mutation (add payloads
store the raw vector bytes + dtype/shape so replay is bit-exact).  A crash
mid-write leaves a *torn tail*: the length/CRC framing detects it, replay
stops at the last intact record, and ``open`` truncates the torn bytes so
new appends never land after garbage.

Lifecycle: ``rotate()`` at each snapshot starts a fresh segment (records up
to the snapshot's ``wal_seq`` live in older segments); ``prune(upto_seq)``
deletes segments entirely covered by the *oldest retained* snapshot — a
torn-newest-snapshot fallback can therefore still replay the older
snapshot's tail.  Thread safety is the engine's job: every append happens
under ``engine.lock``.
"""

from __future__ import annotations

import os
import struct
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import msgpack

_MAGIC = b"RWAL0001"
_HEADER = struct.Struct("<II")        # payload length, crc32(payload)
_MAX_RECORD = 1 << 30                 # sanity bound against garbage lengths


class WALError(RuntimeError):
    """The WAL is unusable (replay divergence, bad directory, ...) —
    distinct from a torn tail, which is an expected crash artifact and is
    truncated silently."""


class WALGap(WALError):
    """A tailing reader's position was pruned away: the records between the
    cursor and the oldest surviving segment are gone, so the reader must
    re-bootstrap from a snapshot instead of replaying."""


class WALRecord:
    """One replayable mutation."""

    __slots__ = ("seq", "kind", "payload")

    def __init__(self, seq: int, kind: str, payload: Dict):
        self.seq = seq
        self.kind = kind
        self.payload = payload

    def __repr__(self) -> str:
        return f"WALRecord(seq={self.seq}, kind={self.kind!r})"


def _segment_name(first_seq: int) -> str:
    return f"wal-{first_seq:012d}.log"


def _list_segments(wal_dir: str) -> List[Tuple[int, str]]:
    """All segment files in ``wal_dir`` as (first_seq, path), seq-sorted."""
    out = []
    try:
        names = os.listdir(wal_dir)
    except FileNotFoundError:
        return []
    for name in names:
        if name.startswith("wal-") and name.endswith(".log"):
            try:
                first = int(name[4:-4])
            except ValueError:
                continue
            out.append((first, os.path.join(wal_dir, name)))
    return sorted(out)


def _scan_tail(path: str, offset: int) -> Tuple[List[WALRecord], int, bool]:
    """Parse frames starting at byte ``offset``; returns
    (records, clean_byte_length, torn).

    ``clean_byte_length`` is the *absolute* offset just past the last intact
    record — the truncation point for a torn tail, and the resume point for
    a tailing cursor.  ``torn`` is True when trailing bytes had to be
    discarded (partial frame, short payload, CRC mismatch).  ``offset == 0``
    verifies the segment magic first.
    """
    records: List[WALRecord] = []
    with open(path, "rb") as f:
        if offset == 0:
            if f.read(len(_MAGIC)) != _MAGIC:
                # unreadable header: treat the whole segment as torn
                return records, 0, True
            offset = len(_MAGIC)
        else:
            f.seek(offset)
        blob = f.read()
    off = 0
    clean = 0
    while off + _HEADER.size <= len(blob):
        length, crc = _HEADER.unpack_from(blob, off)
        start = off + _HEADER.size
        end = start + length
        if length > _MAX_RECORD or end > len(blob):
            return records, offset + clean, True  # partial frame
        payload = blob[start:end]
        if zlib.crc32(payload) != crc:
            return records, offset + clean, True  # corrupt record
        rec = msgpack.unpackb(payload)
        records.append(WALRecord(int(rec["seq"]), rec["kind"], rec))
        off = end
        clean = off
    return records, offset + clean, off != len(blob)


def _scan_segment(path: str) -> Tuple[List[WALRecord], int, bool]:
    """Read one whole segment; returns (records, clean_byte_length, torn)."""
    return _scan_tail(path, 0)


class MutationWAL:
    """Framed, CRC-checked, fsync'd mutation log under ``wal_dir``."""

    def __init__(self, wal_dir: str, *, fsync: bool = True):
        self.wal_dir = wal_dir
        self.fsync = bool(fsync)
        os.makedirs(wal_dir, exist_ok=True)
        self.last_seq = -1                 # highest durable seq
        self.torn_tail = False             # open/replay found torn bytes
        self.n_appended = 0                # records appended this process
        self._since_rotate = 0             # records in the active segment
        self._fh = None
        segs = self._segments()
        if segs:
            # recover the active (newest) segment: find the clean length,
            # truncate any torn tail so appends go after intact records
            for first_seq, path in segs:
                recs, clean, torn = _scan_segment(path)
                if recs:
                    self.last_seq = max(self.last_seq, recs[-1].seq)
                elif not torn:
                    self.last_seq = max(self.last_seq, first_seq - 1)
                if path == segs[-1][1]:
                    self._since_rotate = len(recs)
                    if torn:
                        self.torn_tail = True
                        with open(path, "r+b") as f:
                            f.truncate(max(clean, len(_MAGIC)))
                            f.flush()
                            os.fsync(f.fileno())
            self._open_segment(segs[-1][1], fresh=False)
        else:
            self._start_segment(0)

    # -- segment plumbing ---------------------------------------------------
    def _segments(self) -> List[Tuple[int, str]]:
        return _list_segments(self.wal_dir)

    def _open_segment(self, path: str, *, fresh: bool) -> None:
        self._fh = open(path, "ab")
        if fresh:
            self._fh.write(_MAGIC)
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def _start_segment(self, first_seq: int) -> None:
        path = os.path.join(self.wal_dir, _segment_name(first_seq))
        self._open_segment(path, fresh=not os.path.exists(path)
                           or os.path.getsize(path) == 0)

    # -- client surface -----------------------------------------------------
    def append(self, kind: str, payload: Dict) -> int:
        """Durably append one record; returns its seq number.

        The record is on disk (fsync'd when ``fsync=True``) before this
        returns — the engine acknowledges the mutation only after that, so
        "acked" implies "replayable".
        """
        if self._fh is None:
            raise WALError("WAL is closed")
        seq = self.last_seq + 1
        body = dict(payload)
        body["seq"] = seq
        body["kind"] = kind
        blob = msgpack.packb(body)
        self._fh.write(_HEADER.pack(len(blob), zlib.crc32(blob)))
        self._fh.write(blob)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.last_seq = seq
        self.n_appended += 1
        self._since_rotate += 1
        return seq

    def replay(self, after_seq: int = -1) -> Iterator[WALRecord]:
        """Yield intact records with ``seq > after_seq`` in order.

        Stops at the first torn/corrupt record (sets ``torn_tail``) —
        everything after a tear is untrustworthy by construction.
        """
        for _first, path in self._segments():
            recs, _clean, torn = _scan_segment(path)
            for rec in recs:
                if rec.seq > after_seq:
                    yield rec
            if torn:
                self.torn_tail = True
                return

    def rotate(self) -> None:
        """Start a fresh segment (called at snapshot points): records up to
        ``last_seq`` stay in older segments, prunable once no retained
        snapshot needs them."""
        if self._fh is not None:
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        self._start_segment(self.last_seq + 1)
        self._since_rotate = 0

    def prune(self, upto_seq: int) -> int:
        """Delete segments whose every record has ``seq <= upto_seq``;
        returns how many were removed.  The active segment is never
        pruned."""
        segs = self._segments()
        removed = 0
        for i, (first, path) in enumerate(segs):
            nxt = segs[i + 1][0] if i + 1 < len(segs) else None
            if nxt is None:                       # active segment
                break
            if nxt - 1 <= upto_seq:               # fully covered
                os.remove(path)
                removed += 1
            else:
                break
        return removed

    @property
    def lag(self) -> int:
        """Records appended since the last rotate (≈ replay length if the
        process died right now)."""
        return self._since_rotate

    @property
    def n_segments(self) -> int:
        return len(self._segments())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None

    def summary(self) -> Dict:
        return {
            "last_seq": self.last_seq,
            "lag_records": self.lag,
            "n_segments": self.n_segments,
            "torn_tail": self.torn_tail,
            "fsync": self.fsync,
        }

    def describe(self) -> str:
        return (f"MutationWAL(dir={self.wal_dir!r}, last_seq={self.last_seq}, "
                f"lag={self.lag}, segments={self.n_segments})")


class WALCursor:
    """Read-only tailing cursor over a live WAL directory.

    Built for replication: a follower polls the primary's ``wal/`` directory
    and applies records as they become durable.  The cursor is keyed by
    *sequence number*, not file position, so ``rotate()`` / ``prune()``
    racing a poll can never lose or double-apply a record:

    * records come back strictly in seq order, each exactly once — a
      re-read after rotation is filtered out by ``next_seq``;
    * a segment pruned *behind* the cursor held only consumed records —
      invisible;
    * a prune that removed records the cursor has not read yet (the reader
      fell further behind than the writer's snapshot retention) raises
      ``WALGap`` — the caller must re-bootstrap from a snapshot rather than
      silently skip the missing mutations.

    A torn tail on the newest segment is the writer mid-append (or a crash
    artifact the writer truncates on restart): ``poll`` stops before it and
    picks up from the same byte next time.  A tear in an *older* segment can
    never heal and raises ``WALError``.
    """

    def __init__(self, wal_dir: str, *, after_seq: int = -1):
        self.wal_dir = wal_dir
        self.next_seq = int(after_seq) + 1
        self._offsets: Dict[str, int] = {}     # path -> bytes fully parsed

    @property
    def applied_seq(self) -> int:
        """Highest seq this cursor has handed out (-1 before the first)."""
        return self.next_seq - 1

    def seek(self, after_seq: int) -> None:
        """Reposition so the next ``poll`` starts after ``after_seq``."""
        self.next_seq = int(after_seq) + 1
        self._offsets.clear()

    def poll(self, max_records: Optional[int] = None) -> List[WALRecord]:
        """Return new intact records with ``seq >= next_seq``, in order.

        Returns ``[]`` when the reader is caught up (or the writer is
        mid-append).  Raises ``WALGap`` when pruning outran the cursor.
        """
        for _attempt in range(3):
            try:
                return self._poll_once(max_records)
            except FileNotFoundError:
                # a segment vanished between listing and scan (prune racing
                # the poll): re-list — the seq filter keeps this idempotent
                self._offsets.clear()
                continue
        raise WALError(f"WAL segments under {self.wal_dir!r} keep vanishing "
                       "mid-scan")

    def _poll_once(self, max_records: Optional[int]) -> List[WALRecord]:
        segs = _list_segments(self.wal_dir)
        if not segs:
            return []
        if self.next_seq < segs[0][0]:
            raise WALGap(
                f"cursor at seq {self.next_seq} but oldest surviving segment "
                f"starts at {segs[0][0]}: records were pruned before they "
                "were read — re-bootstrap from a snapshot")
        live = {path for _first, path in segs}
        for stale in [p for p in self._offsets if p not in live]:
            del self._offsets[stale]
        out: List[WALRecord] = []
        for i, (first, path) in enumerate(segs):
            newest = i + 1 == len(segs)
            nxt = None if newest else segs[i + 1][0]
            if nxt is not None and nxt <= self.next_seq:
                continue                           # fully consumed segment
            recs, clean, torn = _scan_tail(path, self._offsets.get(path, 0))
            for rec in recs:
                if rec.seq < self.next_seq:
                    continue
                if rec.seq != self.next_seq:
                    raise WALError(
                        f"WAL sequence gap inside {path!r}: expected "
                        f"{self.next_seq}, found {rec.seq}")
                out.append(rec)
                self.next_seq = rec.seq + 1
                if max_records is not None and len(out) >= max_records:
                    return out
            self._offsets[path] = clean
            if torn:
                if newest:
                    return out                     # writer mid-append: retry
                raise WALError(
                    f"torn record inside non-active segment {path!r}")
        return out

    def last_available_seq(self) -> int:
        """Highest intact seq currently durable in the directory (-1 when
        empty) — the target the cursor is chasing."""
        segs = _list_segments(self.wal_dir)
        if not segs:
            return -1
        first, path = segs[-1]
        try:
            recs, _clean, _torn = _scan_segment(path)
        except FileNotFoundError:
            return self.applied_seq
        if recs:
            return recs[-1].seq
        return first - 1

    def lag(self) -> int:
        """How many durable records the cursor has not yet handed out."""
        return max(0, self.last_available_seq() - self.applied_seq)

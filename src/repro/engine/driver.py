"""Async serving driver: a background thread owning batch formation/dispatch.

``RetrievalEngine`` is deliberately caller-paced — ``step()`` runs one batch
when somebody calls it.  That shape is right for benchmarks and tests, but a
serving system has many client threads and nobody whose job is to call
``step()``.  The driver closes the loop:

    client threads ──submit()/retrieve()──> bounded pending deque
        ──driver thread── DeadlineBatcher.decide() ──flush──>
            engine.execute_batch() under engine.lock ──> RetrievalFuture

* **Deadline-based batching** — the latency/throughput knob.  A request
  waits at most ``max_wait_ms`` (measured from the *oldest* request in the
  partial batch) before flushing; a full top-size bucket flushes
  immediately.  ``max_wait_ms=0`` minimizes latency (singleton batches under
  light load); larger values trade p50 latency for bigger buckets and higher
  device throughput.  The policy itself is ``repro.engine.batching.
  DeadlineBatcher`` — pure and fake-clock-testable; this thread just feeds
  it real time.
* **Thread-safe submission** — ``submit()`` may be called from any thread
  and returns a ``RetrievalFuture``; ``retrieve()`` is the blocking
  convenience wrapper.  **Backpressure**: the pending queue is bounded
  (``max_queue``); ``submit`` blocks until space frees (or raises
  ``DriverQueueFull`` past ``timeout``), so an overloaded engine pushes back
  on producers instead of buffering unboundedly.
* **Lifecycle** — ``start()`` spawns the thread; ``stop(drain=True)``
  serves every accepted request before exiting, ``stop(drain=False)``
  cancels pending requests (their futures raise ``DriverStopped``).  The
  context-manager form drains on clean exit and aborts if the body raised.
* **Exception propagation** — a dispatch error fails that batch's futures
  (clients see the exception from ``result()``) and the driver keeps
  serving; an unexpected driver-loop error is recorded, fails everything
  pending, and re-raises from the next ``submit``/``stop``.
* **Safe-point composition** — every dispatch runs through
  ``engine.execute_batch``, whose pre-dispatch ``maybe_rebuild()`` adopts
  finished background index builds and runs compaction *between* driver
  iterations (PR 2's safe-point contract), never mid-batch.  Corpus
  mutations from client threads serialize against dispatches on
  ``engine.lock``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.engine.adaptive import AdaptivePolicy
from repro.engine.batching import DeadlineBatcher, PendingRequest
from repro.engine.engine import RequestStats, RetrievalEngine, RetrievalResult
from repro.engine.qcache import QueryCache


class DriverStopped(RuntimeError):
    """The driver is stopping/stopped/dead — the request was not served."""


class RequestFailed(RuntimeError):
    """This specific request failed while its co-batched neighbours
    succeeded: batch bisection isolated it as the poison request (its
    dispatch raised on every subset containing it).  The HTTP layer maps
    this to 503 for the offender alone."""


class DriverQueueFull(TimeoutError):
    """``submit`` timed out waiting for space in the bounded pending queue."""


class DeadlineExceeded(TimeoutError):
    """The request's ``SearchRequest.deadline_ms`` budget expired before its
    batch dispatched — the driver dropped it instead of burning device time
    on an answer nobody is waiting for (the HTTP layer maps this to 504)."""


class RetrievalFuture:
    """Write-once result slot for one submitted request.

    ``result(timeout)`` blocks until the driver resolves the future — with a
    ``RetrievalResult``, the dispatch exception, or ``DriverStopped`` on
    abort — and raises ``TimeoutError`` if nothing lands in time.
    """

    __slots__ = ("_event", "_result", "_error")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._result: Optional[RetrievalResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RetrievalResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"no retrieval result within {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """The error the future resolved with (None on success)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"no retrieval result within {timeout}s")
        return self._error

    def _finish(self, result: Optional[RetrievalResult] = None,
                error: Optional[BaseException] = None) -> None:
        self._result, self._error = result, error
        self._event.set()


# driver counter attribute -> registry metric; the three flush counters
# share one labeled family (repro_driver_flush_total{reason=...}) and
# queue_peak mirrors to a gauge — attribute surface unchanged either way
_DRIVER_COUNTERS = {
    "n_submitted": ("repro_driver_requests_submitted_total",
                    "Requests accepted into the driver queue"),
    "n_completed": ("repro_driver_requests_completed_total",
                    "Requests resolved with a result"),
    "n_cancelled": ("repro_driver_requests_cancelled_total",
                    "Requests cancelled at stop(drain=False)"),
    "n_expired": ("repro_driver_requests_expired_total",
                  "Requests shed: client deadline passed pre-dispatch"),
    "n_batch_errors": ("repro_driver_batch_errors_total",
                       "Batches whose dispatch raised"),
    "n_quarantined": ("repro_driver_quarantined_total",
                      "Requests isolated by batch bisection and failed "
                      "alone (RequestFailed/503)"),
    "n_bisections": ("repro_driver_bisect_splits_total",
                     "Failing-batch splits performed while isolating "
                     "poison requests"),
    "n_driver_crashes": ("repro_driver_crashes_total",
                         "Driver-thread deaths absorbed in supervised "
                         "mode"),
    "n_restarts": ("repro_driver_restarts_total",
                   "Driver-thread restarts (supervisor or manual)"),
}
_FLUSH_REASONS = {"n_flush_full": "full", "n_flush_deadline": "deadline",
                  "n_flush_drain": "drain"}


class DriverStats:
    """Driver-side counters (the engine keeps the latency distributions).

    Plain int attributes with the exact field set of the original
    dataclass — ``stats.n_completed += 1`` call sites and ``summary()``
    consumers see no difference.  The ints are the source of truth; a
    bound `repro.obs.MetricsRegistry` sees them through ``publish()``,
    which the driver's scrape-time collector calls — zero registry lock
    traffic on the submit/flush hot path.
    """

    _FIELDS = ("n_submitted", "n_completed", "n_cancelled", "n_expired",
               "n_batch_errors", "n_quarantined", "n_bisections",
               "n_driver_crashes", "n_restarts", "n_flush_full",
               "n_flush_deadline", "n_flush_drain", "queue_peak")

    def __init__(self) -> None:
        for f in self._FIELDS:
            setattr(self, f, 0)
        self._mirror: Dict[str, object] = {}
        self._c_flush = None
        self._g_peak = None

    def bind(self, registry) -> None:
        for attr, (metric, help_text) in _DRIVER_COUNTERS.items():
            self._mirror[attr] = registry.counter(metric, help_text)
        self._c_flush = registry.counter(
            "repro_driver_flush_total",
            "Batches flushed, by trigger (full bucket / deadline / drain)",
            labels=("reason",))
        self._g_peak = registry.gauge(
            "repro_driver_queue_peak",
            "High-water pending-queue depth")
        self.publish()

    def publish(self) -> None:
        """Mirror current totals into the bound registry (collector path:
        runs at scrape time, never per request)."""
        for attr, c in self._mirror.items():
            c.set_total(getattr(self, attr))
        if self._c_flush is not None:
            for attr, reason in _FLUSH_REASONS.items():
                self._c_flush.set_total(getattr(self, attr), reason=reason)
        if self._g_peak is not None:
            self._g_peak.set(float(self.queue_peak))

    def summary(self) -> Dict:
        return {f: getattr(self, f) for f in self._FIELDS}


@dataclasses.dataclass
class _Pending:
    req: PendingRequest         # validated request (rid assigned by engine)
    future: RetrievalFuture
    t_arrival: float            # driver-clock seconds (deadline policy)

    @property
    def mask_key(self):
        return self.req.mask_key


_NEW, _RUNNING, _STOPPING, _STOPPED = "new", "running", "stopping", "stopped"


class EngineDriver:
    """Background batching loop over a ``RetrievalEngine``.

    Args:
      engine:       the engine to drive (its ``policy`` supplies the bucket
                    ladder; its ``lock`` serializes dispatches against
                    client-side corpus mutations).
      max_wait_ms:  deadline a partial batch waits for companions before
                    flushing (0 = flush on arrival).
      max_queue:    pending-queue bound; ``submit`` blocks past it.
      clock:        time source for the *deadline policy only* (injectable
                    for tests); engine latency stats always use
                    ``time.perf_counter``.
    """

    def __init__(
        self,
        engine: RetrievalEngine,
        *,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
        clock: Callable[[], float] = time.perf_counter,
        name: str = "engine-driver",
    ):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.engine = engine
        self.batcher = DeadlineBatcher(engine.policy, float(max_wait_ms) / 1e3)
        self.stats = DriverStats()
        self.stats.bind(engine.metrics)
        self._h_wait = engine.metrics.histogram(
            "repro_driver_queue_wait_ms",
            "Driver-queue wait: submit to batch formation")
        self._g_depth = engine.metrics.gauge(
            "repro_driver_queue_depth",
            "Requests pending in the driver queue")
        # -- adaptive policy + query cache, built from the engine's config
        # sections (both default-off; the driver owns them because the
        # pressure signals — queue depth / queue-wait p95 — are driver-side)
        acfg = engine.config.adaptive
        self.adaptive: Optional[AdaptivePolicy] = (
            AdaptivePolicy(acfg) if acfg.enabled else None)
        if self.adaptive is not None:
            self.adaptive.bind(engine.metrics)
        ccfg = engine.config.cache
        self.cache: Optional[QueryCache] = (
            QueryCache(engine.store.d_emb, capacity=ccfg.capacity,
                       near_eps=ccfg.near_eps) if ccfg.enabled else None)
        if self.cache is not None:
            self.cache.bind(engine.metrics)
        # recent queue waits (seconds) feeding the policy's p95 signal;
        # consumed (cleared) at each policy update so recovery sees a
        # fresh window instead of old overload samples
        self._wait_samples: Deque[float] = deque(maxlen=128)
        engine.metrics.register_collector(self._collect_metrics)
        self._clock = clock
        self._max_queue = int(max_queue)
        self._name = name
        self._pending: Deque[_Pending] = deque()
        self._cv = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._state = _NEW
        self._drain = True
        self._join_timed_out = False
        self._fatal: Optional[BaseException] = None
        # -- fault tolerance: heartbeat stamped per loop iteration (the
        # supervisor's hang detector), an epoch that lets restart() abandon
        # a wedged thread (it exits at its next safe point), and the
        # supervised-crash slot (thread died, state stays _RUNNING so a
        # restart can resume the pending queue)
        self._bisect = bool(engine.config.fault.poison_bisect)
        self._supervised = False
        self._epoch = 0
        self._hb = 0.0
        self._crash: Optional[BaseException] = None
        self.supervisor = None            # attached by Supervisor.__init__

    # -- lifecycle -----------------------------------------------------------
    def start(self, *, supervised: bool = False) -> "EngineDriver":
        """Spawn the batching thread; returns self for chaining.

        ``supervised=True`` changes what a driver-loop crash does: instead
        of failing every pending request and going fatal, the thread
        records the crash and dies with the queue INTACT — a supervisor (or
        a manual ``restart()``) then resumes service.  Unsupervised, a
        crash stays fatal exactly as before.
        """
        with self._cv:
            if self._state != _NEW:
                raise RuntimeError(f"driver already {self._state}")
            self._supervised = bool(supervised)
            self._state = _RUNNING
            self._hb = self._clock()
            self._thread = threading.Thread(
                target=self._run, args=(self._epoch,), name=self._name,
                daemon=True)
            self._thread.start()
        return self

    def restart(self) -> bool:
        """Replace a dead or hung driver thread; pending requests survive.

        Bumps the thread epoch — a hung-but-alive old thread notices the
        stale epoch at its next safe point and exits without touching
        shared state (its in-flight dispatch, if any, still resolves its
        own futures).  Returns False when the driver isn't running (there
        is nothing to revive).
        """
        with self._cv:
            if self._state != _RUNNING:
                return False
            self._crash = None
            self._epoch += 1
            self._hb = self._clock()
            self.stats.n_restarts += 1
            self._thread = threading.Thread(
                target=self._run, args=(self._epoch,),
                name=f"{self._name}-r{self._epoch}", daemon=True)
            self._thread.start()
            self._cv.notify_all()
        return True

    def health(self) -> Dict:
        """Liveness snapshot the supervisor (and deep health) polls."""
        with self._cv:
            now = self._clock()
            alive = self._thread is not None and self._thread.is_alive()
            oldest = (now - self._pending[0].t_arrival
                      if self._pending else 0.0)
            return {
                "state": self._state,
                "thread_alive": alive,
                "heartbeat_age_s": max(0.0, now - self._hb),
                "oldest_wait_s": oldest,
                "n_pending": len(self._pending),
                "n_restarts": self.stats.n_restarts,
                "crashed": self._crash is not None,
            }

    def kill(self, error: BaseException) -> None:
        """Supervisor gave up: fail everything pending and go fatal."""
        with self._cv:
            if self._state == _STOPPED:
                return
            self._fatal = error
            self._epoch += 1             # any surviving thread stands down
            self._finish_locked()

    def stop(self, *, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Shut the driver down.

        ``drain=True`` serves every accepted request first; ``drain=False``
        cancels pending requests (their futures raise ``DriverStopped``).
        Idempotent.  Re-raises a fatal driver-loop error, and raises
        ``TimeoutError`` if the thread doesn't exit within ``timeout``.
        """
        with self._cv:
            if self._state == _STOPPED:
                if self._fatal is not None:
                    raise self._fatal
                return
            if self._state == _NEW:
                # never started: resolve the backlog inline on this thread
                self._state = _STOPPING
                if drain:
                    while self._pending:
                        self._dispatch(self._take_locked(
                            self.engine.policy.max_size), "drain")
                self._finish_locked()
                return
            if self._state == _RUNNING:
                self._state = _STOPPING
                self._drain = drain
                self._cv.notify_all()
            elif not drain and self._drain and self._join_timed_out:
                # already _STOPPING.  A concurrent stop(drain=True) owns the
                # drain policy — an abort racing a healthy drain must not
                # revoke the promise to serve accepted requests.  But once a
                # drain stop() has TIMED OUT the thread is presumed wedged,
                # and a retry with drain=False may DOWNGRADE the policy to
                # reclaim it instead of leaving the driver stuck in
                # _STOPPING forever.
                self._drain = False
                self._cv.notify_all()
        assert self._thread is not None
        self._thread.join(timeout)
        with self._cv:
            if self._thread.is_alive():
                self._join_timed_out = True
                raise TimeoutError(
                    f"driver thread did not stop within {timeout}s")
            if self._state != _STOPPED:
                # the thread is gone but never reached _finish_locked (it
                # crashed in supervised mode, or died uncleanly): complete
                # the shutdown on its behalf so stop() leaves no zombie
                # state behind
                self._finish_locked()
        if self._fatal is not None:
            raise self._fatal

    def __enter__(self) -> "EngineDriver":
        with self._cv:
            not_started = self._state == _NEW
        if not_started:
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # clean exit drains; an exception in the body aborts (the caller is
        # unwinding — don't block on a backlog it no longer wants)
        self.stop(drain=exc_type is None)
        return False

    @property
    def running(self) -> bool:
        with self._cv:
            return self._state == _RUNNING

    @property
    def n_pending(self) -> int:
        with self._cv:
            return len(self._pending)

    # -- client API ----------------------------------------------------------
    def submit(self, request, *,
               timeout: Optional[float] = None) -> RetrievalFuture:
        """Enqueue one request from any thread; returns a
        ``RetrievalFuture``.

        ``request`` is a raw (D,)/(1, D) query vector or a
        `repro.engine.request.SearchRequest` carrying per-request
        k/tenant/filter/deadline (a raw array means ``SearchRequest(query)``
        exactly).  Blocks while the pending queue is full (backpressure);
        raises ``DriverQueueFull`` if no slot frees within ``timeout`` and
        ``DriverStopped`` once the driver is shutting down.  Accepted before
        ``start()`` too — requests just wait for the thread (or an inline
        ``stop(drain=True)``).
        """
        req = self.engine.check_request(request)
        if self.cache is not None:
            hit = self._cache_lookup(req)
            if hit is not None:
                return hit
        fut = RetrievalFuture()
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._cv:
            while True:
                if self._fatal is not None:
                    raise DriverStopped(
                        "driver thread died") from self._fatal
                if self._state in (_STOPPING, _STOPPED):
                    raise DriverStopped("driver is not accepting requests")
                if len(self._pending) < self._max_queue:
                    break
                if deadline is None:
                    self._cv.wait()
                else:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        raise DriverQueueFull(
                            f"pending queue held {self._max_queue} requests "
                            f"for {timeout}s")
                    self._cv.wait(remaining)
            self._pending.append(_Pending(req, fut, self._clock()))
            if req.trace is not None:
                req.trace.mark("admit")
            self.stats.n_submitted += 1
            if len(self._pending) > self.stats.queue_peak:
                self.stats.queue_peak = len(self._pending)
            self._cv.notify_all()
        return fut

    def _cache_lookup(self, req: PendingRequest
                      ) -> Optional[RetrievalFuture]:
        """Serve ``req`` from the query cache if possible.

        Runs on the client thread BEFORE the request enters the pending
        queue, so a hit skips batch formation and dispatch entirely.  The
        staleness stamp is read under ``engine.lock`` right here — a
        cached entry from before any store/mask/rebuild bump can never
        match it (the cache flushes on stamp change), so stale hits are
        structurally impossible.  Hits bypass the driver's
        n_submitted/n_completed accounting on purpose: those counters
        reconcile against engine batches, and no batch ran.
        """
        level = self.adaptive.level if self.adaptive is not None else 0
        stamp = self.engine.cache_stamp()
        got = self.cache.lookup(req.query, req.k, req.mask_key, level, stamp)
        if got is None:
            return None
        scores, ids, _kind = got
        now = time.perf_counter()
        st = RequestStats(
            latency_ms=(now - req.t_submit) * 1e3, queue_ms=0.0,
            compute_ms=0.0, bucket=0, batch_fill=0, compiled=False)
        fut = RetrievalFuture()
        fut._finish(result=RetrievalResult(
            -1, scores, ids, st, store_generation=stamp[0], cached=True,
            degraded_level=level))
        return fut

    def retrieve(self, request, *,
                 timeout: Optional[float] = None) -> RetrievalResult:
        """Blocking submit-and-wait (raw vector or `SearchRequest`);
        ``timeout`` bounds the whole round trip."""
        t0 = time.perf_counter()
        fut = self.submit(request, timeout=timeout)
        remaining = (None if timeout is None
                     else max(0.0, timeout - (time.perf_counter() - t0)))
        return fut.result(remaining)

    # -- batching loop -------------------------------------------------------
    def _take_locked(self, n: int) -> List[_Pending]:
        """Take up to ``n`` requests sharing the head's mask key.

        A dispatch applies ONE tenant/filter bitmask, so only same-key
        requests may share a batch; non-matching requests keep their order
        for the next iteration (the head always progresses — FIFO by the
        oldest request, no starvation).  Unfiltered traffic (mask_key None)
        batches exactly as before.
        """
        if not self._pending:
            return []
        key = self._pending[0].mask_key
        taken: List[_Pending] = []
        skipped: List[_Pending] = []
        while self._pending and len(taken) < n:
            p = self._pending.popleft()
            if p.mask_key == key:
                taken.append(p)
            else:
                skipped.append(p)
        self._pending.extendleft(reversed(skipped))
        now = self._clock()
        waits = [(now - p.t_arrival) for p in taken]
        self._h_wait.observe_many([w * 1e3 for w in waits])
        self._wait_samples.extend(waits)     # adaptive-policy p95 window
        # one real-clock read for the whole batch: trace marks live on the
        # perf_counter timebase (not the injectable policy clock)
        t_batch = time.perf_counter()
        for p in taken:
            if p.req.trace is not None:
                p.req.trace.marks["batch"] = t_batch
        return taken

    def _wait_p95_ms(self) -> Optional[float]:
        """p95 of the queue waits observed since the last policy update
        (caller holds the cv).  The window is consumed: stale overload
        samples must not keep blocking recovery once the queue is calm."""
        if not self._wait_samples:
            return None
        xs = sorted(self._wait_samples)
        self._wait_samples.clear()
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))] * 1e3

    def _collect_metrics(self) -> None:
        """Scrape-time collector: queue-depth gauge + counter totals
        (lock order: cv -> registry, same as every hot-path instrument)."""
        with self._cv:
            self._g_depth.set(float(len(self._pending)))
            self.stats.publish()
            if self.adaptive is not None:
                self.adaptive.publish()
        if self.cache is not None:
            self.cache.publish()

    def _finish_locked(self) -> None:
        """Cancel whatever is left and mark the driver stopped."""
        for p in self._pending:
            p.future._finish(error=DriverStopped(
                "driver stopped before this request was dispatched"))
            self.stats.n_cancelled += 1
        self._pending.clear()
        self._state = _STOPPED
        self._cv.notify_all()

    def _dispatch(self, chunk: List[_Pending], reason: str) -> None:
        """Run one flushed chunk through the engine and resolve its futures."""
        if not chunk:
            return
        # count the flush FIRST: the batcher formed and flushed this batch
        # under ``reason`` regardless of what the shedding below leaves of
        # it.  (Counting after the shed dropped the flush entirely for a
        # group whose members had all expired — the batch then vanished
        # from the flush accounting while its sheds still landed in
        # n_expired.)
        if reason == "full":
            self.stats.n_flush_full += 1
        elif reason == "deadline":
            self.stats.n_flush_deadline += 1
        else:
            self.stats.n_flush_drain += 1
        # drop requests whose client deadline already passed: their futures
        # fail with DeadlineExceeded and they never reach the device —
        # under overload this sheds exactly the work nobody waits for
        now = time.perf_counter()
        live: List[_Pending] = []
        for p in chunk:
            if p.req.deadline is not None and now > p.req.deadline:
                self.stats.n_expired += 1
                p.future._finish(error=DeadlineExceeded(
                    f"deadline expired {((now - p.req.deadline) * 1e3):.1f}ms "
                    f"before dispatch"))
            else:
                live.append(p)
        chunk = live
        if not chunk:
            # every member expired: nothing to dispatch — no empty/
            # degenerate batch may reach the engine
            return
        overrides = None
        if self.adaptive is not None:
            overrides = self.engine.overrides_for_level(self.adaptive.level)
        # static path keeps the bare legacy call shape: callers interposing
        # on execute_batch (tests, tracing wrappers) see no new kwarg
        # unless the policy actually degrades the dispatch
        kw = {} if overrides is None or overrides.level == 0 \
            else {"overrides": overrides}
        try:
            results = self.engine.execute_batch([p.req for p in chunk], **kw)
        except Exception as e:
            # fail this batch's clients — or, with bisection enabled,
            # isolate the offender so its co-batched neighbours still get
            # answers — and keep serving the next batch either way
            self.stats.n_batch_errors += 1
            if self._bisect and len(chunk) > 1:
                self.stats.n_bisections += 1
                self._bisect_failed(chunk, kw)
            else:
                for p in chunk:
                    p.future._finish(error=e)
            return
        self._resolve(chunk, results)

    def _bisect_failed(self, chunk: List[_Pending], kw: Dict) -> None:
        """Isolate the poison request(s) in a failing batch by bisection.

        Re-dispatches each half independently; halves that succeed resolve
        normally, halves that keep failing split again.  A failing
        singleton is the offender: its future gets ``RequestFailed`` (the
        HTTP layer's 503) and it is counted quarantined.  Deterministic
        per-request failures (the realistic poison shape: a query that
        trips a device/input bug on every dispatch) are isolated exactly;
        a transient batch-level error simply retries and succeeds.  Cost
        is O(log batch) extra dispatches per poison, paid only on batches
        that already failed.
        """
        mid = len(chunk) // 2
        for half in (chunk[:mid], chunk[mid:]):
            if not half:
                continue
            try:
                results = self.engine.execute_batch(
                    [p.req for p in half], **kw)
            except Exception as e:
                if len(half) == 1:
                    self.stats.n_quarantined += 1
                    half[0].future._finish(error=RequestFailed(
                        f"request isolated by batch bisection: {e}"))
                else:
                    self.stats.n_bisections += 1
                    self._bisect_failed(half, kw)
            else:
                self._resolve(half, results)

    def _resolve(self, chunk: List[_Pending], results) -> None:
        """Resolve a successfully dispatched chunk's futures + cache."""
        for p, res in zip(chunk, results):
            p.future._finish(result=res)
        self.stats.n_completed += len(chunk)
        if self.cache is not None:
            # stamp read AFTER the batch: if a mutation landed mid-window
            # the delivered results carry the older store_generation and
            # are skipped — never inserted against the newer stamp
            stamp = self.engine.cache_stamp()
            for p, res in zip(chunk, results):
                if res.store_generation != stamp[0]:
                    continue
                self.cache.insert(p.req.query, res.scores, res.doc_ids,
                                  p.req.mask_key, res.degraded_level, stamp)

    def _run(self, epoch: int = 0) -> None:
        try:
            while True:
                chunk: Optional[List[_Pending]] = None
                reason = ""
                with self._cv:
                    while chunk is None:
                        if self._epoch != epoch:
                            # a restart() replaced this thread while it was
                            # wedged: stand down without touching shared
                            # state — the replacement owns the queue now
                            return
                        self._hb = self._clock()
                        if self._state == _STOPPING:
                            if not self._drain or not self._pending:
                                self._finish_locked()
                                return
                            chunk = self._take_locked(
                                self.engine.policy.max_size)
                            reason = "drain"
                            break
                        if self.adaptive is not None:
                            # one controller step per loop iteration: the
                            # depth/wait signals are already in hand here,
                            # and single-writer discipline holds (only this
                            # thread moves the level)
                            self.adaptive.update(
                                len(self._pending), self._wait_p95_ms(),
                                self._clock())
                        d = self.batcher.decide(
                            len(self._pending),
                            self._pending[0].t_arrival
                            if self._pending else 0.0,
                            self._clock(),
                        )
                        if d.action == "flush":
                            chunk, reason = self._take_locked(d.n), d.reason
                        elif d.action == "wait":
                            # supervised: cap the batching wait so the loop
                            # wakes to re-stamp the heartbeat — a thread
                            # waiting out a long max_wait_ms with requests
                            # pending is healthy, and must not look hung
                            w = d.wait_s
                            if self._supervised:
                                w = min(w, self.engine.config.fault
                                        .heartbeat_timeout_s / 2)
                            self._cv.wait(w)
                        elif (self.adaptive is not None
                                and self.adaptive.level > 0):
                            # idle while degraded: wake periodically so the
                            # hysteretic recovery can tick even with no
                            # arrivals to prod the loop
                            self._cv.wait(
                                max(0.05, self.adaptive.cfg.hysteresis_s / 4))
                        else:                     # idle: block for arrivals
                            self._cv.wait()
                    self._cv.notify_all()         # queue space freed
                # dispatch outside the cv so producers keep submitting while
                # the device computes (engine.lock still serializes engine
                # access)
                try:
                    self._dispatch(chunk, reason)
                except BaseException:
                    # a dispatch-path error past _dispatch's own handler is
                    # about to kill this thread: fail the chunk's unresolved
                    # futures first so no client blocks forever on a future
                    # nobody owns anymore
                    for p in chunk:
                        if not p.future.done():
                            p.future._finish(error=DriverStopped(
                                "driver thread died mid-dispatch"))
                    raise
                with self._cv:
                    self._hb = self._clock()
        except BaseException as e:
            with self._cv:
                if self._epoch != epoch:
                    return                        # superseded: stay silent
                if self._supervised and self._state == _RUNNING:
                    # supervised crash: record it and die with the pending
                    # queue INTACT — the supervisor restarts a fresh thread
                    # that picks the backlog right back up
                    self._crash = e
                    self.stats.n_driver_crashes += 1
                    return
                self._fatal = e
                self._finish_locked()

    def describe(self) -> str:
        return (
            f"EngineDriver(max_wait_ms={self.batcher.max_wait_s * 1e3:g}, "
            f"max_queue={self._max_queue}, state={self._state}, "
            f"engine={self.engine.describe()})"
        )

"""IVF-Flat approximate search — beyond-paper, TPU-idiomatic ANN comparator.

The paper compared against HNSW and noted its graph construction cost;
HNSW's pointer-chasing greedy graph walk has no efficient TPU analogue
(serial, data-dependent control flow — see DESIGN.md §Hardware-adaptation).
The TPU-native equivalent of "prune the search space before exact scoring"
is an inverted-file (IVF) index: k-means coarse quantizer + per-list exact
scan, which is pure matmul + gather and therefore maps onto the MXU.

It composes with progressive search: probing can run at a truncated
dimensionality and the final rescore at full dims — `ivf_progressive_search`
below — which is the paper's "future work: integration with ANN" realized.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import truncated as T
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def balanced_assign(
    choices: np.ndarray,
    confidence_order: np.ndarray,
    n_lists: int,
    cap: int,
) -> np.ndarray:
    """Capacity-bounded list assignment (host-side, build time).

    Plain nearest-centroid assignment over real corpora is heavily skewed
    (k-means cells routinely reach 5-10x the mean occupancy), and the IVF
    member table is dense: its width is the *longest* list, so every query
    pays the skew in padded candidate slots.  Bounding every list at ``cap``
    members keeps the table width — and therefore per-query scan cost —
    near the mean instead of the max.

    Rows are admitted to their most-preferred list with free capacity,
    confident rows first (a row whose nearest centroid is far away loses
    little by being displaced to its 2nd/3rd choice; a row close to its
    centroid should stay).  Rows exhausting all ``m`` choices spill into
    whatever lists still have spare capacity, lowest-indexed first (rare
    under a sane cap; every list stays bounded at ``cap`` regardless).

    Args:
      choices:          (N, m) int centroid preference order per row.
      confidence_order: (N,) row indices, most-confident first.
      n_lists:          number of lists.
      cap:              max members per list; needs n_lists * cap >= N.

    Returns:
      (N,) int32 list assignment.
    """
    n, m = choices.shape
    if n_lists * cap < n:
        raise ValueError(f"cap {cap} x {n_lists} lists cannot hold {n} rows")
    assign = np.full(n, -1, np.int32)
    counts = np.zeros(n_lists, np.int64)
    rank = np.empty(n, np.int64)
    rank[confidence_order] = np.arange(n)
    remaining = confidence_order.copy()
    for j in range(m):
        if remaining.size == 0:
            break
        pref = choices[remaining, j]
        # stable-sort by list, keeping confidence order within each list,
        # then admit each list's first (cap - occupancy) rows
        by_list = np.argsort(pref, kind="stable")
        pref_sorted = pref[by_list]
        group_start = np.searchsorted(pref_sorted, pref_sorted)
        pos_in_group = np.arange(remaining.size) - group_start
        admit = pos_in_group < (cap - counts[pref_sorted])
        rows = remaining[by_list[admit]]
        assign[rows] = pref_sorted[admit]
        np.add.at(counts, pref_sorted[admit], 1)
        remaining = remaining[by_list[~admit]]
        remaining = remaining[np.argsort(rank[remaining])]  # restore order
    if remaining.size:
        free = np.repeat(np.arange(n_lists), cap - counts)
        assign[remaining] = free[: remaining.size].astype(np.int32)
    return assign


def pack_lists(
    assign: np.ndarray,
    n_lists: int,
    *,
    ids: Optional[np.ndarray] = None,
    spare: int = 0,
    round_pow2: bool = False,
) -> np.ndarray:
    """Pack a (N,) list assignment into a dense -1-padded member table.

    The single packing path shared by `build_ivf` and the engine's IVF
    backend (one stable argsort, not a per-list scan — n_lists scales with
    N, so a scan per list would make builds quadratic).

    Args:
      assign:     (N,) int list assignment.
      n_lists:    number of lists.
      ids:        (N,) global ids to store (default ``arange(N)``).
      spare:      reserved free slots per list beyond the max occupancy
                  (incremental appends land here between rebuilds).
      round_pow2: round the table width up to a power of two (shape
                  stability across rebuilds keeps state swaps compile-free).

    Returns:
      (n_lists, width) int32 member table, -1 padded.
    """
    n = len(assign)
    if ids is None:
        ids = np.arange(n)
    counts = np.bincount(assign, minlength=n_lists)
    width = max(int(counts.max()) if n else 0, 0) + int(spare)
    width = max(width, 1)
    if round_pow2:
        width = 1 << (width - 1).bit_length()
    order = np.argsort(assign, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    table = np.full((n_lists, width), -1, np.int32)
    sorted_lists = assign[order]
    table[sorted_lists, np.arange(n) - starts[sorted_lists]] = ids[order]
    return table


@functools.partial(jax.jit, static_argnames=("n_lists", "n_iter"))
def kmeans(db: Array, n_lists: int, *, n_iter: int = 10, key=None) -> Array:
    """Lloyd's k-means over db rows. Returns (n_lists, D) centroids."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = db.shape[0]
    init_idx = jax.random.choice(key, n, (n_lists,), replace=False)
    cents = db[init_idx].astype(jnp.float32)

    def step(cents, _):
        s = T.l2_scores(db.astype(jnp.float32), cents)   # (N, n_lists)
        assign = jnp.argmin(s, axis=1)
        one_hot = jax.nn.one_hot(assign, n_lists, dtype=jnp.float32)
        counts = one_hot.sum(axis=0)                     # (n_lists,)
        sums = one_hot.T @ db.astype(jnp.float32)        # (n_lists, D)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None], cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=n_iter)
    return cents


def build_ivf(
    db: Array, n_lists: int, *, key=None, n_iter: int = 10
) -> Dict[str, Array]:
    """Build an IVF index: centroids + padded per-list member tables.

    Lists are padded to the max list length so the structure is a dense
    (n_lists, max_len) int32 table — static shapes for XLA, -1 padding.
    """
    cents = kmeans(db, n_lists, key=key, n_iter=n_iter)
    s = T.l2_scores(db.astype(jnp.float32), cents)
    # Host-side packing (build time, not query time) through the same
    # assignment + packing path the engine backend uses: balanced_assign
    # with an unbounded cap IS plain nearest-centroid assignment, and
    # pack_lists is the one dense-table builder — the two paths can't drift.
    choices = np.asarray(jnp.argmin(s, axis=1))[:, None]
    n = choices.shape[0]
    assign_np = balanced_assign(choices, np.arange(n), n_lists, cap=n)
    table = pack_lists(assign_np, n_lists)
    return {
        "centroids": cents,
        "lists": jnp.asarray(table),
        "assign": jnp.asarray(assign_np.astype(np.int32)),
    }


@functools.partial(jax.jit, static_argnames=("n_probe", "k", "dim"))
def ivf_search(
    q: Array, db: Array, ivf: Dict[str, Array], *, n_probe: int, k: int,
    dim: int | None = None, valid: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """IVF-Flat search: probe ``n_probe`` nearest lists, exact-scan their members.

    Args:
      q:     (Q, D) queries.  dim: optional truncation for probing+scan.
      valid: optional (N,) bool row mask (mutable corpora): candidates whose
             bit is clear are scored +inf and can never be returned.
    Returns:
      ((Q, k) scores, (Q, k) int32 indices).
    """
    d = dim or db.shape[1]
    qd = q[:, :d]
    cents = ivf["centroids"][:, :d]
    cs = T.l2_scores(qd, cents)                      # (Q, n_lists)
    _, probe = jax.lax.top_k(-cs, n_probe)           # (Q, n_probe)
    members = ivf["lists"][probe]                    # (Q, n_probe, max_len)
    cand = members.reshape(q.shape[0], -1)           # (Q, n_probe*max_len)
    return T.rescore_candidates(qd, db[:, :d], cand, dim=d, k=k, valid=valid)


@functools.partial(jax.jit, static_argnames=("n_probe", "k", "d_probe", "d_final"))
def ivf_progressive_search(
    q: Array,
    db: Array,
    ivf: Dict[str, Array],
    *,
    n_probe: int,
    k: int,
    d_probe: int,
    d_final: int,
    valid: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """IVF probing at truncated dims + exact rescore at full dims.

    Realizes the paper's future-work suggestion: ANN candidate generation
    composed with progressive dimensional refinement.
    """
    _, cand = ivf_search(q, db, ivf, n_probe=n_probe, k=k * 8,
                         dim=d_probe, valid=valid)
    return T.rescore_candidates(q, db, cand, dim=d_final, k=k, valid=valid)


@functools.partial(
    jax.jit, static_argnames=("sched", "n_probe", "index_dims", "metric",
                             "stage0_only")
)
def ivf_progressive_search_sched(
    q: Array,
    db: Array,
    centroids: Array,
    lists: Array,
    sched: ProgressiveSchedule,
    *,
    n_probe: int,
    valid: Optional[Array] = None,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    extra_cand: Optional[Array] = None,
    metric: str = "l2",
    cent_sq: Optional[Array] = None,
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """Full progressive schedule with IVF probing replacing the stage-0 scan.

    Probing runs at the centroids' own dimensionality (the space they were
    clustered in — build/search consistency keeps an exact-match query
    probing the cell its document was assigned to); probed members — plus
    optional ``extra_cand`` rows, e.g. the engine's un-indexed tail window —
    are rescored through the schedule's stages at full precision, exactly
    like the flat path after stage 0.

    Args:
      centroids:  (n_lists, d_probe) coarse quantizer; d_probe <= q dim.
      lists:      (n_lists, max_len) int32 member table, -1 padded.
      extra_cand: optional (E,) int32 ids injected into every query's
                  candidate list (-1 padded); must be disjoint from list
                  members so the final top-k carries no duplicate ids.
      valid:      optional (N,) bool row mask threaded through every stage.
      cent_sq:    optional (n_lists,) precomputed centroid squared norms —
                  built backends cache these so probing doesn't recompute
                  them per search call.
    """
    from repro.core.progressive import rescore_ladder

    s0 = sched.stages[0]
    score_fn = T._METRICS[metric]

    d_probe = centroids.shape[1]
    cs = score_fn(q[:, :d_probe], centroids, cent_sq)  # (Q, n_lists)
    _, probe = jax.lax.top_k(-cs, min(n_probe, centroids.shape[0]))
    cand = lists[probe].reshape(q.shape[0], -1)       # (Q, n_probe*max_len)
    cand = T.inject_candidates(cand, extra_cand)
    if cand.shape[1] < s0.k:
        # top_k needs k <= C; -1 columns score +inf and change nothing
        cand = jnp.pad(cand, ((0, 0), (0, s0.k - cand.shape[1])),
                       constant_values=-1)
    if stage0_only:
        # fenced split: probing produced candidates but no scores — the
        # ladder (ALL schedule stages, scores=None) finishes the search
        return None, cand
    # the probed members replace the stage-0 full scan; every schedule
    # stage (stage 0 included) is now a rescore over them
    return rescore_ladder(
        q, db, cand, sched.stages,
        sq_prefix=sq_prefix, index_dims=index_dims,
        valid=valid, metric=metric,
    )


def _sq_col(sq_prefix, index_dims, dim: int):
    """Static lookup of the cached prefix-norm column at ``dim``, if any."""
    if sq_prefix is None or index_dims is None:
        return None
    dims = tuple(int(x) for x in index_dims)
    if int(dim) not in dims:
        return None
    return sq_prefix[:, dims.index(int(dim))]


@functools.partial(
    jax.jit,
    static_argnames=("sched", "n_probe", "index_dims", "metric",
                     "pack_meta", "merge", "pq_oversample", "interpret",
                     "stage0_only"),
)
def _kernel_search_jit(
    q, db, centroids, lists, pack_rows, pack_sq, pack_scale,
    pack_codebooks, pack_cent_sq,
    valid, sq_prefix, extra_cand, cent_sq, sched,
    *, n_probe, index_dims, metric, pack_meta, merge, pq_oversample,
    interpret, stage0_only=False,
):
    from repro.kernels.ivf_scan import ivf_scan_topk
    from repro.kernels.pq_scan import pq_ivf_scan_topk
    from repro.core.progressive import rescore_ladder

    s0 = sched.stages[0]
    d_probe = centroids.shape[1]
    cs = T._METRICS[metric](q[:, :d_probe], centroids, cent_sq)
    _, probe = jax.lax.top_k(-cs, min(n_probe, centroids.shape[0]))

    # mask every unreturnable slot to -1 BEFORE the kernel: list padding is
    # already -1, tombstoned rows come from the live validity bits (the
    # packed member vectors are a build-time snapshot)
    member_ids = lists
    if valid is not None:
        member_ids = jnp.where(
            (lists >= 0) & valid[jnp.maximum(lists, 0)], lists, -1)

    pack = {
        "rows": pack_rows, "sq": pack_sq, "scale": pack_scale,
        "codebooks": pack_codebooks, "cent_sq": pack_cent_sq,
        "dim": pack_meta[0], "max_len": pack_meta[1],
        "block_m": pack_meta[2], "dtype": pack_meta[3],
    }
    if pack_meta[3] == "pq":
        # oversampled survivor pool: the classic PQ remedy for ADC ranking
        # noise — the full-precision rescore ladder cuts it back
        k0_eff = s0.k * pq_oversample
        scores, cand = pq_ivf_scan_topk(
            q, probe, member_ids, pack, k=k0_eff, merge=merge,
            interpret=interpret)
    else:
        k0_eff = s0.k
        scores, cand = ivf_scan_topk(
            q, probe, member_ids, pack, k=k0_eff, merge=merge,
            interpret=interpret)

    if extra_cand is not None:
        # the un-indexed tail window competes in stage 0 exactly as the XLA
        # path's inject_candidates placement: rescore the (few) tail rows at
        # the stage-0 dim and fold them into the kernel's top-k
        e = extra_cand.shape[0]
        tail_tbl = jnp.broadcast_to(
            extra_cand[None, :], (q.shape[0], e))
        # keep as many tail survivors as the (possibly oversampled) pool
        # can seat — capping at s0.k would let coded rows crowd appended
        # rows out of pool slots they outscore
        ts, ti = T.rescore_candidates(
            q, db, tail_tbl, dim=s0.dim, k=min(k0_eff, e),
            db_sq_at_dim=_sq_col(sq_prefix, index_dims, s0.dim),
            valid=valid, metric=metric,
        )
        cat_s = jnp.concatenate([scores, ts], axis=1)
        cat_i = jnp.concatenate([cand, ti], axis=1)
        neg, pos = jax.lax.top_k(-cat_s, k0_eff)
        scores = -neg
        cand = jnp.take_along_axis(cat_i, pos, axis=1)

    if stage0_only:
        return scores, cand
    return rescore_ladder(
        q, db, cand, sched.stages[1:],
        sq_prefix=sq_prefix, index_dims=index_dims,
        valid=valid, metric=metric, scores=scores,
    )


def ivf_progressive_search_kernel(
    q: Array,
    db: Array,
    centroids: Array,
    lists: Array,
    sched: ProgressiveSchedule,
    *,
    n_probe: int,
    valid: Optional[Array] = None,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    extra_cand: Optional[Array] = None,
    metric: str = "l2",
    cent_sq: Optional[Array] = None,
    pack: Optional[Dict] = None,
    merge: str = "sort",
    block_m: int = 128,
    pq_oversample: int = 1,
    interpret: bool = False,
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """`ivf_progressive_search_sched` with the fused Pallas stage-0 kernel.

    Same signature and same results (identical top-k id sets under fixed
    probes — the parity contract `tests/test_kernels.py` enforces), but
    stage 0 runs `repro.kernels.ivf_scan.ivf_scan_topk` — or, for
    ``dtype='pq'`` packs, `repro.kernels.pq_scan.pq_ivf_scan_topk` (the
    fused probe+LUT-scan: per-query ADC tables stay VMEM-resident while
    M-byte code slabs stream) — so probed lists' member rows stream
    HBM→VMEM once and the top-k never leaves VMEM, instead of the XLA
    gather → materialized candidate table → score matrix round trips.  The
    tail ``extra_cand`` window is rescored at the stage-0 dim and merged
    into the kernel's top-k, so injected rows compete exactly where
    `inject_candidates` puts them on the XLA path.

    Extra args over the sched path:
      pack:      `pack_ivf_lists` build artifact (member slabs at the
                 stage-0 dim; pass the cached one from backend state — when
                 None it is packed on the fly, which costs a full gather).
      merge:     in-kernel top-k merge strategy ('sort' | 'select').
      block_m:   member rows per kernel step (on-the-fly packs only).
      pq_oversample: 'pq' packs only — stage-0 survivor pool widens to
                 ``pq_oversample × k0`` (ADC ranking noise is absorbed by
                 the full-precision rescore, which cuts the pool back).
      interpret: run the kernel in interpret mode (CPU validation).
    """
    if metric != "l2":
        raise ValueError(
            f"the fused IVF kernel scores L2 only, got metric={metric!r} "
            f"(use ivf_progressive_search_sched)"
        )
    s0 = sched.stages[0]
    if pack is None:
        from repro.kernels.ivf_scan import pack_ivf_lists
        pack = pack_ivf_lists(
            db, lists, dim=s0.dim,
            db_sq_at_dim=_sq_col(sq_prefix, index_dims, s0.dim),
            block_m=block_m,
        )
    pack_meta = (pack["dim"], pack["max_len"], pack["block_m"], pack["dtype"])
    return _kernel_search_jit(
        q, db, centroids, lists, pack["rows"], pack["sq"], pack["scale"],
        pack.get("codebooks"), pack.get("cent_sq"),
        valid, sq_prefix, extra_cand, cent_sq, sched,
        n_probe=n_probe, index_dims=index_dims, metric=metric,
        pack_meta=pack_meta, merge=merge, pq_oversample=pq_oversample,
        interpret=interpret, stage0_only=stage0_only,
    )

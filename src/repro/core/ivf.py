"""IVF-Flat approximate search — beyond-paper, TPU-idiomatic ANN comparator.

The paper compared against HNSW and noted its graph construction cost;
HNSW's pointer-chasing greedy graph walk has no efficient TPU analogue
(serial, data-dependent control flow — see DESIGN.md §Hardware-adaptation).
The TPU-native equivalent of "prune the search space before exact scoring"
is an inverted-file (IVF) index: k-means coarse quantizer + per-list exact
scan, which is pure matmul + gather and therefore maps onto the MXU.

It composes with progressive search: probing can run at a truncated
dimensionality and the final rescore at full dims — `ivf_progressive_search`
below — which is the paper's "future work: integration with ANN" realized.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import truncated as T

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("n_lists", "n_iter"))
def kmeans(db: Array, n_lists: int, *, n_iter: int = 10, key=None) -> Array:
    """Lloyd's k-means over db rows. Returns (n_lists, D) centroids."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = db.shape[0]
    init_idx = jax.random.choice(key, n, (n_lists,), replace=False)
    cents = db[init_idx].astype(jnp.float32)

    def step(cents, _):
        s = T.l2_scores(db.astype(jnp.float32), cents)   # (N, n_lists)
        assign = jnp.argmin(s, axis=1)
        one_hot = jax.nn.one_hot(assign, n_lists, dtype=jnp.float32)
        counts = one_hot.sum(axis=0)                     # (n_lists,)
        sums = one_hot.T @ db.astype(jnp.float32)        # (n_lists, D)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None], cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=n_iter)
    return cents


def build_ivf(
    db: Array, n_lists: int, *, key=None, n_iter: int = 10
) -> Dict[str, Array]:
    """Build an IVF index: centroids + padded per-list member tables.

    Lists are padded to the max list length so the structure is a dense
    (n_lists, max_len) int32 table — static shapes for XLA, -1 padding.
    """
    cents = kmeans(db, n_lists, key=key, n_iter=n_iter)
    s = T.l2_scores(db.astype(jnp.float32), cents)
    assign = jnp.asarray(jnp.argmin(s, axis=1))
    # Host-side packing (build time, not query time).
    import numpy as np
    assign_np = np.asarray(assign)
    lists = [np.nonzero(assign_np == c)[0] for c in range(n_lists)]
    max_len = max(max(len(l) for l in lists), 1)
    table = np.full((n_lists, max_len), -1, np.int32)
    for c, l in enumerate(lists):
        table[c, : len(l)] = l
    return {
        "centroids": cents,
        "lists": jnp.asarray(table),
        "assign": jnp.asarray(assign_np.astype(np.int32)),
    }


@functools.partial(jax.jit, static_argnames=("n_probe", "k", "dim"))
def ivf_search(
    q: Array, db: Array, ivf: Dict[str, Array], *, n_probe: int, k: int, dim: int | None = None
) -> Tuple[Array, Array]:
    """IVF-Flat search: probe ``n_probe`` nearest lists, exact-scan their members.

    Args:
      q:   (Q, D) queries.  dim: optional truncation for probing+scan.
    Returns:
      ((Q, k) scores, (Q, k) int32 indices).
    """
    d = dim or db.shape[1]
    qd = q[:, :d]
    cents = ivf["centroids"][:, :d]
    cs = T.l2_scores(qd, cents)                      # (Q, n_lists)
    _, probe = jax.lax.top_k(-cs, n_probe)           # (Q, n_probe)
    members = ivf["lists"][probe]                    # (Q, n_probe, max_len)
    cand = members.reshape(q.shape[0], -1)           # (Q, n_probe*max_len)
    return T.rescore_candidates(qd, db[:, :d], cand, dim=d, k=k)


@functools.partial(jax.jit, static_argnames=("n_probe", "k", "d_probe", "d_final"))
def ivf_progressive_search(
    q: Array,
    db: Array,
    ivf: Dict[str, Array],
    *,
    n_probe: int,
    k: int,
    d_probe: int,
    d_final: int,
) -> Tuple[Array, Array]:
    """IVF probing at truncated dims + exact rescore at full dims.

    Realizes the paper's future-work suggestion: ANN candidate generation
    composed with progressive dimensional refinement.
    """
    _, cand = ivf_search(q, db, ivf, n_probe=n_probe, k=max(k * 8, k), dim=d_probe)
    return T.rescore_candidates(q, db, cand, dim=d_final, k=k)

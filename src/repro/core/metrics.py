"""Retrieval quality metrics (paper §III.E)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def top1_accuracy(retrieved: Array, ground_truth: Array) -> Array:
    """Fraction of queries whose top-1 retrieved index equals the ground truth.

    Args:
      retrieved:    (Q,) or (Q, k) retrieved indices (column 0 = best).
      ground_truth: (Q,) true document index per query.
    """
    if retrieved.ndim == 2:
        retrieved = retrieved[:, 0]
    return jnp.mean((retrieved == ground_truth).astype(jnp.float32))


def recall_at_k(retrieved: Array, ground_truth: Array, k: int) -> Array:
    """Fraction of queries whose ground truth appears in the top-k retrieved."""
    hits = (retrieved[:, :k] == ground_truth[:, None]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))


def overlap_at_k(a: Array, b: Array, k: int) -> Array:
    """Mean per-query overlap |a_k ∩ b_k| / k between two retrieval results."""
    eq = a[:, :k, None] == b[:, None, :k]
    inter = eq.any(axis=2).sum(axis=1)
    return jnp.mean(inter.astype(jnp.float32)) / k

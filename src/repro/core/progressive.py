"""Progressive Retrieval — the paper's contribution (§III.D), TPU-native.

Multi-stage search: stage 0 scans the *entire* database at a low truncated
dimensionality keeping K candidates per query; each subsequent stage doubles
the dimensionality, halves K, and rescores only the surviving candidates; the
final stage runs exact 1-NN at the target dimensionality on the remaining
pool.  Early stages are cheap (low dim) but touch everything; late stages are
expensive per row but touch almost nothing — total work collapses from
O(N·D_max) to O(N·D_start + Σ K_s·D_s).

Two variants are provided:

* ``progressive_search`` — **per-query candidate sets, fully static shapes.**
  Every stage has a compile-time-known pool size, so the whole pipeline jits
  into one XLA program and shards under pjit.  This is the TPU adaptation of
  the paper's algorithm (see DESIGN.md §Hardware-adaptation): the paper's
  reference implementation pools candidates across the query batch into one
  deduplicated set, which is a dynamic-shape construct that XLA cannot
  express; per-query sets keep *at least* the paper's per-query candidates,
  so stage-s recall is >= the pooled variant restricted to each query's own
  survivors.

* ``progressive_search_pooled`` — **paper-faithful union pool.**  Candidates
  from all queries are merged into one pool (deduplicated with a static
  bound of Q*K via ``jnp.unique(size=...)``), and every query rescores the
  whole surviving pool each stage, exactly as the reference implementation
  does.  Used by the fidelity benchmarks to validate the per-query variant.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import truncated as T
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def _prefix_sq(index: Optional[Dict[str, Array]], dims: Optional[tuple], dim: int):
    """Static lookup of the precomputed prefix-norm column, if available."""
    if index is None or dims is None:
        return None
    dims = tuple(int(x) for x in dims)
    if int(dim) not in dims:
        return None
    return index["sq_prefix"][:, dims.index(int(dim))]


def rescore_ladder(
    q: Array,
    db: Array,
    cand: Array,
    stages,
    *,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    valid: Optional[Array] = None,
    metric: str = "l2",
    scores: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Chain ``rescore_candidates`` over ``stages`` — the refinement ladder
    every search path shares once it has a candidate table (flat after its
    stage-0 scan, IVF after probing, quantized after the int8 scan).

    ``scores`` is returned unchanged when ``stages`` is empty (degenerate
    single-stage schedules).
    """
    index = {"sq_prefix": sq_prefix} if sq_prefix is not None else None
    for stage in stages:
        scores, cand = T.rescore_candidates(
            q, db, cand,
            dim=stage.dim, k=stage.k,
            db_sq_at_dim=_prefix_sq(index, index_dims, stage.dim),
            valid=valid,
            metric=metric,
        )
    return scores, cand


@functools.partial(
    jax.jit,
    static_argnames=("stages", "index_dims", "metric"),
)
def rescore_ladder_jit(
    q: Array,
    db: Array,
    cand: Array,
    stages,
    *,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    valid: Optional[Array] = None,
    metric: str = "l2",
    scores: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Jitted ``rescore_ladder`` — the second half of a fenced search.

    The fused entry points (`progressive_search` and the IVF / quantized /
    PQ variants) jit stage-0 + ladder as one XLA program.  Observability
    stage fences (``obs.stage_fences``) instead run stage-0 with
    ``stage0_only=True``, ``block_until_ready`` the candidates to timestamp
    the stage-0/rescore boundary, then finish through this program.
    ``stages`` must be a (hashable) tuple of `Stage`.
    """
    return rescore_ladder(
        q, db, cand, stages,
        sq_prefix=sq_prefix, index_dims=index_dims,
        valid=valid, metric=metric, scores=scores,
    )


@functools.partial(
    jax.jit,
    static_argnames=("sched", "index_dims", "block_n", "metric",
                     "stage0_only"),
)
def progressive_search(
    q: Array,
    db: Array,
    sched: ProgressiveSchedule,
    *,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    valid: Optional[Array] = None,
    block_n: int = 65536,
    metric: str = "l2",
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """Per-query progressive search (static shapes; jit/pjit-native).

    Args:
      q:          (Q, D) queries.
      db:         (N, D) documents.
      sched:      static ProgressiveSchedule (hashable; marked static).
      sq_prefix:  optional (N, len(index_dims)) prefix squared norms
                  (``index['sq_prefix']`` from `repro.core.index.build_index`).
      index_dims: static tuple of dims matching sq_prefix's columns.
      valid:      optional (N,) bool row-validity mask (mutable-corpus
                  serving: deleted / unpopulated rows are unreturnable).
      block_n:    document tile for the stage-0 full scan.
      metric:     'l2' or 'cosine'.
      stage0_only: static; return the stage-0 (scores, candidates) without
                  the rescore ladder — the fenced-observability split point
                  (finish via ``rescore_ladder_jit`` on ``stages[1:]``).

    Returns:
      (scores, indices): ((Q, final_k) float32, (Q, final_k) int32).
    """
    index = {"sq_prefix": sq_prefix} if sq_prefix is not None else None

    s0 = sched.stages[0]
    scores, cand = T.truncated_search(
        q, db,
        dim=s0.dim, k=s0.k,
        db_sq_at_dim=_prefix_sq(index, index_dims, s0.dim),
        valid=valid,
        block_n=block_n, metric=metric,
    )
    if stage0_only:
        return scores, cand
    return rescore_ladder(
        q, db, cand, sched.stages[1:],
        sq_prefix=sq_prefix, index_dims=index_dims,
        valid=valid, metric=metric, scores=scores,
    )


@functools.partial(
    jax.jit,
    static_argnames=("sched", "index_dims", "block_n", "metric"),
)
def progressive_search_pooled(
    q: Array,
    db: Array,
    sched: ProgressiveSchedule,
    *,
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    valid: Optional[Array] = None,
    block_n: int = 65536,
    metric: str = "l2",
) -> Tuple[Array, Array]:
    """Paper-faithful pooled progressive search.

    After stage 0, candidates of *all* queries are merged into one
    deduplicated pool ("collected and saved in the same candidate pool, so the
    duplicate neighbors will be removed", §III.D); each later stage rescores
    every query against the whole surviving pool and the per-query top-k
    survivors are re-pooled.  Pool sizes are bounded statically by Q*K_s
    (padded with -1), which keeps shapes compile-time constant.

    Returns:
      (scores, indices): ((Q, final_k) float32, (Q, final_k) int32).
    """
    index = {"sq_prefix": sq_prefix} if sq_prefix is not None else None
    nq = q.shape[0]

    s0 = sched.stages[0]
    _, cand = T.truncated_search(
        q, db,
        dim=s0.dim, k=s0.k,
        db_sq_at_dim=_prefix_sq(index, index_dims, s0.dim),
        valid=valid,
        block_n=block_n, metric=metric,
    )

    def pool_of(per_query_cand: Array, bound: int) -> Array:
        """Dedup a (Q, K) candidate table into a (bound,) padded pool."""
        flat = per_query_cand.reshape(-1)
        pool = jnp.unique(flat, size=bound, fill_value=-1)
        return pool

    scores = None
    for stage in sched.stages[1:]:
        bound = min(nq * stage.pool, db.shape[0])
        pool = pool_of(cand, bound)                       # (bound,)
        # Every query scores the whole pool (the paper's "surviving rows").
        pool_tbl = jnp.broadcast_to(pool[None, :], (nq, bound))
        scores, cand = T.rescore_candidates(
            q, db, pool_tbl,
            dim=stage.dim, k=stage.k,
            db_sq_at_dim=_prefix_sq(index, index_dims, stage.dim),
            valid=valid,
            metric=metric,
        )
    if scores is None:  # degenerate single-stage schedule
        scores, cand = T.rescore_candidates(
            q, db, cand, dim=sched.d_max, k=sched.final_k,
            db_sq_at_dim=_prefix_sq(index, index_dims, sched.d_max),
            valid=valid,
            metric=metric,
        )
    return scores, cand

"""PCA dimensionality reduction — the paper's compared alternative (§II, §III.C).

The paper evaluated PCA against plain truncation and found truncation slightly
better for retrieval accuracy at much lower cost; we implement PCA so the
comparison benchmark (`benchmarks/table2`) can reproduce that finding.

Fit is exact via eigendecomposition of the covariance when D is modest, or via
(blocked) subspace power iteration for large D — both pure JAX, jit-able, and
deterministic given a PRNG key.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class PCAState(NamedTuple):
    mean: Array          # (D,)
    components: Array    # (D, K)  orthonormal columns, sorted by variance desc
    explained_var: Array # (K,)


@functools.partial(jax.jit, static_argnames=("n_components",))
def fit_pca(x: Array, n_components: int) -> PCAState:
    """Exact PCA via eigh on the (D, D) covariance.  O(N·D² + D³)."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    cov = (xc.T @ xc) / (x.shape[0] - 1)
    evals, evecs = jnp.linalg.eigh(cov)           # ascending
    order = jnp.argsort(-evals)[:n_components]
    return PCAState(
        mean=mean,
        components=evecs[:, order],
        explained_var=evals[order],
    )


@functools.partial(
    jax.jit, static_argnames=("n_components", "n_iter", "oversample")
)
def fit_pca_power(
    x: Array, n_components: int, *, n_iter: int = 8, oversample: int = 8,
    key: Array | None = None
) -> PCAState:
    """Subspace (block power) iteration PCA — avoids the D×D eigh for large D.

    Iterates on an oversampled block of K + ``oversample`` columns and
    extracts the top K by Rayleigh–Ritz: the trailing *wanted* component then
    converges at the gap to the (K+p)-th eigenvalue rather than the (K+1)-th,
    which is what makes small-eigengap spectra (trained-embedding tails)
    usable at modest ``n_iter``.  Cost O(n_iter · N · D · (K+p)).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    d = x.shape[1]
    kp = min(d, n_components + oversample)
    v = jax.random.normal(key, (d, kp), jnp.float32)
    v, _ = jnp.linalg.qr(v)

    def body(_, v):
        w = xc.T @ (xc @ v)
        v, _ = jnp.linalg.qr(w)
        return v

    v = jax.lax.fori_loop(0, n_iter, body, v)
    # Rayleigh–Ritz: solve the small (kp, kp) projected eigenproblem and
    # rotate the basis, instead of trusting raw QR columns.
    t = v.T @ (xc.T @ (xc @ v)) / (x.shape[0] - 1)
    evals, w = jnp.linalg.eigh((t + t.T) / 2)      # ascending
    order = jnp.argsort(-evals)[:n_components]
    return PCAState(
        mean=mean,
        components=v @ w[:, order],
        explained_var=evals[order],
    )


@jax.jit
def pca_transform(state: PCAState, x: Array) -> Array:
    """Project ``x`` onto the fitted components: (N, D) -> (N, K)."""
    return (x.astype(jnp.float32) - state.mean) @ state.components


def fit_rotation(db: Array) -> PCAState:
    """Full-rank PCA rotation — the beyond-paper enabler for progressive
    search over *arbitrary* learned embeddings.

    The paper's truncation works because trained text embeddings concentrate
    signal in leading dimensions; embeddings trained without a Matryoshka
    objective (e.g. a fresh two-tower model) spread variance uniformly, and
    truncation-based stages lose recall.  A full-rank orthogonal PCA
    rotation preserves all pairwise L2 distances exactly (so full-dim
    results are unchanged) while reordering variance into the leading dims —
    after which the paper's progressive schedule applies to any embedding.
    Rotate the corpus once at index-build time and each query at search time
    (one (D, D) matmul).
    """
    return fit_pca(db, db.shape[1])


def rotate(state: PCAState, x: Array) -> Array:
    """Apply the distance-preserving rotation (centering included)."""
    return pca_transform(state, x)

"""Static stage schedule for Progressive Retrieval.

The paper (§III.D) parameterizes progressive search by

  * ``initial K``      — neighbours retrieved per query in the first stage,
  * ``starting dim``   — truncated dimensionality of the first (full-DB) scan,
  * ``max dim``        — dimensionality of the final 1-NN pass.

The loop doubles the dimension each stage and halves K (minimum 1) while the
doubled dimension is still below the max dimension; the final stage runs at
the max dimension on the surviving candidates.

Everything about the schedule is *static* (a function of the three parameters
only), which is what makes the whole pipeline jit-able with fixed shapes: XLA
sees one fused program per (schedule, DB shape) pair.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Stage:
    """One stage of progressive search.

    Attributes:
      dim:        number of leading embedding dimensions scored this stage.
      k:          number of candidates kept per query after this stage.
      pool:       number of candidate rows scored this stage (the *input*
                  candidate count; ``-1`` means the whole database).
    """

    dim: int
    k: int
    pool: int


@dataclasses.dataclass(frozen=True)
class ProgressiveSchedule:
    """Fully static description of a progressive search run."""

    stages: Tuple[Stage, ...]
    d_start: int
    d_max: int
    k0: int
    final_k: int

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def describe(self) -> str:
        parts = [
            f"stage{i}[dim={s.dim:>5} pool={'N' if s.pool < 0 else s.pool:>6} -> k={s.k}]"
            for i, s in enumerate(self.stages)
        ]
        return " ; ".join(parts)


def make_schedule(
    d_start: int,
    d_max: int,
    k0: int,
    *,
    final_k: int = 1,
    k_min: int = 1,
) -> ProgressiveSchedule:
    """Build the paper's schedule: dim doubles, K halves (min ``k_min``).

    Stage 0 scans the full database at ``d_start`` dims keeping ``k0``
    candidates per query.  While ``2*dim < d_max`` the dim doubles and K
    halves; the last stage runs at exactly ``d_max`` keeping ``final_k``.

    Args:
      d_start: starting (lowest) dimensionality; must be >= 1.
      d_max:   target dimensionality (the embedding model's output size, or
               any truncation of it); must be >= d_start.
      k0:      initial K for the full-DB scan.
      final_k: neighbours returned by the final stage (paper uses 1).
      k_min:   lower bound on intermediate K (paper uses 1).

    Returns:
      A ProgressiveSchedule whose stages have strictly increasing dims.
    """
    if d_start < 1:
        raise ValueError(f"d_start must be >= 1, got {d_start}")
    if d_max < d_start:
        raise ValueError(f"d_max ({d_max}) must be >= d_start ({d_start})")
    if k0 < max(final_k, 1):
        raise ValueError(f"k0 ({k0}) must be >= final_k ({final_k})")

    stages = [Stage(dim=d_start, k=k0, pool=-1)]
    dim, k = d_start, k0
    if d_max > d_start:
        while dim * 2 < d_max:
            dim *= 2
            # never halve below the final stage's k (keeps ks non-increasing
            # when final_k > 1, e.g. recall@10 serving)
            k = max(k // 2, k_min, final_k)
            stages.append(Stage(dim=dim, k=k, pool=stages[-1].k))
        stages.append(Stage(dim=d_max, k=min(final_k, stages[-1].k),
                            pool=stages[-1].k))
    return ProgressiveSchedule(
        stages=tuple(stages), d_start=d_start, d_max=d_max, k0=k0, final_k=final_k
    )


def validate_schedule(sched: ProgressiveSchedule, n_db: int, d_emb: int) -> None:
    """Raise if a schedule is inconsistent with a database of shape (n_db, d_emb)."""
    if sched.d_max > d_emb:
        raise ValueError(
            f"schedule d_max={sched.d_max} exceeds database dim {d_emb}"
        )
    if sched.k0 > n_db:
        raise ValueError(f"schedule k0={sched.k0} exceeds database size {n_db}")
    dims = [s.dim for s in sched.stages]
    if dims != sorted(dims) or len(set(dims)) != len(dims):
        raise ValueError(f"stage dims must be strictly increasing, got {dims}")
    ks = [s.k for s in sched.stages]
    for a, b in zip(ks, ks[1:]):
        if b > a:
            raise ValueError(f"stage K must be non-increasing, got {ks}")

"""Vector index: the database-side state for truncated / progressive search.

An index holds the document embedding matrix plus precomputed *prefix squared
norms*: ``sq_prefix[n, j] = sum_{i < dims[j]} db[n, i]^2`` for every stage
dimensionality a schedule can touch.  Precomputing these once at build time
moves O(N·D) work out of every query batch — the same role the ``||x||^2``
cache plays in classic matmul-form L2 search.

The index is a pytree (dict of arrays), so it shards transparently under
pjit/shard_map: sharding the leading (document) axis across the ``data`` mesh
axis gives each device a contiguous slab of the corpus.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def stage_dims(sched: ProgressiveSchedule) -> tuple:
    return tuple(s.dim for s in sched.stages)


@functools.partial(jax.jit, static_argnames=("dims",))
def prefix_squared_norms(db: Array, dims: tuple) -> Array:
    """(N, len(dims)) prefix squared norms of ``db`` rows at each dim.

    One cumulative-sum pass gives every prefix norm at once:
    ``cumsq[:, j] = sum_{i<=j} db[:, i]^2``; prefix norm at dim k =
    ``cumsq[:, k-1]``.  Jitted and standalone so mutable-corpus callers
    (`repro.engine`) can compute norms for *appended rows only* instead of
    rebuilding the whole index.
    """
    n, _ = db.shape
    dims = tuple(int(x) for x in dims)
    if not dims:
        return jnp.zeros((n, 0), jnp.float32)
    cumsq = jnp.cumsum(db.astype(jnp.float32) ** 2, axis=1)
    cols = jnp.asarray([k - 1 for k in dims], jnp.int32)
    return cumsq[:, cols]


def build_index(
    db: Array,
    dims: Sequence[int],
    *,
    valid: Optional[Array] = None,
    dtype=jnp.float32,
) -> Dict[str, Array]:
    """Build a search index over ``db`` with prefix norms at each dim in ``dims``.

    Args:
      db:    (N, D) document embeddings.
      dims:  dimensionalities whose prefix squared norms to precompute.  Must
             be sorted ascending; each must be <= D.
      valid: optional (N,) bool row-validity mask (mutable corpora: False rows
             are deleted / unpopulated).  Stored in the index for the caller;
             the search functions take it explicitly — pass
             ``index['valid']`` as the ``valid=`` kwarg of
             ``truncated_search`` / ``progressive_search`` to make masked
             rows unreturnable.  Defaults to all-valid.

    Returns:
      dict with keys:
        'db'        : (N, D) embeddings (cast to ``dtype``)
        'sq_prefix' : (N, len(dims)) prefix squared norms, float32
        'valid'     : (N,) bool row-validity mask
        'dims'      : (len(dims),) int32 — static metadata, kept as an array so
                      the pytree stays jit-friendly.
    """
    db = jnp.asarray(db, dtype)
    n, d = db.shape
    dims = tuple(int(x) for x in dims)
    if list(dims) != sorted(dims):
        raise ValueError(f"dims must be ascending, got {dims}")
    if dims and dims[0] < 1:
        # dim 0 would gather cumsum column -1, silently wrapping to the
        # full-D norm under jit — reject eagerly like the other bounds
        raise ValueError(f"dims must be >= 1, got {dims}")
    if dims and dims[-1] > d:
        raise ValueError(f"max dim {dims[-1]} exceeds embedding dim {d}")
    if valid is None:
        valid = jnp.ones((n,), bool)
    else:
        valid = jnp.asarray(valid, bool)
        if valid.shape != (n,):
            raise ValueError(f"valid mask shape {valid.shape} != ({n},)")

    return {
        "db": db,
        "sq_prefix": prefix_squared_norms(db, dims),
        "valid": valid,
        "dims": jnp.asarray(dims, jnp.int32),
    }


def index_for_schedule(db: Array, sched: ProgressiveSchedule, **kw) -> Dict[str, Array]:
    return build_index(db, stage_dims(sched), **kw)


def prefix_norm_column(index: Dict[str, Array], dim: int, dims: Sequence[int]) -> Array:
    """Return the (N,) prefix squared-norm column for ``dim``.

    ``dims`` is the static tuple the index was built with (the array version in
    the index is device data; static lookup must use the python tuple so the
    column index is a compile-time constant).
    """
    dims = tuple(int(x) for x in dims)
    try:
        j = dims.index(int(dim))
    except ValueError:
        raise KeyError(f"dim {dim} not precomputed; index has {dims}") from None
    return index["sq_prefix"][:, j]

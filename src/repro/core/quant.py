"""Quantized staged index — precision-progressive search (beyond paper).

The paper's insight is that early search stages need only a *cheap sketch*
of each vector (few leading dimensions).  Precision is the same axis:
stage 0 tolerates int8; only the final exact stage needs full precision.
Composing both, the stage-0 scan reads

    N x Ds x 1 byte      (int8 staged block)

versus ``N x D x 4`` for the naive f32 row-major scan — 16-56x less HBM
traffic at the paper's dimensionalities (D/Ds in [4, 28], x4 bytes).
Scores accumulate in int32 on the MXU (int8 inputs), rank-equivalent to the
dequantized distances up to per-dimension scale rounding; the progressive
rescore at full precision absorbs any stage-0 ranking noise exactly the way
it absorbs truncation noise.

    idx = build_quantized_index(db, sched)
    scores, ids = quantized_progressive_search(q, idx, sched)
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import truncated as T
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


# -- shared int8 grid helpers -------------------------------------------------
# The one home for per-dimension symmetric int8 bookkeeping: the quantized
# backend, the fused IVF kernel's member-slab packing, and incremental
# append encoding all share the same grid (fit scale -> encode -> fold the
# query), so the math cannot drift between the XLA and Pallas paths.

def fit_int8_scale(x: Array, mask: Optional[Array] = None) -> Array:
    """Per-dimension symmetric scale: ``amax/127`` over (masked) rows.

    ``mask`` selects the rows the grid is fit on (live corpus rows — dead /
    padding slots would drag the grid toward zero); codes can still be
    emitted for every row afterwards.
    """
    ax = jnp.abs(x.astype(jnp.float32))
    if mask is not None:
        ax = jnp.where(mask[:, None], ax, 0.0)
    amax = jnp.max(ax, axis=0)
    return jnp.maximum(amax, 1e-12) / 127.0


def int8_encode(x: Array, scale: Array) -> Tuple[Array, Array]:
    """Code rows onto an existing grid.

    Returns (codes (N, D) int8, deq_sq (N,) f32) where ``deq_sq`` holds the
    squared norms of the *dequantized* rows — the norm table every int8
    scoring path pairs with the codes.
    """
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scale
    return codes, jnp.sum(deq * deq, axis=-1)


def fold_int8_query(q: Array, scale: Array) -> Array:
    """Fold a query onto the codes' grid for rank-equivalent int8 scoring.

    Distances in the *scaled* space (x_d / s_d) are NOT rank-equivalent to
    true distances, so the query is quantized onto the same grid and the
    per-dim ``s_d^2`` rescale is folded into the query side:
    ``ip = (round(clip(q/s)) * s^2) @ codes^T`` keeps the db operand — the
    side that dominates HBM traffic — int8.
    """
    qq = jnp.clip(jnp.round(q.astype(jnp.float32) / scale), -127, 127)
    return (qq * scale * scale).astype(jnp.float32)


def pad_pow2(a: np.ndarray) -> np.ndarray:
    """Pad axis 0 up to a power of two by repeating the last element.

    Scatter updates are idempotent under repeats (same dest, same value),
    and bounding the batch shape to O(log B) distinct sizes keeps jitted
    append-scatters from retracing on every burst size.
    """
    a = np.asarray(a)
    n = a.shape[0]
    target = 1 << (max(n, 1) - 1).bit_length()
    if target == n:
        return a
    reps = np.ones(n, np.int64)
    reps[-1] = target - n + 1
    return np.repeat(a, reps, axis=0)


# incremental-append scatters, shared by the quantized backend's code block
# and the fused kernels' member-slab packs: on accelerators the target
# buffers are DONATED so XLA updates them in place (absorbing a handful of
# rows must not copy an O(corpus) buffer); CPU has no donation and pays the
# copy, which only matters for interpret-mode validation
_scatter_rows_donate = jax.jit(
    lambda buf, dests, rows: buf.at[dests].set(rows), donate_argnums=(0,))
_scatter_rows_copy = jax.jit(
    lambda buf, dests, rows: buf.at[dests].set(rows))
_scatter_rows2_donate = jax.jit(
    lambda a, b, dests, ra, rb: (a.at[dests].set(ra), b.at[dests].set(rb)),
    donate_argnums=(0, 1))
_scatter_rows2_copy = jax.jit(
    lambda a, b, dests, ra, rb: (a.at[dests].set(ra), b.at[dests].set(rb)))


def scatter_rows(buf: Array, dests: Array, rows: Array) -> Array:
    """Scatter ``rows`` into ``buf[dests]``, in place off-CPU (donation)."""
    fn = (_scatter_rows_copy if jax.default_backend() == "cpu"
          else _scatter_rows_donate)
    return fn(buf, dests, rows)


def scatter_rows2(a: Array, b: Array, dests: Array,
                  ra: Array, rb: Array) -> Tuple[Array, Array]:
    """Paired scatter (codes + their norm table) sharing one dest batch."""
    fn = (_scatter_rows2_copy if jax.default_backend() == "cpu"
          else _scatter_rows2_donate)
    return fn(a, b, dests, ra, rb)


def quantize_per_dim(x: Array, valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Symmetric per-dimension int8 quantization.

    Returns (q (N, D) int8, scale (D,) f32) with x ≈ q * scale.  When a
    ``valid`` row mask is given, the scale is fit on live rows only (dead /
    unpopulated buffer slots would otherwise drag the grid toward zero), but
    codes are still emitted for every row.
    """
    scale = fit_int8_scale(x, valid)
    q, _ = int8_encode(x, scale)
    return q, scale


def build_quantized_index(
    db: Array, sched: ProgressiveSchedule, *, valid: Optional[Array] = None
) -> Dict[str, Array]:
    """Stage-0 int8 block + full-precision corpus + stage-0 squared norms."""
    ds = sched.stages[0].dim
    scale0 = fit_int8_scale(db[:, :ds], valid)
    q0, deq_sq = int8_encode(db[:, :ds], scale0)
    return {
        "db": db,
        "db0_q": q0,                 # (N, Ds) int8
        "scale0": scale0,            # (Ds,) f32
        "sq0": deq_sq,               # (N,) norms of the dequantized block
    }


def _scaled_space_scores(q: Array, idx: Dict[str, Array]) -> Array:
    """Rank-equivalent stage-0 scores computed wholly in scaled int8 space
    (see `fold_int8_query` for why the rescale rides on the query side)."""
    db0_q = idx["db0_q"]
    ds = db0_q.shape[1]
    q_scaled = fold_int8_query(q[:, :ds], idx["scale0"])  # (Q, Ds)
    ip = jax.lax.dot_general(
        q_scaled, db0_q.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return idx["sq0"][None, :] - 2.0 * ip


def quant_rest_stages(sched, *, extra_cand=None, valid=None):
    """Post-stage-0 ladder stages for the quantized / PQ families.

    Mirrors the fused paths' ``rest`` logic so a fenced search
    (``stage0_only=True`` + `rescore_ladder_jit`) refines through exactly
    the stages the fused program would: ``stages[1:]``, except a
    single-stage schedule with injected or masked candidates still needs
    one exact pass so those candidates carry full-precision scores.
    """
    rest = sched.stages[1:]
    if not rest and (extra_cand is not None or valid is not None):
        rest = (sched.stages[0],)
    return rest


@functools.partial(jax.jit, static_argnames=("sched", "metric",
                                             "stage0_only"))
def quantized_progressive_search(
    q: Array, idx: Dict[str, Array], sched: ProgressiveSchedule,
    *, metric: str = "l2",
    db: Optional[Array] = None,
    valid: Optional[Array] = None,
    row_limit: Optional[Array] = None,
    extra_cand: Optional[Array] = None,
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """Progressive search with an int8 stage-0 block.

    Stage 0 ranks with quantized scores; every later stage rescores the
    survivors at full precision, so the final results carry exact distances.

    Mutable-corpus extensions (all optional, used by the engine's
    ``QuantizedProgressiveBackend``):

      db:         rescore buffer when the index's ``db`` snapshot is stale
                  (rows < the snapshot length are append-only identical, so
                  the stage-0 codes stay exact for the rows they cover).
      valid:      (N,) bool row mask over ``db``; invalid rows are scored
                  +inf at stage 0 and at every rescore.
      row_limit:  scalar — rows >= it are excluded from stage-0 ranking
                  (their codes predate them); pair with ``extra_cand`` to
                  keep them reachable.
      extra_cand: (E,) int32 ids injected after stage 0 (-1 padded), rescored
                  at full precision; must be disjoint from stage-0 rows.
    """
    from repro.core.progressive import rescore_ladder

    s0 = sched.stages[0]
    rescore_db = idx["db"] if db is None else db
    scores = _scaled_space_scores(q, idx)
    n0 = scores.shape[1]
    keep = jnp.ones((n0,), bool)
    if valid is not None:
        keep = keep & valid[:n0]
    if row_limit is not None:
        keep = keep & (jnp.arange(n0) < row_limit)
    scores = jnp.where(keep[None, :], scores, jnp.inf)
    neg, cand = jax.lax.top_k(-scores, min(s0.k, n0))
    # fully-masked slots must surface the -1 sentinel, not row 0
    cand = jnp.where(jnp.isfinite(-neg), cand.astype(jnp.int32), -1)
    scores = -neg
    cand = T.inject_candidates(cand, extra_cand)
    if stage0_only:
        # fenced split: injected tail rows ride along unscored — the ladder
        # (`quant_rest_stages` + `rescore_ladder_jit`) scores them exactly
        return scores, cand
    rest = sched.stages[1:]
    if not rest and (extra_cand is not None or valid is not None):
        # single-stage schedule: still need one exact pass so injected /
        # masked candidates carry full-precision scores and ranking
        rest = (s0,)
    return rescore_ladder(
        q, rescore_db, cand, rest,
        valid=valid, metric=metric, scores=scores,
    )

"""Quantized staged index — precision-progressive search (beyond paper).

The paper's insight is that early search stages need only a *cheap sketch*
of each vector (few leading dimensions).  Precision is the same axis:
stage 0 tolerates int8; only the final exact stage needs full precision.
Composing both, the stage-0 scan reads

    N x Ds x 1 byte      (int8 staged block)

versus ``N x D x 4`` for the naive f32 row-major scan — 16-56x less HBM
traffic at the paper's dimensionalities (D/Ds in [4, 28], x4 bytes).
Scores accumulate in int32 on the MXU (int8 inputs), rank-equivalent to the
dequantized distances up to per-dimension scale rounding; the progressive
rescore at full precision absorbs any stage-0 ranking noise exactly the way
it absorbs truncation noise.

    idx = build_quantized_index(db, sched)
    scores, ids = quantized_progressive_search(q, idx, sched)
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import truncated as T
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def quantize_per_dim(x: Array, valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Symmetric per-dimension int8 quantization.

    Returns (q (N, D) int8, scale (D,) f32) with x ≈ q * scale.  When a
    ``valid`` row mask is given, the scale is fit on live rows only (dead /
    unpopulated buffer slots would otherwise drag the grid toward zero), but
    codes are still emitted for every row.
    """
    ax = jnp.abs(x.astype(jnp.float32))
    if valid is not None:
        ax = jnp.where(valid[:, None], ax, 0.0)
    amax = jnp.max(ax, axis=0)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def build_quantized_index(
    db: Array, sched: ProgressiveSchedule, *, valid: Optional[Array] = None
) -> Dict[str, Array]:
    """Stage-0 int8 block + full-precision corpus + stage-0 squared norms."""
    ds = sched.stages[0].dim
    q0, scale0 = quantize_per_dim(db[:, :ds], valid)
    deq_sq = jnp.sum((q0.astype(jnp.float32) * scale0) ** 2, axis=1)
    return {
        "db": db,
        "db0_q": q0,                 # (N, Ds) int8
        "scale0": scale0,            # (Ds,) f32
        "sq0": deq_sq,               # (N,) norms of the dequantized block
    }


def _scaled_space_scores(q: Array, idx: Dict[str, Array]) -> Array:
    """Rank-equivalent stage-0 scores computed wholly in scaled int8 space.

    Distances in the *scaled* space (x_d / s_d) are NOT rank-equivalent to
    true distances, so instead we quantize the query onto the same grid and
    compute int32 inner products of raw int8 codes, then rescale per-dim by
    s_d^2 — folded into the query codes as f32 before the matmul would lose
    the int8 path, so we split: ip = (qq * s^2) @ db0_q^T with the f32
    left operand (still a skinny (Q, Ds) f32 x int8 matmul — the *db* side,
    which dominates traffic, stays int8).
    """
    db0_q = idx["db0_q"]
    s = idx["scale0"]
    ds = db0_q.shape[1]
    qq = jnp.clip(jnp.round(q[:, :ds].astype(jnp.float32) / s), -127, 127)
    q_scaled = (qq * s * s).astype(jnp.float32)         # (Q, Ds)
    ip = jax.lax.dot_general(
        q_scaled, db0_q.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return idx["sq0"][None, :] - 2.0 * ip


@functools.partial(jax.jit, static_argnames=("sched", "metric"))
def quantized_progressive_search(
    q: Array, idx: Dict[str, Array], sched: ProgressiveSchedule,
    *, metric: str = "l2",
    db: Optional[Array] = None,
    valid: Optional[Array] = None,
    row_limit: Optional[Array] = None,
    extra_cand: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Progressive search with an int8 stage-0 block.

    Stage 0 ranks with quantized scores; every later stage rescores the
    survivors at full precision, so the final results carry exact distances.

    Mutable-corpus extensions (all optional, used by the engine's
    ``QuantizedProgressiveBackend``):

      db:         rescore buffer when the index's ``db`` snapshot is stale
                  (rows < the snapshot length are append-only identical, so
                  the stage-0 codes stay exact for the rows they cover).
      valid:      (N,) bool row mask over ``db``; invalid rows are scored
                  +inf at stage 0 and at every rescore.
      row_limit:  scalar — rows >= it are excluded from stage-0 ranking
                  (their codes predate them); pair with ``extra_cand`` to
                  keep them reachable.
      extra_cand: (E,) int32 ids injected after stage 0 (-1 padded), rescored
                  at full precision; must be disjoint from stage-0 rows.
    """
    from repro.core.progressive import rescore_ladder

    s0 = sched.stages[0]
    rescore_db = idx["db"] if db is None else db
    scores = _scaled_space_scores(q, idx)
    n0 = scores.shape[1]
    keep = jnp.ones((n0,), bool)
    if valid is not None:
        keep = keep & valid[:n0]
    if row_limit is not None:
        keep = keep & (jnp.arange(n0) < row_limit)
    scores = jnp.where(keep[None, :], scores, jnp.inf)
    neg, cand = jax.lax.top_k(-scores, min(s0.k, n0))
    # fully-masked slots must surface the -1 sentinel, not row 0
    cand = jnp.where(jnp.isfinite(-neg), cand.astype(jnp.int32), -1)
    scores = -neg
    cand = T.inject_candidates(cand, extra_cand)
    rest = sched.stages[1:]
    if not rest and (extra_cand is not None or valid is not None):
        # single-stage schedule: still need one exact pass so injected /
        # masked candidates carry full-precision scores and ranking
        rest = (s0,)
    return rescore_ladder(
        q, rescore_db, cand, rest,
        valid=valid, metric=metric, scores=scores,
    )

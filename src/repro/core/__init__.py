"""The paper's primary contribution: progressive multi-stage retrieval.

Public API:
  make_schedule / ProgressiveSchedule   — static stage schedules (§III.D)
  truncated_search                      — the paper's baseline (§III.C)
  progressive_search                    — TPU-native per-query variant
  progressive_search_pooled             — paper-faithful pooled variant
  sharded_progressive_search            — corpus-sharded multi-device search
  build_index / index_for_schedule      — prefix-norm index build
  fit_pca / pca_transform               — compared alternative (§II)
  build_ivf / ivf_search                — beyond-paper TPU-native ANN
  top1_accuracy / recall_at_k           — metrics (§III.E)
"""

from repro.core.schedule import (
    ProgressiveSchedule,
    Stage,
    make_schedule,
    validate_schedule,
)
from repro.core.index import (
    build_index,
    index_for_schedule,
    prefix_norm_column,
    prefix_squared_norms,
    stage_dims,
)
from repro.core.truncated import (
    cosine_scores,
    inject_candidates,
    l2_scores,
    rescore_candidates,
    truncated_search,
)
from repro.core.progressive import (
    progressive_search,
    progressive_search_pooled,
    rescore_ladder,
)
from repro.core.distributed import sharded_progressive_search
from repro.core.pca import (PCAState, fit_pca, fit_pca_power, fit_rotation,
                            pca_transform, rotate)
from repro.core.ivf import (
    balanced_assign,
    build_ivf,
    ivf_progressive_search,
    ivf_progressive_search_kernel,
    ivf_progressive_search_sched,
    ivf_search,
    kmeans,
    pack_lists,
)
from repro.core.metrics import overlap_at_k, recall_at_k, top1_accuracy

__all__ = [
    "ProgressiveSchedule", "Stage", "make_schedule", "validate_schedule",
    "build_index", "index_for_schedule", "prefix_norm_column",
    "prefix_squared_norms", "stage_dims",
    "l2_scores", "cosine_scores", "truncated_search", "rescore_candidates",
    "inject_candidates", "rescore_ladder",
    "progressive_search", "progressive_search_pooled",
    "sharded_progressive_search",
    "PCAState", "fit_pca", "fit_pca_power", "fit_rotation", "rotate",
    "pca_transform",
    "balanced_assign", "build_ivf", "ivf_search", "ivf_progressive_search",
    "ivf_progressive_search_sched", "ivf_progressive_search_kernel",
    "kmeans", "pack_lists",
    "top1_accuracy", "recall_at_k", "overlap_at_k",
]

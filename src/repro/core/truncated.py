"""Truncated Retrieval — the paper's baseline (§III.C).

Brute-force exact k-NN over the full database at a truncated dimensionality.
Distances are computed in matmul form so the MXU does the heavy lifting:

    ||q - x||^2 = ||q||^2 - 2 q·x + ||x||^2

``||q||^2`` is constant per query row, so for *ranking* we score
``s = ||x||^2 - 2 q·x`` and only add ``||q||^2`` when the caller asks for true
distances.  The database scan is tiled with ``lax.map`` over document blocks so
the (Q, N) score matrix never materializes at once — the same streaming
structure the Pallas kernel (`repro.kernels.distance_topk`) implements on-chip.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_scores(q: Array, db: Array, db_sq: Optional[Array] = None) -> Array:
    """Rank-equivalent squared-L2 scores: ``||x||^2 - 2 q·x`` (no ||q||^2 term).

    Args:
      q:     (Q, d) queries.
      db:    (N, d) documents.
      db_sq: optional precomputed (N,) squared norms of db rows.

    Returns:
      (Q, N) float32 scores; argmin over axis 1 == exact 1-NN by L2.
    """
    if db_sq is None:
        db_sq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)
    # Accumulate the inner product in f32 regardless of storage dtype.
    ip = jax.lax.dot_general(
        q, db,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return db_sq[None, :] - 2.0 * ip


def cosine_scores(q: Array, db: Array, db_sq: Optional[Array] = None) -> Array:
    """Negated cosine similarity (so lower is better, matching L2 convention)."""
    if db_sq is None:
        db_sq = jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)
    q_n = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    ip = jax.lax.dot_general(
        q_n, db,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return -(ip / jnp.maximum(jnp.sqrt(db_sq)[None, :], 1e-12))


_METRICS = {"l2": l2_scores, "cosine": cosine_scores}


@functools.partial(
    jax.jit, static_argnames=("dim", "k", "block_n", "metric")
)
def truncated_search(
    q: Array,
    db: Array,
    *,
    dim: int,
    k: int = 1,
    db_sq_at_dim: Optional[Array] = None,
    valid: Optional[Array] = None,
    block_n: int = 65536,
    metric: str = "l2",
) -> Tuple[Array, Array]:
    """Exact k-NN over ``db`` truncated to the first ``dim`` dimensions.

    The scan over documents is blocked: each step scores a (Q, block_n) tile
    and folds it into a running per-query top-k, so peak memory is
    O(Q·(k + block_n)) instead of O(Q·N).

    Args:
      q:            (Q, D) queries (D >= dim; only [:, :dim] is used).
      db:           (N, D) documents.
      dim:          truncation dimensionality (static).
      k:            neighbours to return (static).
      db_sq_at_dim: optional (N,) precomputed prefix squared norms at ``dim``
                    (ignored for cosine).
      valid:        optional (N,) bool mask; rows where it is False (deleted
                    or not-yet-populated buffer slots) are scored +inf and can
                    never be returned.  When every scored row is invalid the
                    corresponding index slots are -1.
      block_n:      document tile size (static).
      metric:       'l2' or 'cosine'.

    Returns:
      (scores, indices): ((Q, k) float32, (Q, k) int32), ascending by score.
      L2 scores omit the constant ``||q||^2`` term (rank-equivalent).
    """
    if metric not in _METRICS:
        raise ValueError(f"unknown metric {metric!r}")
    n, _ = db.shape
    qd = q[:, :dim]
    dbd = db[:, :dim]

    n_blocks = max(-(-n // block_n), 1)
    pad = n_blocks * block_n - n

    if pad:
        dbd = jnp.pad(dbd, ((0, pad), (0, 0)))
        if db_sq_at_dim is not None:
            # +inf norms keep padded rows out of every top-k.
            db_sq_at_dim = jnp.pad(
                db_sq_at_dim, (0, pad), constant_values=jnp.inf
            )

    if valid is not None:
        # Additive mask: +inf pushes invalid rows past every real candidate,
        # and past the -1-index sentinels already in the top-k carry (ties at
        # +inf break toward the carry's earlier columns), so a fully-invalid
        # scan yields index -1, never a deleted row.
        bias = jnp.where(valid, 0.0, jnp.inf).astype(jnp.float32)
        if pad:
            bias = jnp.pad(bias, (0, pad), constant_values=jnp.inf)
        bias_blocks = bias.reshape(n_blocks, block_n)
    else:
        bias_blocks = None

    score_fn = _METRICS[metric]

    def scan_block(carry, blk):
        best_s, best_i = carry
        db_blk, sq_blk, base, bias_blk = blk
        s = score_fn(qd, db_blk, sq_blk)  # (Q, block_n)
        if metric == "cosine" and pad:
            # padded rows have zero norm -> score 0; push them to +inf
            in_range = (base + jnp.arange(block_n)) < n
            s = jnp.where(in_range[None, :], s, jnp.inf)
        if bias_blk is not None:
            s = s + bias_blk[None, :]
        idx = base + jnp.arange(block_n, dtype=jnp.int32)[None, :]
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(idx, s.shape)], axis=1)
        top_s, pos = jax.lax.top_k(-cat_s, k)
        new_s = -top_s
        new_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (new_s, new_i), None

    db_blocks = dbd.reshape(n_blocks, block_n, dim)
    if db_sq_at_dim is None and metric == "l2":
        sq_blocks = jnp.sum(
            db_blocks.astype(jnp.float32) ** 2, axis=-1
        )
        if pad:
            row = jnp.arange(n_blocks * block_n).reshape(n_blocks, block_n)
            sq_blocks = jnp.where(row < n, sq_blocks, jnp.inf)
    elif metric == "l2":
        sq_blocks = db_sq_at_dim.reshape(n_blocks, block_n)
    else:
        sq_blocks = jnp.sum(db_blocks.astype(jnp.float32) ** 2, axis=-1)

    bases = (jnp.arange(n_blocks, dtype=jnp.int32) * block_n)
    init = (
        jnp.full((q.shape[0], k), jnp.inf, jnp.float32),
        jnp.full((q.shape[0], k), -1, jnp.int32),
    )
    (best_s, best_i), _ = jax.lax.scan(
        scan_block, init, (db_blocks, sq_blocks, bases, bias_blocks)
    )
    return best_s, best_i


def inject_candidates(cand: Array, extra: Optional[Array]) -> Array:
    """Append a shared (E,) id window to every query's candidate table.

    ``extra`` is -1-padded (scored +inf by ``rescore_candidates``) and must
    be disjoint from ``cand``'s ids so the final top-k carries no
    duplicates; used for the engine's un-indexed tail rows.
    """
    if extra is None:
        return cand
    return jnp.concatenate(
        [cand,
         jnp.broadcast_to(extra[None, :], (cand.shape[0], extra.shape[0]))],
        axis=1,
    )


def rescore_candidates(
    q: Array,
    db: Array,
    cand: Array,
    *,
    dim: int,
    k: int,
    db_sq_at_dim: Optional[Array] = None,
    valid: Optional[Array] = None,
    metric: str = "l2",
) -> Tuple[Array, Array]:
    """Exact k-NN of each query against *its own* candidate rows at ``dim`` dims.

    This is the refinement step of progressive search: gather each query's
    surviving candidate vectors and rescore them at a higher dimensionality.

    Args:
      q:    (Q, D) queries.
      db:   (N, D) documents.
      cand: (Q, C) int32 candidate indices per query (may contain -1 padding;
            padded entries are scored +inf).
      dim:  scoring dimensionality (static).
      k:    candidates kept (static, k <= C).
      valid: optional (N,) bool mask; candidates pointing at invalid rows are
             scored +inf (guards against rows deleted between stages).

    Returns:
      (scores, indices): ((Q, k) float32, (Q, k) int32 — *global* db indices).
    """
    qd = q[:, :dim]
    safe = jnp.maximum(cand, 0)
    gathered = db[safe, :dim]                       # (Q, C, dim)
    ip = jnp.einsum(
        "qd,qcd->qc", qd, gathered, preferred_element_type=jnp.float32
    )
    if metric == "l2":
        if db_sq_at_dim is not None:
            sq = db_sq_at_dim[safe]
        else:
            sq = jnp.sum(gathered.astype(jnp.float32) ** 2, axis=-1)
        s = sq - 2.0 * ip
    else:
        qn = jnp.maximum(jnp.linalg.norm(qd, axis=-1, keepdims=True), 1e-12)
        gn = jnp.maximum(jnp.linalg.norm(gathered, axis=-1), 1e-12)
        s = -(ip / (qn * gn))
    keep = cand >= 0
    if valid is not None:
        keep = keep & valid[safe]
    s = jnp.where(keep, s, jnp.inf)
    top_s, pos = jax.lax.top_k(-s, k)
    idx = jnp.take_along_axis(cand, pos, axis=1)
    # Slots that only ever saw invalid candidates surface as -1, not as a
    # stale (possibly deleted) row id.
    idx = jnp.where(jnp.isfinite(-top_s) | (idx < 0), idx, -1)
    return -top_s, idx

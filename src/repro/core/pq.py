"""Product-quantized stage 0 — the compression frontier past int8.

The paper's insight is that early search stages only need a *cheap sketch*
of each vector.  The repo already exploits the dimensionality axis
(truncated stage 0) and the precision axis (int8 stage 0); product
quantization (Jégou et al., the FAISS IVF-PQ workhorse) pushes the sketch
further: the stage-0 block is split into ``M`` subspaces of ``dsub = Ds/M``
dims, each k-means-quantized to ``C ≤ 256`` centroids, so a row's sketch is
``M`` uint8 codes — **M bytes/row** against ``Ds`` for int8 and ``4·Ds``
for f32.  Queries never decode rows: an **asymmetric-distance (ADC)**
lookup table of the query's distance to every centroid of every subspace
(``(M, C)`` floats, VMEM-resident in the fused kernel) turns scoring a row
into ``M`` table lookups, and the full-precision progressive rescore
absorbs the quantization noise exactly the way it absorbs truncation noise.

Rank-equivalence convention: like every scoring path in this repo, ADC
tables drop the per-query ``‖q‖²`` constant — ``lut[m, c] = ‖c‖² − 2·q_m·c``
— so ADC sums are directly comparable with `truncated.l2_scores` /
`rescore_candidates` outputs and exact tail-window rescores can merge into
a PQ top-k without a unit mismatch.

    idx = build_pq_index(db, sched, m=8)
    scores, ids = pq_progressive_search(q, idx, sched)
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import truncated as T
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def auto_pq_m(d0: int) -> int:
    """Default subspace count for a ``d0``-dim stage-0 block: aim dsub = 8.

    ``d0 // 8`` when that divides evenly (8-dim subspaces quantize well at
    256 codes); otherwise a single subspace — coarse, but the progressive
    rescore runs at full precision either way, and an explicit ``pq_m`` is
    always available.
    """
    if d0 >= 16 and d0 % 8 == 0:
        return d0 // 8
    return 1


def pq_dims(codebooks: Array) -> Tuple[int, int, int]:
    """(M, C, dsub) of a codebook tensor."""
    m, c, dsub = codebooks.shape
    return int(m), int(c), int(dsub)


def pq_cent_sq(codebooks: Array) -> Array:
    """(M, C) squared centroid norms — the ADC tables' constant term."""
    cb = codebooks.astype(jnp.float32)
    return jnp.sum(cb * cb, axis=-1)


@functools.partial(jax.jit, static_argnames=("m", "n_codes", "n_iter"))
def train_pq(
    x: Array, *, m: int, n_codes: int = 256, n_iter: int = 10, key=None
) -> Array:
    """Train PQ codebooks: independent k-means per subspace.

    Args:
      x:       (N, Ds) training rows (live corpus rows; Ds % m == 0).
      m:       subspace count.
      n_codes: centroids per subspace (≤ 256 so codes fit uint8).
      n_iter:  Lloyd iterations.
      key:     PRNG key (init sampling).

    Returns:
      (m, n_codes, Ds//m) float32 codebooks.

    Subspaces are fit sequentially (``lax.map``) so peak memory is one
    (N, n_codes) assignment matrix, not m of them.  When N < n_codes the
    init samples with replacement — duplicate centroids are harmless
    (encoding ties break to the lowest code) and keep every shape static
    across corpus sizes.
    """
    if n_codes > 256:
        raise ValueError(f"n_codes must be <= 256 (uint8 codes), got {n_codes}")
    n, ds = x.shape
    if ds % m:
        raise ValueError(f"stage-0 dim {ds} is not divisible by pq m={m}")
    if key is None:
        key = jax.random.PRNGKey(0)
    dsub = ds // m
    subs = x.astype(jnp.float32).reshape(n, m, dsub).transpose(1, 0, 2)
    keys = jax.random.split(key, m)
    replace = n < n_codes

    def fit(args):
        sub, k = args                                  # (N, dsub)
        init = jax.random.choice(k, n, (n_codes,), replace=replace)
        cents = sub[init]

        def step(c, _):
            s = T.l2_scores(sub, c)                    # (N, n_codes)
            a = jnp.argmin(s, axis=1)
            oh = jax.nn.one_hot(a, n_codes, dtype=jnp.float32)
            counts = oh.sum(axis=0)
            sums = oh.T @ sub
            new = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts, 1.0)[:, None], c)
            return new, None

        cents, _ = jax.lax.scan(step, cents, None, length=n_iter)
        return cents

    return jax.lax.map(fit, (subs, keys))


@jax.jit
def _encode_block(x: Array, codebooks: Array, cent_sq: Array) -> Array:
    m, _, dsub = codebooks.shape
    xs = x.astype(jnp.float32).reshape(x.shape[0], m, dsub)
    ip = jnp.einsum("nmd,mcd->nmc", xs, codebooks,
                    preferred_element_type=jnp.float32)
    s = cent_sq[None, :, :] - 2.0 * ip                 # rank-equivalent
    return jnp.argmin(s, axis=-1).astype(jnp.uint8)


def pq_encode(x: Array, codebooks: Array, *, block_n: int = 8192) -> Array:
    """Encode rows to (N, M) uint8 codes (nearest centroid per subspace).

    Blocked over rows so the (block, M, C) assignment scores never
    materialize for the whole corpus at once (build/absorb time, host loop).
    """
    cent_sq = pq_cent_sq(codebooks)
    n = x.shape[0]
    if n <= block_n:
        return _encode_block(x, codebooks, cent_sq)
    parts = [
        _encode_block(x[lo: lo + block_n], codebooks, cent_sq)
        for lo in range(0, n, block_n)
    ]
    return jnp.concatenate(parts, axis=0)


@jax.jit
def pq_decode(codes: Array, codebooks: Array) -> Array:
    """Reconstruct (N, Ds) float32 rows from (N, M) codes."""
    m = codebooks.shape[0]
    rows = codebooks[jnp.arange(m)[None, :], codes.astype(jnp.int32)]
    return rows.reshape(codes.shape[0], -1)


def pq_lut(q: Array, codebooks: Array, cent_sq: Optional[Array] = None) -> Array:
    """Per-query ADC lookup tables: (Q, M, C) rank-equivalent distances.

    ``lut[q, m, c] = ‖c‖² − 2·q_m·c`` — summing a row's M entries gives the
    rank-equivalent L2 score of the query against that row's
    *reconstruction* (`pq_decode`), exactly (see `pq_adc_scores`).
    """
    m, _, dsub = codebooks.shape
    if cent_sq is None:
        cent_sq = pq_cent_sq(codebooks)
    qs = q.astype(jnp.float32).reshape(q.shape[0], m, dsub)
    ip = jnp.einsum("qmd,mcd->qmc", qs, codebooks,
                    preferred_element_type=jnp.float32)
    return cent_sq[None, :, :] - 2.0 * ip


def pq_adc_scores(lut: Array, codes: Array) -> Array:
    """(Q, N) ADC scores: M table lookups per row, no decode.

    Identity: ``pq_adc_scores(pq_lut(q, cb), codes)`` equals
    ``l2_scores(q, pq_decode(codes, cb))`` up to f32 summation order — the
    property the codec tests pin.
    """
    m = codes.shape[1]
    idx = codes.astype(jnp.int32)
    acc = jnp.take(lut[:, 0, :], idx[:, 0], axis=1)
    for j in range(1, m):
        acc = acc + jnp.take(lut[:, j, :], idx[:, j], axis=1)
    return acc


def build_pq_index(
    db: Array,
    sched: ProgressiveSchedule,
    *,
    m: Optional[int] = None,
    n_codes: int = 256,
    n_iter: int = 10,
    train_rows: int = 65536,
    valid: Optional[Array] = None,
    seed: int = 0,
) -> Dict[str, Array]:
    """Stage-0 PQ code block + full-precision corpus + codebooks.

    Codebooks are fit on (a bounded sample of) live rows only; codes are
    emitted for every buffer row (static shape — dead/unpopulated slots are
    masked at search time).  An all-dead buffer degenerates to codebooks
    fit on zero rows, which is harmless: nothing is returnable anyway.
    """
    ds = sched.stages[0].dim
    m = m or auto_pq_m(ds)
    x = db[:, :ds]
    n = x.shape[0]
    if valid is not None:
        live = np.nonzero(np.asarray(valid[:n]))[0]
    else:
        live = np.arange(n)
    if live.size == 0:
        live = np.arange(min(n, 1))
    rng = np.random.default_rng(seed)
    if live.size > train_rows:
        live = np.sort(rng.choice(live, train_rows, replace=False))
    train = x[jnp.asarray(live)]
    codebooks = train_pq(train, m=m, n_codes=n_codes, n_iter=n_iter,
                         key=jax.random.PRNGKey(seed))
    codes = pq_encode(x, codebooks)
    return {
        "db": db,
        "codes": codes,                   # (N, M) uint8
        "codebooks": codebooks,           # (M, C, dsub) f32
        "cent_sq": pq_cent_sq(codebooks),  # (M, C) f32
    }


def _stage0_ids(codes, valid, row_limit):
    """(N,) int32 ids with every stage-0-unreturnable slot masked to -1."""
    n0 = codes.shape[0]
    ids = jnp.arange(n0, dtype=jnp.int32)
    keep = jnp.ones((n0,), bool)
    if valid is not None:
        keep = keep & valid[:n0]
    if row_limit is not None:
        keep = keep & (jnp.arange(n0) < row_limit)
    return jnp.where(keep, ids, -1)


def _finish(q, rescore_db, sched, scores, cand, *, valid, extra_cand, metric,
            stage0_only=False):
    """Shared post-stage-0 path: tail injection + the rescore ladder."""
    from repro.core.progressive import rescore_ladder

    cand = T.inject_candidates(cand, extra_cand)
    if stage0_only:
        # fenced split: the ladder (`quant_rest_stages` +
        # `rescore_ladder_jit`) scores the injected rows exactly
        return scores, cand
    rest = sched.stages[1:]
    if not rest and (extra_cand is not None or valid is not None):
        # single-stage schedule: still need one exact pass so injected /
        # masked candidates carry full-precision scores and ranking
        rest = (sched.stages[0],)
    return rescore_ladder(
        q, rescore_db, cand, rest,
        valid=valid, metric=metric, scores=scores,
    )


@functools.partial(
    jax.jit, static_argnames=("sched", "metric", "oversample",
                              "stage0_only"))
def pq_progressive_search(
    q: Array, idx: Dict[str, Array], sched: ProgressiveSchedule,
    *, metric: str = "l2",
    db: Optional[Array] = None,
    valid: Optional[Array] = None,
    row_limit: Optional[Array] = None,
    extra_cand: Optional[Array] = None,
    oversample: int = 1,
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """Progressive search with a PQ ADC stage-0 scan (XLA reference).

    Stage 0 ranks every coded row by ADC lookup; every later stage rescores
    the survivors at full precision, so the final results carry exact
    distances.  ``oversample`` widens the stage-0 survivor pool to
    ``oversample × k0`` — the classic PQ remedy for ADC ranking noise
    (widening the cheap stage is nearly free; the full-precision rescore
    cuts the pool back).  The mutable-corpus extensions (``db``/``valid``/
    ``row_limit``/``extra_cand``) mean exactly what they mean for
    `repro.core.quant.quantized_progressive_search`.
    """
    if metric != "l2":
        raise ValueError(
            f"PQ ADC scores are rank-equivalent L2 distances; got "
            f"metric={metric!r}")
    s0 = sched.stages[0]
    rescore_db = idx["db"] if db is None else db
    codes = idx["codes"]
    n0 = codes.shape[0]
    ds = idx["codebooks"].shape[0] * idx["codebooks"].shape[2]
    lut = pq_lut(q[:, :ds], idx["codebooks"], idx["cent_sq"])
    scores = pq_adc_scores(lut, codes)
    ids = _stage0_ids(codes, valid, row_limit)
    scores = jnp.where(ids[None, :] >= 0, scores, jnp.inf)
    neg, cand = jax.lax.top_k(-scores, min(s0.k * oversample, n0))
    # fully-masked slots must surface the -1 sentinel, not row 0
    cand = jnp.where(jnp.isfinite(-neg), cand.astype(jnp.int32), -1)
    return _finish(q, rescore_db, sched, -neg, cand,
                   valid=valid, extra_cand=extra_cand, metric=metric,
                   stage0_only=stage0_only)


@functools.partial(
    jax.jit,
    static_argnames=("sched", "metric", "merge", "block_m", "oversample",
                     "interpret", "stage0_only"))
def pq_progressive_search_kernel(
    q: Array, idx: Dict[str, Array], sched: ProgressiveSchedule,
    *, metric: str = "l2",
    db: Optional[Array] = None,
    valid: Optional[Array] = None,
    row_limit: Optional[Array] = None,
    extra_cand: Optional[Array] = None,
    merge: str = "sort",
    block_m: int = 128,
    oversample: int = 1,
    interpret: bool = False,
    stage0_only: bool = False,
) -> Tuple[Array, Array]:
    """`pq_progressive_search` with the fused Pallas ADC stage-0 kernel.

    Same results (identical top-k id sets — the parity contract
    `tests/test_kernels.py` enforces), but stage 0 runs
    `repro.kernels.pq_scan.pq_scan_topk`: the per-query (M, C) LUT stays
    VMEM-resident while uint8 code slabs stream HBM→VMEM once and the
    running top-k never leaves VMEM.
    """
    from repro.kernels.pq_scan import pq_scan_topk

    if metric != "l2":
        raise ValueError(
            f"PQ ADC scores are rank-equivalent L2 distances; got "
            f"metric={metric!r}")
    s0 = sched.stages[0]
    rescore_db = idx["db"] if db is None else db
    codes = idx["codes"]
    n0 = codes.shape[0]
    ds = idx["codebooks"].shape[0] * idx["codebooks"].shape[2]
    lut = pq_lut(q[:, :ds], idx["codebooks"], idx["cent_sq"])
    ids = _stage0_ids(codes, valid, row_limit)
    scores, cand = pq_scan_topk(
        lut, codes, ids, k=min(s0.k * oversample, n0), block_m=block_m,
        merge=merge, interpret=interpret)
    return _finish(q, rescore_db, sched, scores, cand,
                   valid=valid, extra_cand=extra_cand, metric=metric,
                   stage0_only=stage0_only)

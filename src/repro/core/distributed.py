"""Distributed progressive search over a row-sharded corpus.

At production scale the corpus does not fit one device: the (N, D) embedding
matrix is sharded along the document axis across the ``data`` mesh axis (and,
multi-pod, across ``('pod', 'data')``).  The key observation that makes
progressive search embarrassingly parallel:

    the global top-k of stage 0 is contained in the union of per-shard
    top-k's, and every later stage only *shrinks* each candidate set —

so each shard can run the **entire** progressive pipeline locally on its own
slab and only the final (score, index) pair per query is combined across
shards with a single tiny min-reduction.  Collective traffic is
O(Q · final_k · shards) scalars — effectively free — versus O(N · D) if the
corpus were gathered.  This is the design a 1000-node deployment wants: zero
vector movement, one latency-bounded collective at the end.

Two modes:

* ``mode='local'``  (default) — per-shard full pipeline + final merge, as
  above.  Recall >= single-device progressive search with the same schedule
  (each shard keeps k0 candidates of *its* slab, a superset of the global
  stage-0 top-k0 restricted to that slab).

* ``mode='global'`` — after stage 0, per-shard candidates are all-gathered and
  every shard refines the same global candidate set (paper's semantics across
  the full DB).  Costs one all-gather of (Q, k0) indices+scores per stage but
  gives bit-identical results to the single-device per-query variant.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import truncated as T
from repro.core.progressive import progressive_search
from repro.core.schedule import ProgressiveSchedule

Array = jax.Array


def _merge_final(scores: Array, cand: Array, axis_name: str, global_offset: Array):
    """All-gather per-shard (Q, k) results and take the global top-k."""
    cand_g = jnp.where(cand >= 0, cand + global_offset, -1)
    all_s = jax.lax.all_gather(scores, axis_name, axis=1)   # (Q, S, k)
    all_i = jax.lax.all_gather(cand_g, axis_name, axis=1)   # (Q, S, k)
    q_, s_, k_ = all_s.shape
    flat_s = all_s.reshape(q_, s_ * k_)
    flat_i = all_i.reshape(q_, s_ * k_)
    top, pos = jax.lax.top_k(-flat_s, k_)
    return -top, jnp.take_along_axis(flat_i, pos, axis=1)


def build_sharded_search(
    mesh: jax.sharding.Mesh,
    sched: ProgressiveSchedule,
    n: int,
    *,
    db_axes: Tuple[str, ...] = ("data",),
    has_prefix: bool = False,
    index_dims: Optional[tuple] = None,
    block_n: int = 16384,
    metric: str = "l2",
    mode: str = "local",
):
    """Build the shard_map'd search callable ``fn(q, db, sq_prefix)`` for a
    corpus of ``n`` rows sharded over ``db_axes``.

    Exposed separately from `sharded_progressive_search` so the multi-pod
    dry-run can ``jit(fn).lower(...)`` it directly (the retrieval_cand cell).
    """
    from jax.experimental.shard_map import shard_map
    n_shards = 1
    for a in db_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards:
        raise ValueError(f"corpus rows {n} not divisible by {n_shards} shards")
    rows_local = n // n_shards
    axis_name = db_axes if len(db_axes) > 1 else db_axes[0]

    def local_fn(q_l, db_l, sqp_l):
        if not has_prefix:
            sqp_l = None
        shard_id = jax.lax.axis_index(axis_name)
        offset = (shard_id * rows_local).astype(jnp.int32)
        if mode == "local":
            s, c = progressive_search(
                q_l, db_l, sched,
                sq_prefix=sqp_l, index_dims=index_dims,
                block_n=min(block_n, rows_local), metric=metric,
            )
            return _merge_final(s, c, axis_name, offset)
        # mode == 'global': stage-0 local scan, gather candidates, then each
        # shard rescored only its own rows; others masked +inf, merged per stage.
        s0 = sched.stages[0]
        dims = index_dims
        sqp0 = None
        if sqp_l is not None and dims is not None and s0.dim in dims:
            sqp0 = sqp_l[:, tuple(dims).index(s0.dim)]
        s, c = T.truncated_search(
            q_l, db_l, dim=s0.dim, k=s0.k, db_sq_at_dim=sqp0,
            block_n=min(block_n, rows_local), metric=metric,
        )
        s, c = _merge_final(s, c, axis_name, offset)      # global (Q, k0)
        for stage in sched.stages[1:]:
            local_c = jnp.where(
                (c >= offset) & (c < offset + rows_local), c - offset, -1
            )
            sqp_s = None
            if sqp_l is not None and dims is not None and stage.dim in dims:
                sqp_s = sqp_l[:, tuple(dims).index(stage.dim)]
            s_l, c_l = T.rescore_candidates(
                q_l, db_l, local_c, dim=stage.dim, k=min(stage.k, local_c.shape[1]),
                db_sq_at_dim=sqp_s, metric=metric,
            )
            s, c = _merge_final(s_l, c_l, axis_name, offset)
            s, c = s[:, : stage.k], c[:, : stage.k]
        return s, c

    db_spec = P(axis_name)
    sq_spec = P(axis_name) if has_prefix else P()
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(), db_spec, sq_spec),
        out_specs=(P(), P()),
        check_rep=False,
    )


def build_sharded_search_staged(
    mesh: jax.sharding.Mesh,
    sched: ProgressiveSchedule,
    n: int,
    *,
    db_axes: Tuple[str, ...] = ("data",),
    dtype_wire=jnp.bfloat16,
):
    """Corpus-sharded search over a *staged* index layout.

    Beyond-paper serving optimization (§Perf iteration log): the stage-0 scan
    touches every row but only the first ``Ds`` columns.  With a row-major
    (N, D) corpus the hardware still streams full rows (HBM reads are
    row-granular), so the scan pays N·D bytes for N·Ds of useful data.
    Storing the stage-0 prefix as its own contiguous (N, Ds) block — in bf16,
    scores accumulate in fp32 — cuts stage-0 HBM traffic by (D/Ds)·2x;
    later stages gather full-precision rows from the full-dim block.

    Returns ``fn(q, db0, db, sq_prefix)`` for jit/lowering:
      db0: (N, Ds) ``dtype_wire`` stage-0 block, row-sharded like db.
    """
    from jax.experimental.shard_map import shard_map

    n_shards = 1
    for a in db_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards:
        raise ValueError(f"corpus rows {n} not divisible by {n_shards}")
    rows_local = n // n_shards
    axis_name = db_axes if len(db_axes) > 1 else db_axes[0]
    s0 = sched.stages[0]

    def local_fn(q_l, db0_l, db_l, sqp_l):
        shard_id = jax.lax.axis_index(axis_name)
        offset = (shard_id * rows_local).astype(jnp.int32)
        s, c = T.truncated_search(
            q_l.astype(dtype_wire), db0_l, dim=s0.dim, k=s0.k,
            db_sq_at_dim=sqp_l[:, 0], block_n=rows_local)
        for stage in sched.stages[1:]:
            s, c = T.rescore_candidates(q_l, db_l, c, dim=stage.dim,
                                        k=stage.k)
        return _merge_final(s, c, axis_name, offset)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(), P()),
        check_rep=False,
    )


def sharded_progressive_search(
    mesh: jax.sharding.Mesh,
    q: Array,
    db: Array,
    sched: ProgressiveSchedule,
    *,
    db_axes: Tuple[str, ...] = ("data",),
    sq_prefix: Optional[Array] = None,
    index_dims: Optional[tuple] = None,
    block_n: int = 16384,
    metric: str = "l2",
    mode: str = "local",
) -> Tuple[Array, Array]:
    """Run progressive search with the corpus row-sharded over ``db_axes``.

    Args:
      mesh: device mesh containing ``db_axes``.
      q:    (Q, D) queries — replicated to every shard.
      db:   (N, D) corpus — sharded along axis 0 over ``db_axes``;
            N must divide evenly by the product of those axis sizes.
      sched, sq_prefix, index_dims, block_n, metric: as `progressive_search`.
      mode: 'local' (shard-local pipeline + final merge) or 'global'
            (cross-shard candidate merging after stage 0).

    Returns:
      ((Q, final_k) scores, (Q, final_k) int32 global indices), replicated.
    """
    fn = build_sharded_search(
        mesh, sched, db.shape[0], db_axes=db_axes,
        has_prefix=sq_prefix is not None, index_dims=index_dims,
        block_n=block_n, metric=metric, mode=mode)
    sqp = (sq_prefix if sq_prefix is not None
           else jnp.zeros((db.shape[0], 0), jnp.float32))
    return jax.jit(fn)(q, db, sqp)

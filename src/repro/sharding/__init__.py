from repro.sharding.specs import (
    DEFAULT_RULES,
    NULL_CTX,
    ShardingCtx,
    make_ctx,
)

__all__ = ["ShardingCtx", "NULL_CTX", "DEFAULT_RULES", "make_ctx"]

"""Logical-axis sharding: one rules table maps model-space axis names to mesh
axes; every param/activation carries logical names, and the same model code
lowers on a laptop (no mesh), one pod (16x16 'data' x 'model'), or multi-pod
(2 x 16 x 16 'pod' x 'data' x 'model').

Rules (defaults; shapes may override — e.g. long-context decode moves the
kv sequence axis onto 'data', batch=1 cells clear 'batch'):

    batch    -> ('pod', 'data')   data parallelism (+ pod axis folded in)
    embed    -> ('data',)         FSDP: parameters sharded over data, gathered
                                  per layer by GSPMD (ZeRO-3 equivalent)
    vocab    -> ('model',)        Megatron-style vocab-parallel embed/logits
    heads    -> ('model',)        tensor parallelism over attention heads
    kv_heads -> ('model',)
    mlp      -> ('model',)        tensor parallelism over FFN hidden
    expert   -> ('model',)        expert parallelism (MoE dispatch all-to-all)
    kv_seq   -> ()                decode cache sequence axis (overridden to
                                  ('data',) / ('pod','data') for long-context)
    rows     -> ('data',)         corpus/document axis of retrieval DBs,
                                  embedding-table row sharding
    fields   -> ('model',)        recsys: table-wise parallelism over fields
    nodes/edges -> ('data',)      GNN: graph partitioned over devices

Unknown logical names map to replicated.  An axis rule is dropped when the
mesh lacks that axis or the dimension is not divisible by the axis size —
graceful degradation instead of GSPMD errors on small smoke meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": ("data",),
    "embed_act": (),
    "embed_moe": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "layers": (),
    "kv_seq": (),
    "rows": ("pod", "data"),
    "fields": ("model",),
    "nodes": ("data",),
    "edges": ("pod", "data", "model"),
    "cand": ("data",),
}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Binds a mesh + rules table; translates logical axes to shardings."""

    mesh: Optional[Mesh]
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...]  # hashable rules

    @property
    def rules_dict(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.rules)

    def spec(self, logical: Tuple[Optional[str], ...],
             shape: Optional[Tuple[int, ...]] = None) -> P:
        """PartitionSpec for a tuple of logical axis names (None = replicated).

        If ``shape`` is given, axis rules whose mesh-size doesn't divide the
        dimension are dropped (prevents uneven-shard errors on odd configs).
        """
        if self.mesh is None:
            return P()
        rules = self.rules_dict
        axes_in_mesh = set(self.mesh.axis_names)
        used = set()
        out = []
        for i, name in enumerate(logical):
            if name is None or name not in rules:
                out.append(None)
                continue
            cand = [a for a in rules[name] if a in axes_in_mesh and a not in used]
            if shape is not None and cand:
                keep, size = [], 1
                for a in cand:
                    nsize = size * self.mesh.shape[a]
                    if shape[i] % nsize == 0:
                        keep.append(a)
                        size = nsize
                cand = keep
            if not cand:
                out.append(None)
            elif len(cand) == 1:
                out.append(cand[0])
                used.update(cand)
            else:
                out.append(tuple(cand))
                used.update(cand)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x: Array, logical: Tuple[Optional[str], ...]) -> Array:
        """with_sharding_constraint by logical names (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical, x.shape))
        )

    def tree_shardings(self, logical_tree, param_tree):
        """Match a logical-axes pytree against a param pytree -> shardings.

        ``logical_tree`` mirrors ``param_tree``'s structure with tuples of
        logical names at the leaves (a leaf = tuple of str/None).
        """
        def leaf(log, p):
            return self.sharding(log, tuple(p.shape))

        return jax.tree.map(
            leaf, logical_tree, param_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )


NULL_CTX = ShardingCtx(mesh=None, rules=tuple(DEFAULT_RULES.items()))


def make_ctx(mesh: Optional[Mesh], overrides: Optional[Dict[str, Tuple[str, ...]]] = None) -> ShardingCtx:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return ShardingCtx(mesh=mesh, rules=tuple(sorted(rules.items())))

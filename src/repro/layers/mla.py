"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Keys/values are compressed through a shared low-rank latent ``c_kv`` (rank
``kv_lora_rank``) plus a small decoupled-RoPE key shared across heads.  The
decode path caches only (c_kv, k_rope) — the famous ~1/60 KV-cache shrink —
and uses the *absorbed* formulation: the per-head up-projections W_uk / W_uv
are folded into the query / output projections so attention runs directly in
the latent space:

    score_h ∝ (W_uk_hᵀ q_nope_h) · c_kv  +  q_rope_h · k_rope
    out_h    = W_uv_h (softmax · c_kv)

Training materializes per-head k/v (standard formulation) — cheaper when
Sq == Skv and fully shardable over heads.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.layers.attention import chunked_attention, dense_attention
from repro.layers.common import dense_init, rmsnorm
from repro.layers.rope import apply_rope

Array = jax.Array

_NEG_INF = -1e30


def mla_init(key, d_model: int, n_heads: int, cfg: MLAConfig, dtype):
    ks = jax.random.split(key, 8)
    h = n_heads
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), jnp.float32)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora_rank, h * (cfg.d_nope + cfg.d_rope), dtype)
    else:
        p["wq"] = dense_init(ks[0], d_model, h * (cfg.d_nope + cfg.d_rope), dtype)
    p["wkv_a"] = dense_init(ks[2], d_model, cfg.kv_lora_rank + cfg.d_rope, dtype)
    p["kv_norm"] = jnp.zeros((cfg.kv_lora_rank,), jnp.float32)
    p["wkv_b"] = dense_init(ks[3], cfg.kv_lora_rank, h * (cfg.d_nope + cfg.d_v), dtype)
    p["wo"] = dense_init(ks[4], h * cfg.d_v, d_model, dtype,
                         scale=(h * cfg.d_v) ** -0.5)
    return p


def mla_specs(cfg: MLAConfig):
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = ("embed", None)
        p["q_norm"] = (None,)
        p["wq_b"] = (None, "heads")
    else:
        p["wq"] = ("embed", "heads")
    p["wkv_a"] = ("embed", None)
    p["kv_norm"] = (None,)
    p["wkv_b"] = (None, "heads")
    p["wo"] = ("heads", "embed")
    return p


def _project_q(p, x, n_heads, cfg: MLAConfig):
    b, s, _ = x.shape
    if cfg.q_lora_rank:
        ql = rmsnorm(x @ p["wq_a"], p["q_norm"])
        q = ql @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, n_heads, cfg.d_nope + cfg.d_rope).transpose(0, 2, 1, 3)
    return q[..., : cfg.d_nope], q[..., cfg.d_nope:]        # nope, rope parts


def mla_forward(
    p, x, *, n_heads: int, cfg: MLAConfig, rope_theta: float = 10000.0,
    positions: Optional[Array] = None, impl: str = "chunked",
    constrain=lambda a, names: a,
) -> Array:
    """Training / prefill MLA.  x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _project_q(p, x, n_heads, cfg)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    kv_a = x @ p["wkv_a"]                                    # (B,S,rank+d_rope)
    c_kv = rmsnorm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., cfg.kv_lora_rank:]                    # (B,S,d_rope)
    k_rope = apply_rope(k_rope[:, None], positions, rope_theta)  # (B,1,S,d_rope)

    kv = (c_kv @ p["wkv_b"]).reshape(b, s, n_heads, cfg.d_nope + cfg.d_v)
    kv = kv.transpose(0, 2, 1, 3)
    k_nope, v = kv[..., : cfg.d_nope], kv[..., cfg.d_nope:]

    k_rope_b = jnp.broadcast_to(k_rope, (b, n_heads, s, cfg.d_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pin per-head layouts so GSPMD keeps attention tiles device-local
    # (same fix as mha_forward; §Perf iteration log)
    q = constrain(q, ("batch", "heads", None, None))
    k = constrain(k, ("batch", "heads", None, None))
    v = constrain(v, ("batch", "heads", None, None))
    # scale uses the full qk dim (nope+rope), matching DeepSeek
    if impl == "chunked":
        from repro.layers.attention import _dryrun_attn_opts
        unroll, bq, bk = _dryrun_attn_opts()
        o = chunked_attention(q, k, v, causal=True, window=0,
                              block_q=bq, block_k=bk, unroll=unroll)
    else:
        o = dense_attention(q, k, v, causal=True, window=0)
    o = constrain(o, ("batch", "heads", None, None))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * cfg.d_v)
    return o @ p["wo"]


def mla_decode(
    p, x, ckv_cache, krope_cache, *, pos, n_heads: int, cfg: MLAConfig,
    rope_theta: float = 10000.0,
) -> Tuple[Array, Array, Array]:
    """Absorbed-matmul decode.  x: (B, 1, D).

    ckv_cache:   (B, S, kv_lora_rank)
    krope_cache: (B, S, d_rope)
    Returns (out (B,1,D), ckv_cache', krope_cache').
    """
    b = x.shape[0]
    rank = cfg.kv_lora_rank
    posv = jnp.asarray(pos)[None]

    q_nope, q_rope = _project_q(p, x, n_heads, cfg)          # (B,H,1,*)
    q_rope = apply_rope(q_rope, posv, rope_theta)

    kv_a = x @ p["wkv_a"]                                    # (B,1,rank+d_rope)
    c_kv_new = rmsnorm(kv_a[..., :rank], p["kv_norm"])
    k_rope_new = apply_rope(kv_a[:, None, :, rank:], posv, rope_theta)[:, 0]

    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv_new.astype(ckv_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope_new.astype(krope_cache.dtype), pos, axis=1)

    # absorb W_uk into q:  q_lat[b,h,r] = sum_n q_nope[b,h,n] * W_uk[r,h,n]
    wkv_b = p["wkv_b"].reshape(rank, n_heads, cfg.d_nope + cfg.d_v)
    w_uk = wkv_b[..., : cfg.d_nope]                          # (rank,H,d_nope)
    w_uv = wkv_b[..., cfg.d_nope:]                           # (rank,H,d_v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0], w_uk)

    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0], krope_cache,
                        preferred_element_type=jnp.float32)
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    logits = (s_lat + s_rope) * scale
    k_pos = jnp.arange(ckv_cache.shape[1])
    logits = jnp.where((k_pos <= pos)[None, None], logits, _NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)

    o_lat = jnp.einsum("bhs,bsr->bhr", attn.astype(ckv_cache.dtype), ckv_cache,
                       preferred_element_type=jnp.float32)   # (B,H,rank)
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(x.dtype), w_uv)
    o = o.reshape(b, 1, n_heads * cfg.d_v)
    return o @ p["wo"], ckv_cache, krope_cache

"""GQA attention: dense, chunked (flash-equivalent jnp), and decode paths.

The chunked implementation is the memory-safe lowering used by the multi-pod
dry-run: an online-softmax scan over kv blocks, so peak memory is
O(bq · bk) per (batch, head) instead of O(S²).  It is bit-compatible (up to
fp reassociation) with `repro.kernels.flash_attention`, which replaces it on
real TPU.

``window`` may be a *traced* scalar (0 = full attention): the gemma3
local/global 5:1 pattern passes per-layer windows as scan xs so a single
stacked-layer scan serves both layer kinds.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.common import dense_init
from repro.layers.rope import apply_rope

Array = jax.Array

_NEG_INF = -1e30


def _dryrun_attn_opts():
    """Dry-run cost-accounting knobs (read per call, set by launch/costs.py):
    unrolled tiles so XLA's static cost analysis sees every FLOP, and coarser
    tiles to keep the unrolled HLO small."""
    import os
    unroll = os.environ.get("REPRO_DRYRUN_UNROLL", "0") == "1"
    bq = int(os.environ.get("REPRO_ATTN_BLOCK_Q", "512"))
    bk = int(os.environ.get("REPRO_ATTN_BLOCK_K", "1024"))
    return unroll, bq, bk


# ---------------------------------------------------------------- params --

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype,
                         scale=(n_heads * d_head) ** -0.5),
    }


def attn_specs():
    return {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }


# ------------------------------------------------------------ mask math --

def _mask(q_pos, k_pos, window, causal: bool):
    m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        m &= k_pos <= q_pos
    # traced window: 0 disables
    m &= (k_pos > q_pos - window) | (window <= 0)
    return m


# --------------------------------------------------------------- dense ---

def dense_attention(q, k, v, *, causal: bool, window, q_offset=0) -> Array:
    """Reference attention; q (B,H,Sq,Dh), k/v (B,Hkv,Skv,Dh)."""
    b, h, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * dh**-0.5
    q_pos = jnp.arange(sq)[:, None] + (skv - sq) + q_offset
    k_pos = jnp.arange(skv)[None, :]
    m = _mask(q_pos, k_pos, window, causal)
    s = jnp.where(m[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# -------------------------------------------------------------- chunked --

def chunked_attention(
    q, k, v, *, causal: bool, window, block_q: int = 512, block_k: int = 1024,
    unroll: bool = False,
) -> Array:
    """Online-softmax attention, O(bq·bk) score memory.  Shapes as dense.

    q/k share their head dim; v may differ (MLA: d_nope+d_rope vs d_v).
    """
    b, h, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = h // hkv

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = -sq % bq, -skv % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sqp, skp = q.shape[2], k.shape[2]
    nq, nk = sqp // bq, skp // bk

    # (B, Hkv, rep, nq, bq, dh): group q heads by their kv head
    qg = q.reshape(b, hkv, rep, sqp, dh).reshape(b, hkv, rep, nq, bq, dh)
    kg = k.reshape(b, hkv, nk, bk, dh)
    vg = v.reshape(b, hkv, nk, bk, dv)
    scale = dh**-0.5
    offset = skv - sq

    def q_block(iq, q_blk):
        # q_blk: (b, hkv, rep, bq, dh)
        q_pos = iq * bq + jnp.arange(bq)[:, None] + offset

        def kv_step(carry, j):
            m_i, l_i, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(kg, j, 2, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vg, j, 2, keepdims=False)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            k_pos = j * bk + jnp.arange(bk)[None, :]
            msk = _mask(q_pos, k_pos, window, causal) & (k_pos < skv)
            s = jnp.where(msk[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1, keepdims=True))
            p = jnp.where(msk[None, None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m_i - m_new)
            l_new = l_i * alpha + p.sum(axis=-1, keepdims=True)
            pv = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * alpha + pv), None

        init = (
            jnp.full((b, hkv, rep, bq, 1), _NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, rep, bq, 1), jnp.float32),
            jnp.zeros((b, hkv, rep, bq, dv), jnp.float32),
        )
        (m_i, l_i, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk),
                                          unroll=True if unroll else 1)
        return (acc / jnp.maximum(l_i, 1e-30)).astype(q.dtype)

    if unroll:
        # static loop: every tile visible in HLO (exact cost accounting for
        # the dry-run roofline — XLA counts while-loop bodies once).
        out = jnp.stack([q_block(jnp.int32(i), qg[:, :, :, i])
                         for i in range(nq)])
    else:
        out = jax.lax.map(
            lambda args: q_block(*args),
            (jnp.arange(nq), jnp.moveaxis(qg, 3, 0)),
        )                                              # (nq, b, hkv, rep, bq, dv)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, rep, sqp, dv)
    out = out.reshape(b, h, sqp, dv)
    return out[:, :, :sq]


# --------------------------------------------------------------- decode --

def decode_attention(q, k_cache, v_cache, *, pos, window, ring: bool = False) -> Array:
    """Single-token decode: q (B,H,1,Dh) vs cache (B,Hkv,S,Dh).

    ``pos`` is the (traced) index of the current token; cache entries at
    positions > pos are masked.  Window semantics match training.

    ``ring=True`` treats the cache as a circular buffer of the last
    ``cache_len`` tokens (local/sliding-window layers keep a window-sized
    cache; the buffer *is* the window, so only the unfilled-prefix mask
    applies).
    """
    b, h, _, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    qg = q.reshape(b, hkv, rep, dh)
    logits = jnp.einsum("bgrd,bgsd->bgrs", qg, k_cache,
                        preferred_element_type=jnp.float32) * dh**-0.5
    k_pos = jnp.arange(s)
    if ring:
        msk = (k_pos <= pos) | (pos >= s)
    else:
        msk = (k_pos <= pos) & ((k_pos > pos - window) | (window <= 0))
    logits = jnp.where(msk[None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, 1, dh).astype(q.dtype)


# ------------------------------------------------------------- wiring ----

def mha_forward(
    p, x, *, n_heads: int, n_kv_heads: int, d_head: int,
    causal: bool = True, window=0, rope_theta: float = 10000.0,
    positions: Optional[Array] = None, impl: str = "chunked",
    return_kv: bool = False, constrain=lambda a, names: a,
):
    """Full-sequence attention block (training / prefill).

    x: (B, S, D).  Returns (B, S, D) and optionally the rotated (k, v) for
    cache construction during prefill.

    ``constrain`` pins q/k/v to head-sharded layouts so GSPMD keeps the
    attention tiles device-local under sequence-parallel residuals.
    """
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q = (x @ p["wq"]).reshape(b, s, n_heads, d_head)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, d_head)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, d_head)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, rope_theta)
    v = v.transpose(0, 2, 1, 3)
    # Sequence-length-adaptive SP attention scheme (§Perf iterations 3/6):
    #  * short sequences (train_4k): KV-replicated — q keeps its sequence
    #    shard, only k/v replicate across 'model' (bf16, 2.5-5x fewer bytes
    #    than resharding the f32 residual);
    #  * long sequences (32k prefill): replicating k/v costs S·2·Hkv·dh per
    #    device and GSPMD then keeps whole layers replicated (measured 8-11x
    #    flop inflation) — head-sharded q/k/v tiles are right there.
    if s >= 16384:
        q = constrain(q, ("batch", "heads", None, None))
        k = constrain(k, ("batch", "kv_heads", None, None))
        v = constrain(v, ("batch", "kv_heads", None, None))
    else:
        q = constrain(q, ("batch", None, "seq_act", None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    if impl == "dense":
        o = dense_attention(q, k, v, causal=causal, window=window)
    else:
        unroll, bq, bk = _dryrun_attn_opts()
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, unroll=unroll)
    if s >= 16384:
        o = constrain(o, ("batch", "heads", None, None))
    else:
        o = constrain(o, ("batch", None, "seq_act", None))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def mha_decode(
    p, x, k_cache, v_cache, *, pos, n_heads: int, n_kv_heads: int,
    d_head: int, window=0, rope_theta: float = 10000.0, ring: bool = False,
):
    """One-token decode step.  x: (B, 1, D); caches (B, Hkv, S, Dh).

    ``ring=True``: the cache holds only the last ``S`` tokens (sliding-window
    layer); the new kv is written at ``pos % S``.

    Returns (out (B,1,D), k_cache', v_cache').
    """
    b, _, d = x.shape
    q = (x @ p["wq"]).reshape(b, 1, n_heads, d_head).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, 1, n_kv_heads, d_head).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, 1, n_kv_heads, d_head).transpose(0, 2, 1, 3)
    posv = jnp.asarray(pos)[None]
    q = apply_rope(q, posv, rope_theta)
    k = apply_rope(k, posv, rope_theta)
    slot = jax.lax.rem(pos, k_cache.shape[2]) if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=2)
    o = decode_attention(q, k_cache, v_cache, pos=pos, window=0 if ring else window, ring=ring)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, n_heads * d_head)
    return o @ p["wo"], k_cache, v_cache

"""Rotary position embeddings (RoPE), plain and decoupled (MLA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(d: int, theta: float) -> Array:
    """(d/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate the last dim of ``x`` by position.

    Args:
      x:         (..., S, D) with D even (pairs (x[2i], x[2i+1]) rotated).
      positions: (S,) or broadcastable to x's S axis.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)

"""Top-k routed Mixture-of-Experts with capacity-based dispatch.

Dispatch is the sort-and-pack scheme (MegaBlocks-adjacent, XLA-expressible):
token→expert assignments are sorted by expert id, each token takes a rank
within its expert, tokens past the static capacity are dropped, and expert
FFNs run as one batched einsum over the (E, C, D) packed buffer.

Two execution paths share the dispatch math (`_dispatch_local`):
  * single-device / decode: plain GSPMD lowering (tiny permutation tensors);
  * train/prefill on a mesh: `moe_apply_ep` — shard_map with an explicit
    all_to_all over the 'model' axis.  GSPMD-auto lowering of the global
    sort was measured at 52 TB/device/step of replicated-scatter all-reduce
    on qwen3 train_4k; the explicit EP exchange is 96x cheaper
    (EXPERIMENTS.md §Perf cell 1).

Supports DeepSeek-style shared experts (always-on dense branch) and
normalized top-k gates (DeepSeek-V2 / Qwen3 convention).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.layers.common import dense_init, ffn_apply, ffn_init, ffn_specs

Array = jax.Array


def moe_init(key, d_model: int, cfg: MoEConfig, ffn_type: str, dtype):
    ks = jax.random.split(key, 8)
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d_model, e, jnp.float32),  # router in fp32
        "w_in": (jax.random.truncated_normal(ks[1], -3, 3, (e, d_model, f), jnp.float32)
                 * d_model**-0.5).astype(dtype),
        "w_out": (jax.random.truncated_normal(ks[2], -3, 3, (e, f, d_model), jnp.float32)
                  * f**-0.5).astype(dtype),
    }
    if ffn_type == "swiglu":
        p["w_gate"] = (jax.random.truncated_normal(ks[3], -3, 3, (e, d_model, f), jnp.float32)
                       * d_model**-0.5).astype(dtype)
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], d_model, cfg.d_ff_shared * cfg.n_shared_experts,
                               ffn_type, dtype)
    return p


def moe_specs(cfg: MoEConfig, ffn_type: str):
    p = {
        # router replicated: tiny, and the EP shard_map path needs full-D
        # logits locally
        "router": (None, None),
        "w_in": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    if ffn_type == "swiglu":
        p["w_gate"] = ("expert", "embed", "mlp")
    if cfg.n_shared_experts:
        p["shared"] = ffn_specs(ffn_type)
    return p


def _dispatch_local(x2, logits, cfg: MoEConfig):
    """Sort-and-pack capacity dispatch over a *local* token slab.

    Returns (buf (E, C, D), combine info) — pure function of local data,
    reused by both the single-device path and the shard_map EP path.
    """
    t, d = x2.shape
    e, k = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)
    if cfg.router_norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(experts, e, dtype=jnp.float32).sum(axis=1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)

    cap = min(max(int(t * k / e * cfg.capacity_factor), 4), t)
    e_flat = experts.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gate_flat = gate_vals.reshape(-1)

    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = tok_of[order]
    gate_sorted = gate_flat[order]
    first_of = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - first_of[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)

    buf = jnp.zeros((e, cap + 1, d), x2.dtype)
    buf = buf.at[e_sorted, slot].set(x2[tok_sorted], mode="drop")
    buf = buf[:, :cap]
    info = (e_sorted, slot, tok_sorted, gate_sorted, keep, cap)
    return buf, info, frac_tokens, frac_probs


def _combine_local(y_buf, info, t, d):
    e_sorted, slot, tok_sorted, gate_sorted, keep, cap = info
    y_pairs = y_buf[e_sorted, jnp.minimum(slot, cap - 1)]
    y_pairs = jnp.where(keep[:, None], y_pairs, 0.0)
    y_pairs = y_pairs * gate_sorted[:, None].astype(y_pairs.dtype)
    return jnp.zeros((t, d), y_buf.dtype).at[tok_sorted].add(y_pairs)


def moe_apply_ep(p, x: Array, cfg: MoEConfig, ffn_type: str, ctx) -> Tuple[Array, Array]:
    """Expert-parallel MoE via shard_map: the production train/prefill path.

    Naive GSPMD lowering of sort-and-pack dispatch materializes the global
    (T·k, D) permutation tensors *replicated* and all-reduces them —
    measured 52 TB/device/step on qwen3 train_4k.  This path makes the
    communication explicit and minimal:

      1. each device dispatches its local token slab into a local
         (E, C_local, D) buffer (pure local compute),
      2. one ``all_to_all`` over the 'model' axis turns it into
         (E/ep, C_local·ep, D) — every device now holds all tokens routed
         to *its* experts (the canonical EP exchange, bf16 on the wire),
      3. expert FFNs run locally (weights FSDP-gathered over 'data' —
         the per-layer ZeRO-3 gather, unavoidable at this memory budget),
      4. the reverse ``all_to_all`` + local combine scatter gates results
         back to token order.

    Per-device wire bytes: 2 · E·C_local·D ≈ 2 · T_local·k·cf·D — the
    information-theoretic EP dispatch volume.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    ep = mesh.shape["model"]
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    assert e % ep == 0, (e, ep)

    x_spec = ctx.spec(("batch", "seq_act", None), x.shape)
    w_spec = {
        "router": P(),
        "w_in": ctx.spec(("expert", "embed", None), p["w_in"].shape),
        "w_out": ctx.spec(("expert", None, "embed"), p["w_out"].shape),
    }
    if ffn_type == "swiglu":
        w_spec["w_gate"] = w_spec["w_in"]
    routed = {kk: p[kk] for kk in w_spec}

    # mesh axes the token slab is split over (for the aux-loss mean)
    token_axes = tuple(a for a in ("pod", "data", "model")
                       if a in mesh.axis_names)

    def local_fn(x_l, w):
        bl, sl, _ = x_l.shape
        x2 = x_l.reshape(-1, d)
        t_l = x2.shape[0]
        logits = x2.astype(jnp.float32) @ w["router"]
        buf, info, frac_t, frac_p = _dispatch_local(x2, logits, cfg)
        aux_local = cfg.aux_loss_coef * e * jnp.sum(frac_t * frac_p)
        aux = jax.lax.pmean(aux_local, token_axes)

        # EP exchange: (E, C_l, D) -> (E/ep, C_l*ep, D), bf16 on the wire
        buf = jax.lax.all_to_all(buf.astype(jnp.bfloat16), "model",
                                 split_axis=0, concat_axis=1, tiled=True)
        buf = buf.astype(x2.dtype)

        # FSDP gather of this layer's local-expert weights over 'data'
        w_in = jax.lax.all_gather(w["w_in"], "data", axis=1, tiled=True)
        w_out = jax.lax.all_gather(w["w_out"], "data", axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in)
        if ffn_type == "swiglu":
            w_g = jax.lax.all_gather(w["w_gate"], "data", axis=1, tiled=True)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_g)) * h
        else:
            h = jax.nn.gelu(h)
        y_buf = jnp.einsum("ecf,efd->ecd", h, w_out)

        # reverse exchange + local combine
        y_buf = jax.lax.all_to_all(y_buf.astype(jnp.bfloat16), "model",
                                   split_axis=1, concat_axis=0, tiled=True)
        y = _combine_local(y_buf.astype(x2.dtype), info, t_l, d)
        return y.reshape(bl, sl, d), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, w_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    y, aux = fn(x, routed)

    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x, ffn_type)
    return y, aux


def moe_apply(
    p, x: Array, cfg: MoEConfig, ffn_type: str,
    *, constrain=lambda a, names: a, ctx=None,
) -> Tuple[Array, Array]:
    """Apply the MoE FFN.  x: (B, S, D) or (T, D).

    ``constrain(array, logical_axes)`` lets the caller inject
    with_sharding_constraint at the dispatch boundary (expert parallelism).
    When ``ctx`` carries a mesh with a 'model' axis and the batch is a
    training/prefill slab (seq > 1), dispatch goes through the shard_map
    EP path (`moe_apply_ep`); single-token decode keeps the GSPMD path
    (tiny permutation tensors, no benefit from explicit collectives).

    Returns (output matching x's shape, aux load-balancing loss scalar).
    """
    if (ctx is not None and getattr(ctx, "mesh", None) is not None
            and "model" in ctx.mesh.axis_names
            and cfg.n_experts % ctx.mesh.shape["model"] == 0
            and x.ndim == 3 and x.shape[1] > 1):
        return moe_apply_ep(p, x, cfg, ffn_type, ctx)
    shape_in = x.shape
    d = shape_in[-1]
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    e = cfg.n_experts

    logits = x2.astype(jnp.float32) @ p["router"]           # (T, E)
    buf, info, frac_tokens, frac_probs = _dispatch_local(x2, logits, cfg)
    aux = cfg.aux_loss_coef * e * jnp.sum(frac_tokens * frac_probs)
    buf = constrain(buf, ("expert", None, "embed_moe"))

    # ---- expert FFN over the packed buffer ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if ffn_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    y_buf = constrain(y_buf, ("expert", None, "embed_moe"))

    # ---- combine: gather back and weighted scatter-add to tokens ----
    y = _combine_local(y_buf, info, t, d)

    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x2, ffn_type)

    return y.reshape(shape_in), aux

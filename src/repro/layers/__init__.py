"""Shared neural-net layers (pure JAX; params are plain pytrees)."""

"""Core layers: norms, dense projections, FFN variants, initializers.

Params are nested dicts of jnp arrays.  Every init function has a matching
``*_specs`` twin returning a pytree of *logical axis tuples* with identical
structure — `repro.sharding.specs` maps logical names to mesh axes.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init ----

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    if scale is None:
        scale = d_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.truncated_normal(key, -3, 3, (vocab, d), jnp.float32)
            ).astype(dtype)


# --------------------------------------------------------------- norms ----

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """RMSNorm in fp32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


# ----------------------------------------------------------------- FFN ----

def ffn_init(key, d_model: int, d_ff: int, ffn_type: str, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d_model, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if ffn_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn_specs(ffn_type: str):
    p = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}
    if ffn_type == "swiglu":
        p["w_gate"] = ("embed", "mlp")
    return p


def ffn_apply(p, x: Array, ffn_type: str) -> Array:
    h = x @ p["w_in"]
    if ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_out"]


# ----------------------------------------------------------------- MLP ----

def mlp_init(key, dims: Tuple[int, ...], dtype, *, bias: bool = True):
    """Plain MLP tower: dims = (d_in, h1, ..., d_out)."""
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, (a, b) in enumerate(zip(dims, dims[1:])):
        layer = {"w": dense_init(ks[i], a, b, dtype)}
        if bias:
            layer["b"] = jnp.zeros((b,), dtype)
        layers.append(layer)
    return layers


def mlp_specs(dims: Tuple[int, ...], *, bias: bool = True):
    out = []
    for _ in range(len(dims) - 1):
        layer = {"w": ("embed", "mlp")}
        if bias:
            layer["b"] = ("mlp",)
        out.append(layer)
    return out


def mlp_apply(layers, x: Array, *, act=jax.nn.relu, final_act: bool = False) -> Array:
    n = len(layers)
    for i, l in enumerate(layers):
        x = x @ l["w"]
        if "b" in l:
            x = x + l["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ------------------------------------------------------------- losses ----

def softmax_xent(logits: Array, labels: Array, *, z_loss: float = 0.0):
    """Token cross-entropy in fp32 with optional z-loss; labels -100 ignored.

    Returns (mean_loss, n_valid_tokens).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    nll = jnp.where(valid, nll, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, n

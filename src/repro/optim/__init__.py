from repro.optim.adamw import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    opt_state_logical,
)

__all__ = [
    "OptState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "cosine_schedule", "opt_state_logical",
]

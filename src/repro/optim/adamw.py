"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX pytrees).

Optimizer moments are fp32 regardless of (bf16) param dtype — the standard
mixed-precision recipe; `opt_state_logical` mirrors the params' logical axes
so moments shard identically to their parameters (ZeRO-style: with the
``embed -> data`` FSDP rule the whole optimizer state is sharded, nothing is
replicated but norm scales).

Distributed-optimization hooks:
  * ``grad_dtype='bfloat16'`` — gradients cast before the (GSPMD-inserted)
    data-parallel reduction: 2x less all-reduce traffic (gradient
    compression; stochastic rounding left to XLA).
  * grad accumulation lives in `repro.train.loop.accumulate_grads`.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class OptState(NamedTuple):
    step: Array          # () int32
    mu: object           # pytree like params, fp32
    nu: object           # pytree like params, fp32


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def opt_state_logical(param_logical):
    """Logical axes for OptState given the params' logical tree."""
    return OptState(step=(), mu=param_logical, nu=param_logical)


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    warm = base_lr * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(
    params, grads, state: OptState, *,
    lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, max_grad_norm: float = 1.0,
    grad_dtype: Optional[str] = None,
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if grad_dtype:
        grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1 - b1**step.astype(jnp.float32)
    b2c = 1 - b2**step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * gf * gf
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm}

from repro.data.synth import (
    lm_batch_stream,
    recsys_batch_stream,
    synthetic_markov_lm,
)

__all__ = ["lm_batch_stream", "recsys_batch_stream", "synthetic_markov_lm"]

"""Synthetic-but-learnable data pipelines.

All generators are host-side numpy (double-buffered by the train loop), with
enough structure that a model's loss demonstrably decreases:

  * LM: order-1 Markov chain over the vocab with Zipf-ish stationary
    distribution — cross-entropy floor is the chain's conditional entropy,
    well below the uniform log V.
  * RecSys: clicks generated from a planted low-rank user x item affinity,
    so CTR models can learn the labels and two-tower recovers the planted
    item geometry.
"""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def synthetic_markov_lm(
    rng: np.random.Generator, vocab: int, *, branching: int = 16
) -> np.ndarray:
    """Sparse row-stochastic transition matrix (vocab, branching) ids+probs."""
    nxt = rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)
    w = rng.dirichlet(np.ones(branching) * 0.5, size=vocab).astype(np.float32)
    return nxt, w


def lm_batch_stream(
    rng: np.random.Generator, vocab: int, batch: int, seq: int,
    *, branching: int = 16,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'tokens': (batch, seq+1) int32} from a Markov chain."""
    nxt, w = synthetic_markov_lm(rng, vocab, branching=branching)
    state = rng.integers(0, vocab, size=batch, dtype=np.int32)
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = state
        for t in range(seq):
            choice = (rng.random(batch)[:, None] >
                      np.cumsum(w[state], axis=1)).sum(axis=1)
            choice = np.minimum(choice, branching - 1)
            state = nxt[state, choice]
            toks[:, t + 1] = state
        yield {"tokens": toks}


def recsys_batch_stream(
    rng: np.random.Generator, family: str, batch: int, *,
    n_sparse: int = 26, multi_hot: int = 1, vocab: int = 1_000_000,
    n_dense: int = 13, seq_len: int = 100, rank: int = 8,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields batches for the recsys families with planted structure."""
    # latent universes never exceed the id vocabulary — otherwise distinct
    # latents collide onto one embedding row and the labels become
    # unlearnable noise (matters for small smoke vocabularies)
    n_users_lat = min(4096, vocab)
    n_items_lat = min(8192, vocab)
    u_lat = rng.normal(size=(n_users_lat, rank)).astype(np.float32)
    i_lat = rng.normal(size=(n_items_lat, rank)).astype(np.float32)

    while True:
        if family == "two_tower":
            nf = max(n_sparse // 2, 1)
            u = rng.integers(0, n_users_lat, batch)
            # positive item correlated with user latent
            scores = u_lat[u] @ i_lat.T + rng.gumbel(size=(batch, n_items_lat)) * 0.5
            pos = scores.argmax(axis=1)
            user_ids = np.stack(
                [(u * 2654435761 + f) % vocab for f in range(nf)], 1
            )[:, :, None].astype(np.int32)
            item_ids = np.stack(
                [(pos * 97 + f * 31) % vocab for f in range(nf)], 1
            )[:, :, None].astype(np.int32)
            yield {"user_ids": np.broadcast_to(user_ids, (batch, nf, multi_hot)).astype(np.int32),
                   "item_ids": np.broadcast_to(item_ids, (batch, nf, multi_hot)).astype(np.int32)}
        elif family == "din":
            # the task DIN's target-attention exists for: does the target
            # relate to the user's history?  positives are items from the
            # user's recent history, negatives are random items.
            u = rng.integers(0, n_users_lat, batch)
            aff = u_lat[u] @ i_lat.T
            hist = np.argsort(-(aff + rng.gumbel(size=aff.shape)),
                              axis=1)[:, :seq_len]
            label = (rng.random(batch) < 0.5).astype(np.float32)
            pos = hist[np.arange(batch),
                       rng.integers(0, max(seq_len // 2, 1), batch)]
            neg = rng.integers(0, n_items_lat, batch)
            target = np.where(label > 0.5, pos, neg)
            yield {"hist": (hist % vocab).astype(np.int32),
                   "target": (target % vocab).astype(np.int32),
                   "label": label}
        else:  # autoint / dlrm
            u = rng.integers(0, n_users_lat, batch)
            item = rng.integers(0, n_items_lat, batch)
            aff = np.einsum("br,br->b", u_lat[u], i_lat[item])
            label = (aff + rng.normal(size=batch) * 0.5 > 0).astype(np.float32)
            ids = np.stack(
                [((u if f % 2 else item) * 2654435761 + f * 101) % vocab
                 for f in range(n_sparse)], 1
            )[:, :, None].astype(np.int32)
            out = {"ids": np.broadcast_to(ids, (batch, n_sparse, multi_hot)).astype(np.int32),
                   "label": label}
            if family == "dlrm":
                dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
                dense[:, 0] = aff  # leak signal into a dense feature
                out["dense"] = dense
            yield out

"""repro: production-grade JAX framework implementing *Progressive Searching
for Retrieval in RAG* (Jeong et al., ICMLA 2025 / CS.IR 2026).

The paper's contribution — a multi-stage progressive ANN search that starts
from truncated low-dimensional embeddings and incrementally refines the
candidate set toward the full target dimensionality — is implemented as a
first-class, shardable retrieval feature (repro.core), integrated into a
RAG serving pipeline (repro.rag), a two-tower retrieval model
(repro.models.recsys), and a multi-pod launcher (repro.launch).
"""

__version__ = "1.0.0"

from repro.train.loop import TrainLoop, make_train_step

__all__ = ["TrainLoop", "make_train_step"]

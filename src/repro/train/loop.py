"""Training loop: jit'd step factory + fault-tolerant driver.

``make_train_step`` builds the canonical (params, opt_state, batch) ->
(params', opt_state', metrics) function from any ``loss_fn(params, batch)``,
with optional gradient accumulation (microbatching) folded *inside* the jit
so remat + accumulation compose, and optional bf16 gradient compression
before the data-parallel reduction.

``TrainLoop`` is the production driver:
  * restart-aware (restores the latest complete checkpoint on construction),
  * async checkpoints every ``ckpt_every`` steps + emergency checkpoint on
    SIGTERM/KeyboardInterrupt (preemption handling),
  * host-side data prefetch (double buffering),
  * straggler/step-time telemetry (p50/p95, slowest-step log) — at fleet
    scale the same telemetry feeds the coordinator's straggler mitigation
    (DESIGN.md §Fault-tolerance).
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.optim import adamw_init, adamw_update, cosine_schedule


def make_train_step(
    loss_fn: Callable,
    *,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    accum_steps: int = 1,
    grad_dtype: Optional[str] = None,
    donate: bool = True,
    jit: bool = True,
):
    """Build a jit'd train step.

    ``loss_fn(params, batch) -> (loss, metrics)``.
    With ``accum_steps > 1`` the batch's leading axis must be divisible by it;
    microbatches run in a ``lax.scan`` accumulating fp32 grads.
    """

    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = gfn(params, batch)
            return grads, metrics

        def micro(batch_i):
            return jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:])[batch_i]
                if hasattr(x, "reshape") else x, batch)

        def body(carry, i):
            acc = carry
            (loss, metrics), grads = gfn(params, micro(i))
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps, acc, grads)
            return acc, metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(body, zero, jnp.arange(accum_steps))
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    def step(params, opt_state, batch):
        grads, metrics = accumulate(params, batch)
        lr = cosine_schedule(opt_state.step, base_lr=base_lr,
                             warmup=warmup, total=total_steps)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr,
            weight_decay=weight_decay, max_grad_norm=max_grad_norm,
            grad_dtype=grad_dtype)
        metrics = {**metrics, **om, "lr": lr}
        return params, opt_state, metrics

    if not jit:
        return step     # dry-run lowers it with explicit shardings itself
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class _Prefetcher:
    """One-batch-ahead host prefetch on a daemon thread."""

    def __init__(self, it: Iterator):
        self.it = it
        self._next = None
        self._sem_full = threading.Semaphore(0)
        self._sem_empty = threading.Semaphore(1)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self.it:
            self._sem_empty.acquire()
            self._next = item
            self._sem_full.release()

    def __next__(self):
        self._sem_full.acquire()
        item = self._next
        self._sem_empty.release()
        return item


class TrainLoop:
    """Fault-tolerant training driver."""

    def __init__(
        self,
        loss_fn: Callable,
        init_params_fn: Callable[[], Any],
        data_iter: Iterator,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        log_every: int = 10,
        prefetch: bool = True,
        **step_kwargs,
    ):
        self.step_fn = make_train_step(loss_fn, **step_kwargs)
        self.data = _Prefetcher(data_iter) if prefetch else data_iter
        self.log_every = log_every
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.step_times: collections.deque = collections.deque(maxlen=512)
        self.history: list = []

        params = init_params_fn()
        opt_state = adamw_init(params)
        self.state = (params, opt_state)
        self.start_step = 0
        if self.mgr is not None:
            restored, step = self.mgr.restore((params, opt_state))
            if restored is not None:
                self.state = restored
                self.start_step = int(step)
                print(f"[train] restored checkpoint at step {step}")

    def _emergency_save(self, step):
        if self.mgr is not None:
            print(f"[train] emergency checkpoint at step {step}")
            self.mgr.save_async(step, self.state)
            self.mgr.wait()

    def run(self, n_steps: int) -> Dict[str, float]:
        params, opt_state = self.state
        step = self.start_step
        last_metrics: Dict[str, float] = {}
        try:
            while step < n_steps:
                batch = next(self.data)
                batch = jax.tree.map(jnp.asarray, batch)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step_times.append(dt)
                self.state = (params, opt_state)
                step += 1
                if step % self.log_every == 0 or step == n_steps:
                    last_metrics = {k: float(v) for k, v in metrics.items()}
                    ts = np.asarray(self.step_times)
                    last_metrics["step_p50_ms"] = float(np.percentile(ts, 50) * 1e3)
                    last_metrics["step_p95_ms"] = float(np.percentile(ts, 95) * 1e3)
                    self.history.append({"step": step, **last_metrics})
                    print(f"[train] step {step}: " + " ".join(
                        f"{k}={v:.4g}" for k, v in last_metrics.items()))
                if self.mgr is not None and step % self.ckpt_every == 0:
                    self.mgr.save_async(step, self.state)
        except KeyboardInterrupt:
            self._emergency_save(step)
            raise
        if self.mgr is not None:
            self.mgr.save_async(step, self.state)
            self.mgr.wait()
        return last_metrics

"""End-to-end RAG serving pipeline (paper Fig. 1/2 realized as a service).

    query tokens ──embed──> query vector ──progressive search──> top-k docs
         └───────────────────────── prompt assembly ──> LM decode ──> answer

The embedder is pluggable: production uses a trained encoder; the examples
use either the LM's own token embeddings (mean-pooled) or a hash projection
— the retrieval machinery is agnostic, it only sees vectors.

Batched requests: every stage is vmapped/batched; the pipeline jits one
program per (batch, prompt-length) bucket, the standard serving practice.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core import (
    ProgressiveSchedule,
    build_index,
    make_schedule,
    progressive_search,
    stage_dims,
)
from repro.models import lm as LM

Array = jax.Array


def mean_pool_embedder(params, cfg: LMConfig) -> Callable[[Array], Array]:
    """Embed token ids by mean-pooling the LM's token-embedding rows.

    Cheap, deterministic, and uses the model's own representation space —
    good enough for the synthetic serving demo; swap for a trained encoder
    in production.
    """

    def embed(tokens: Array) -> Array:           # (B, S) -> (B, D)
        e = params["embed"][tokens].astype(jnp.float32)
        mask = (tokens > 0)[..., None].astype(jnp.float32)
        return (e * mask).sum(1) / jnp.maximum(mask.sum(1), 1.0)

    return embed


class RAGPipeline:
    """Retrieval-augmented generation over a document corpus."""

    def __init__(
        self,
        lm_params,
        lm_cfg: LMConfig,
        doc_embeddings: Array,          # (N, D_emb)
        doc_tokens: Array,              # (N, doc_len) int32 — corpus text
        *,
        schedule: Optional[ProgressiveSchedule] = None,
        embedder: Optional[Callable] = None,
        d_start: int = 32,
        k0: int = 32,
    ):
        self.lm_params = lm_params
        self.cfg = lm_cfg
        self.db = jnp.asarray(doc_embeddings, jnp.float32)
        self.doc_tokens = jnp.asarray(doc_tokens, jnp.int32)
        d_emb = self.db.shape[1]
        self.sched = schedule or make_schedule(min(d_start, d_emb), d_emb, k0)
        self.index = build_index(self.db, stage_dims(self.sched))
        self.embed = embedder or mean_pool_embedder(lm_params, lm_cfg)

    def retrieve(self, query_tokens: Array) -> Tuple[Array, Array]:
        """(B, S) query tokens -> ((B, k) scores, (B, k) doc indices)."""
        q = self.embed(query_tokens)
        return progressive_search(
            q, self.db, self.sched,
            sq_prefix=self.index["sq_prefix"],
            index_dims=stage_dims(self.sched),
        )

    def assemble_prompts(self, query_tokens: Array, doc_idx: Array) -> Array:
        """Prepend the top-1 retrieved document to each query."""
        docs = self.doc_tokens[doc_idx[:, 0]]            # (B, doc_len)
        return jnp.concatenate([docs, query_tokens], axis=1)

    def serve(self, query_tokens: Array, *, max_new_tokens: int = 8) -> Dict:
        """Full pipeline for a batch of requests; greedy decode."""
        scores, idx = self.retrieve(query_tokens)
        prompts = self.assemble_prompts(query_tokens, idx)
        b, s = prompts.shape
        total = s + max_new_tokens

        logits, cache = LM.prefill(self.lm_params, prompts, self.cfg)
        cache = LM.prefill_to_decode_cache(self.cfg, cache, s, total)
        toks = jnp.argmax(logits, axis=-1)[:, None]

        out = [toks]
        for i in range(max_new_tokens - 1):
            logits, cache = LM.decode_step(
                self.lm_params, cache, toks, s + i, self.cfg)
            toks = jnp.argmax(logits, axis=-1)[:, None]
            out.append(toks)
        return {
            "retrieved": idx,
            "retrieval_scores": scores,
            "generated": jnp.concatenate(out, axis=1),
        }

"""End-to-end RAG serving pipeline (paper Fig. 1/2 realized as a service).

    query tokens ──embed──> query vector ──RetrievalEngine──> top-k docs
         └───────────────────────── prompt assembly ──> LM decode ──> answer

The embedder is pluggable: production uses a trained encoder; the examples
use either the LM's own token embeddings (mean-pooled) or a hash projection
— the retrieval machinery is agnostic, it only sees vectors.

Retrieval runs through `repro.engine.RetrievalEngine`: requests are coalesced
into shape-bucketed batches (each bucket jits exactly once per corpus
capacity), and the corpus is mutable — ``add_docs`` / ``delete_docs`` keep
the doc-token table and the engine's embedding buffers in sync, with deleted
docs unreturnable from the moment of deletion.

For concurrent serving, ``start_driver()`` puts an async ``EngineDriver`` in
front of the engine (deadline-based batch formation on a background thread);
while it runs, ``retrieve``/``serve`` route each query through the driver's
future-based request path — so calls from many client threads coalesce into
shared batches — and ``stop_driver()`` drains it.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core import ProgressiveSchedule, make_schedule
from repro.engine import EngineDriver, RetrievalEngine
from repro.models import lm as LM

Array = jax.Array


def mean_pool_embedder(params, cfg: LMConfig) -> Callable[[Array], Array]:
    """Embed token ids by mean-pooling the LM's token-embedding rows.

    Cheap, deterministic, and uses the model's own representation space —
    good enough for the synthetic serving demo; swap for a trained encoder
    in production.
    """

    def embed(tokens: Array) -> Array:           # (B, S) -> (B, D)
        e = params["embed"][tokens].astype(jnp.float32)
        mask = (tokens > 0)[..., None].astype(jnp.float32)
        return (e * mask).sum(1) / jnp.maximum(mask.sum(1), 1.0)

    return embed


class RAGPipeline:
    """Retrieval-augmented generation over a mutable document corpus."""

    def __init__(
        self,
        lm_params,
        lm_cfg: LMConfig,
        doc_embeddings: Array,          # (N, D_emb)
        doc_tokens: Array,              # (N, doc_len) int32 — corpus text
        *,
        schedule: Optional[ProgressiveSchedule] = None,
        embedder: Optional[Callable] = None,
        d_start: int = 32,
        k0: int = 32,
        buckets: Optional[Sequence[int]] = None,
        backend: Optional[str] = None,
        backend_opts: Optional[Dict] = None,
        engine: Optional[RetrievalEngine] = None,
    ):
        self.lm_params = lm_params
        self.cfg = lm_cfg
        # Host-side token table with capacity doubling, mirroring DocStore's
        # growth so streaming add_docs stays amortized O(1) per append
        # (a jnp.concatenate per add would copy the whole table every call).
        self._tokens = np.asarray(doc_tokens, np.int32)
        # np.asarray may alias the caller's buffer (or a read-only device
        # view); in-place writes wait until growth/compaction copies it
        self._tokens_owned = False
        self._n_tokens = self._tokens.shape[0]
        db = jnp.asarray(doc_embeddings, jnp.float32)
        d_emb = db.shape[1]
        self.sched = schedule or make_schedule(min(d_start, d_emb), d_emb, k0)
        if engine is not None:
            if engine.store.size != 0:
                # doc ids double as doc_tokens row numbers; a pre-populated
                # engine would offset every id and silently fetch wrong text
                raise ValueError(
                    f"caller-supplied engine must be empty, holds "
                    f"{engine.store.size} docs"
                )
            if engine.store.d_emb != d_emb:
                raise ValueError(
                    f"engine dim {engine.store.d_emb} != embedding dim {d_emb}"
                )
            # the engine's own schedule/buckets are what retrieve() runs —
            # reject conflicting explicit args rather than silently ignoring
            if schedule is not None and schedule != engine.sched:
                raise ValueError(
                    "explicit schedule conflicts with supplied engine's "
                    "schedule; pass one or the other"
                )
            if buckets is not None and tuple(buckets) != engine.policy.sizes:
                raise ValueError(
                    f"explicit buckets {tuple(buckets)} conflict with "
                    f"supplied engine's {engine.policy.sizes}"
                )
            if backend is not None or backend_opts is not None:
                raise ValueError(
                    "explicit backend/backend_opts conflict with the "
                    "supplied engine's backend; pass one or the other"
                )
            self.sched = engine.sched
            self.engine = engine
        else:
            self.engine = RetrievalEngine(
                d_emb, schedule=self.sched,
                capacity=max(1, db.shape[0]),
                buckets=buckets if buckets is not None
                else (1, 2, 4, 8, 16, 32),
                backend=backend or "flat",
                backend_opts=backend_opts,
            )
        # Compaction remaps engine doc ids; follow with the token table so
        # ids keep doubling as token-row numbers.
        self.engine.on_remap.append(self._apply_remap)
        self.engine.add_docs(db)
        self.embed = embedder or mean_pool_embedder(lm_params, lm_cfg)
        self._driver: Optional[EngineDriver] = None
        # store generation of the last compaction remap (written in
        # _apply_remap under engine.lock): driver-path results dispatched
        # before it hold pre-remap ids that no longer index the token table
        self._last_remap_gen = 0

    # -- async serving driver -------------------------------------------------
    @property
    def driver(self) -> Optional[EngineDriver]:
        """The running ``EngineDriver`` (None while serving synchronously)."""
        return self._driver

    def start_driver(self, *, max_wait_ms: float = 2.0, max_queue: int = 1024,
                     **driver_kw) -> EngineDriver:
        """Put an async batching driver in front of the engine and start it.

        While the driver runs, ``retrieve``/``serve`` submit through it (one
        future per query) instead of calling ``engine.search`` — so requests
        from many threads coalesce into shared deadline-flushed batches.
        """
        if self._driver is not None:
            raise RuntimeError("driver already running; stop_driver() first")
        self._driver = EngineDriver(
            self.engine, max_wait_ms=max_wait_ms, max_queue=max_queue,
            **driver_kw,
        ).start()
        return self._driver

    def stop_driver(self, *, drain: bool = True) -> None:
        """Stop the async driver (drain by default); idempotent."""
        if self._driver is not None:
            driver, self._driver = self._driver, None
            driver.stop(drain=drain)

    # -- corpus mutation ------------------------------------------------------
    @property
    def doc_tokens(self) -> np.ndarray:
        """(N, doc_len) int32 token rows, aligned with engine doc ids."""
        return self._tokens[:self._n_tokens]

    def add_docs(self, doc_embeddings: Array, doc_tokens: Array) -> np.ndarray:
        """Append docs (embeddings + token text); returns their stable ids."""
        embs = jnp.asarray(doc_embeddings, jnp.float32)
        tokens = np.asarray(doc_tokens, np.int32)
        # Validate before mutating the engine: a partial append would leave
        # searchable ids with no (or the wrong) token text behind them.
        if tokens.shape[0] != embs.shape[0]:
            raise ValueError(
                f"{embs.shape[0]} embeddings but {tokens.shape[0]} token rows"
            )
        if tokens.shape[1] != self._tokens.shape[1]:
            raise ValueError(
                f"doc_tokens width {tokens.shape[1]} != corpus width "
                f"{self._tokens.shape[1]}"
            )
        ids = self.engine.add_docs(embs)
        need = self._n_tokens + tokens.shape[0]
        if need > self._tokens.shape[0]:
            new_cap = max(2 * self._tokens.shape[0], need)
            grown = np.zeros((new_cap, self._tokens.shape[1]), np.int32)
            grown[:self._n_tokens] = self._tokens[:self._n_tokens]
            self._tokens = grown
            self._tokens_owned = True
        self._tokens[self._n_tokens:need] = tokens
        self._n_tokens = need
        return ids

    def delete_docs(self, ids) -> int:
        """Remove docs from retrieval.

        Token rows stay until the engine's next compaction, at which point
        ids are remapped and this pipeline's table follows automatically.
        """
        return self.engine.delete_docs(ids)

    def _apply_remap(self, id_map: np.ndarray) -> None:
        """Engine compaction callback: drop dead token rows, keep alignment.

        ``id_map`` maps old engine row ids to new ones (-1 = tombstoned);
        compaction preserves live-row order, so gathering the surviving
        token rows in old-id order reproduces the new id order exactly.

        The alignment check below fires when docs were added to the engine
        behind the pipeline's back (``pipe.engine.add_docs(...)``); the
        engine's compaction path is exception-safe — it finishes its own
        rebuild before this error reaches the caller.
        """
        if id_map.shape[0] != self._n_tokens:
            raise RuntimeError(
                f"compaction remap covers {id_map.shape[0]} rows but the "
                f"token table holds {self._n_tokens} — corpus out of sync"
            )
        live_old = np.nonzero(id_map >= 0)[0]
        rows = self._tokens[live_old]            # fancy index: a copy
        if not self._tokens_owned:
            # still aliasing the constructor argument (caller-owned buffer,
            # or a read-only device view): never write through it
            self._tokens = self._tokens.copy()
            self._tokens_owned = True
        self._n_tokens = live_old.size
        self._tokens[: self._n_tokens] = rows
        self._last_remap_gen = self.engine.store.generation

    # -- serving --------------------------------------------------------------
    def retrieve(self, query_tokens: Array) -> Tuple[np.ndarray, np.ndarray]:
        """(B, S) query tokens -> ((B, k) scores, (B, k) doc indices).

        Routes through the async driver when one is running (each query
        becomes a future; the driver coalesces across concurrent callers),
        otherwise through the engine's synchronous bucketed batch API.
        """
        q = np.asarray(self.embed(query_tokens), np.float32)
        driver = self._driver
        if driver is None:
            return self.engine.search(q)
        if q.shape[0] == 0:
            k = self.engine.out_k
            return (np.zeros((0, k), np.float32), np.zeros((0, k), np.int32))
        futures = [driver.submit(v) for v in q]
        results = [f.result() for f in futures]
        scores = np.stack([r.scores for r in results])
        ids = np.stack([r.doc_ids for r in results])
        with self.engine.lock:
            # A compaction can land between a result's dispatch and this
            # gather: such ids predate a remap the futures never saw, and
            # would index the already-reorganized token table wrongly.
            # store_generation detects exactly this; re-retrieve those rows
            # synchronously under the lock.  The re-search runs the engine's
            # own safe point and may itself compact (remapping the rows we
            # did NOT re-search), so loop until no row predates the last
            # remap.  Terminates: compaction clears every tombstone and no
            # other thread can delete while we hold the lock, so at most
            # one compaction can fire in here.
            gens = [r.store_generation for r in results]
            while True:
                # the g < generation guard bounds the loop unconditionally:
                # a re-searched row carries the newest generation, so it can
                # only be flagged again if a compaction bumped it since
                cur = self.engine.store.generation
                stale = [j for j, g in enumerate(gens)
                         if g < self._last_remap_gen and g < cur]
                if not stale:
                    break
                scores[stale], ids[stale] = self.engine.search(q[stale])
                for j in stale:
                    gens[j] = self.engine.store.generation
        return scores, ids

    def assemble_prompts(self, query_tokens: Array, doc_idx) -> Array:
        """Prepend the top-1 retrieved document to each query.

        A -1 index (nothing retrievable, e.g. fully-deleted corpus) prepends
        padding tokens instead of any document's text — deleted docs must not
        leak into prompts through the sentinel.
        """
        top1 = np.asarray(doc_idx)[:, 0]
        doc_len = self._tokens.shape[1]
        if self._n_tokens == 0:
            # zero-doc corpus: every index is the -1 sentinel; all padding
            docs = np.zeros((top1.shape[0], doc_len), np.int32)
        else:
            docs = self.doc_tokens[np.maximum(top1, 0)]    # (B, doc_len)
            docs = np.where((top1 >= 0)[:, None], docs, 0)
        return jnp.concatenate(
            [jnp.asarray(docs), jnp.asarray(query_tokens)], axis=1)

    def generate(self, query_tokens: Array, doc_idx,
                 *, max_new_tokens: int = 8) -> Array:
        """Greedy-decode answers given already-retrieved doc indices."""
        prompts = self.assemble_prompts(query_tokens, doc_idx)
        b, s = prompts.shape
        total = s + max_new_tokens

        logits, cache = LM.prefill(self.lm_params, prompts, self.cfg)
        cache = LM.prefill_to_decode_cache(self.cfg, cache, s, total)
        toks = jnp.argmax(logits, axis=-1)[:, None]

        out = [toks]
        for i in range(max_new_tokens - 1):
            logits, cache = LM.decode_step(
                self.lm_params, cache, toks, s + i, self.cfg)
            toks = jnp.argmax(logits, axis=-1)[:, None]
            out.append(toks)
        return jnp.concatenate(out, axis=1)

    def serve(self, query_tokens: Array, *, max_new_tokens: int = 8) -> Dict:
        """Full pipeline for a batch of requests; greedy decode."""
        scores, idx = self.retrieve(query_tokens)
        return {
            "retrieved": idx,
            "retrieval_scores": scores,
            "generated": self.generate(
                query_tokens, idx, max_new_tokens=max_new_tokens),
        }

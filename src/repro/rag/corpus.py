"""Synthetic embedding corpus with the statistics that make the paper's
experiments reproducible offline.

The paper embeds 1M dbpedia documents with text-embedding-3-large (3072) and
gte-Qwen2-7B (3584) and measures top-1 retrieval accuracy of GPT-generated
queries as a function of *truncation* dimensionality (Table II/IV): accuracy
climbs steeply through ~64-256 dims and saturates in the low-to-mid 90s by
the full dimensionality.  Two statistical properties produce that curve:

  1. **Decaying per-dimension signal**: leading dimensions carry more of the
     query-document alignment (trained embeddings concentrate energy;
     text-embedding-3 is explicitly Matryoshka-trained).  We draw documents
     as  d_i = s ⊙ z_i,  z ~ N(0, I),  s_j = (1+j)^-alpha.
  2. **Hard distractors**, two kinds (both observed in web corpora):
     - *exact twins* (mirrored/boilerplate documents): retrieval returns the
       twin half the time — a permanent accuracy cap (the 95% plateau);
     - *late-dim near-twins*: copies that differ only in trailing embedding
       dimensions — indistinguishable at low truncation, resolved as dims
       grow, producing the paper's slow 92.8 -> 95.0 climb from 256 dims to
       full dimensionality.

`make_corpus` exposes all knobs; defaults are calibrated so the
accuracy-vs-dim profile matches gte-Qwen2-7B-instruct's Table II shape.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    db: np.ndarray          # (N, D) document embeddings
    queries: np.ndarray     # (Q, D) query embeddings
    ground_truth: np.ndarray  # (Q,) index of each query's source document
    scales: np.ndarray      # (D,) the spectrum used


def make_corpus(
    n_docs: int = 100_000,
    dim: int = 1024,
    n_queries: int = 2470,
    *,
    alpha: float = 0.2,
    sigma: float = 1.25,
    sigma_spread: float = 0.55,
    twin_frac: float = 0.08,
    late_twin_frac: float = 0.05,
    late_start_frac: float = 0.25,
    late_sigma: float = 0.6,
    seed: int = 0,
    dtype=np.float32,
) -> SyntheticCorpus:
    """Build the synthetic corpus.

    Args:
      alpha:        per-dimension signal decay exponent (mild for trained
                    embeddings; steeper = more Matryoshka-like).
      sigma:        median query noise (per-dim scaled by the spectrum).
      sigma_spread: lognormal spread of per-query noise — heterogeneous query
                    difficulty, which is what gives real corpora their soft
                    accuracy-vs-dim transition and sub-100% plateau.
      twin_frac:    fraction of *queried* docs given an (effectively exact)
                    twin elsewhere — permanent ~frac/2 top-1 loss.
      late_twin_frac: fraction given a near-twin differing only in dims
                    >= late_start_frac * dim (resolved as dims grow).
      late_sigma:   size of the near-twin's late-dim offset.
    """
    rng = np.random.default_rng(seed)
    scales = (1.0 + np.arange(dim)) ** (-alpha)
    scales = (scales / np.linalg.norm(scales) * np.sqrt(dim)).astype(dtype)

    db = rng.standard_normal((n_docs, dim), dtype=dtype) * scales
    gt = rng.choice(n_docs // 2, n_queries, replace=False)  # sources live in
    # the first half; twins overwrite rows in the second half so a twin never
    # clobbers another query's source.

    spare = np.arange(n_docs // 2, n_docs)
    rng.shuffle(spare)
    n_twin = int(n_queries * twin_frac)
    n_late = int(n_queries * late_twin_frac)
    twin_of = rng.choice(n_queries, n_twin + n_late, replace=False)

    # "exact" twins: an infinitesimal symmetric offset (1e-3) so the
    # query-noise sign — not index order — decides ties: ~half lost at
    # every dimensionality.
    twin_rows = db[gt[twin_of[:n_twin]]].copy()
    twin_rows += 1e-3 * scales * rng.standard_normal(
        (n_twin, dim), dtype=dtype)
    db[spare[:n_twin]] = twin_rows

    # late-dim near-twins: identical leading dims, offset trailing dims
    late0 = int(dim * late_start_frac)
    late_rows = db[gt[twin_of[n_twin:]]].copy()
    late_rows[:, late0:] += (late_sigma * scales[late0:]
                             * rng.standard_normal((n_late, dim - late0),
                                                   dtype=dtype))
    db[spare[n_twin: n_twin + n_late]] = late_rows

    sig_q = sigma * np.exp(
        sigma_spread * rng.standard_normal(n_queries)).astype(dtype)
    queries = db[gt] + sig_q[:, None] * scales * rng.standard_normal(
        (n_queries, dim), dtype=dtype)
    return SyntheticCorpus(db=db, queries=queries.astype(dtype),
                           ground_truth=gt.astype(np.int64), scales=scales)


def make_clustered_corpus(
    n_docs: int = 100_000,
    dim: int = 256,
    n_queries: int = 256,
    *,
    n_clusters: int = 96,
    cluster_spread: float = 2.0,
    cluster_std: float = 0.35,
    sigma: float = 0.25,
    alpha: float = 0.2,
    seed: int = 0,
    dtype=np.float32,
) -> SyntheticCorpus:
    """Topically-clustered corpus — the workload ANN backends are built for.

    ``make_corpus`` models the paper's *truncation* experiments with an
    unclustered anisotropic gaussian; real document embeddings additionally
    carry topical cluster structure (dbpedia categories, product verticals),
    which is precisely the prior an IVF coarse quantizer exploits.  Here
    documents are a mixture of ``n_clusters`` gaussians over the same
    decaying per-dimension spectrum, and queries are noisy copies of their
    source documents — near neighbours concentrate inside a topic, distant
    topics are prunable.

    Args:
      cluster_spread: centre scale relative to within-cluster std scale —
                      larger separates topics more cleanly.
      cluster_std:    within-cluster document spread (per-dim scaled).
      sigma:          query noise (per-dim scaled).
    """
    rng = np.random.default_rng(seed)
    scales = (1.0 + np.arange(dim)) ** (-alpha)
    scales = (scales / np.linalg.norm(scales) * np.sqrt(dim)).astype(dtype)

    centers = (cluster_spread * scales
               * rng.standard_normal((n_clusters, dim), dtype=dtype))
    topic = rng.integers(0, n_clusters, n_docs)
    db = centers[topic] + cluster_std * scales * rng.standard_normal(
        (n_docs, dim), dtype=dtype)
    gt = rng.choice(n_docs, n_queries, replace=False)
    queries = db[gt] + sigma * scales * rng.standard_normal(
        (n_queries, dim), dtype=dtype)
    return SyntheticCorpus(db=db, queries=queries.astype(dtype),
                           ground_truth=gt.astype(np.int64), scales=scales)

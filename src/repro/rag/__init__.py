from repro.rag.corpus import SyntheticCorpus, make_clustered_corpus, make_corpus
from repro.rag.pipeline import RAGPipeline

__all__ = ["SyntheticCorpus", "make_clustered_corpus", "make_corpus",
           "RAGPipeline"]

from repro.rag.corpus import SyntheticCorpus, make_corpus
from repro.rag.pipeline import RAGPipeline

__all__ = ["SyntheticCorpus", "make_corpus", "RAGPipeline"]

from repro.checkpoint.ckpt import (
    CheckpointManager,
    CorruptCheckpoint,
    all_steps,
    latest_step,
    load_arrays,
    restore_checkpoint,
    save_arrays,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "CorruptCheckpoint", "save_checkpoint",
           "restore_checkpoint", "save_arrays", "load_arrays",
           "all_steps", "latest_step"]

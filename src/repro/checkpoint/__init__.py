from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    load_arrays,
    restore_checkpoint,
    save_arrays,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "save_arrays", "load_arrays", "latest_step"]

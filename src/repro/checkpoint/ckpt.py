"""Fault-tolerant checkpointing: npz shards + msgpack manifest.

Design points for 1000-node operation (DESIGN.md §Fault-tolerance):

  * **Mesh-agnostic**: arrays are saved fully-replicated host-side (gathered
    via jax.device_get), so a job can restart on a *different* mesh/device
    count — elastic rescaling comes free because shardings are re-applied at
    load from the arch's logical rules, not recorded in the checkpoint.
  * **Atomic**: writes go to ``step_XXXX.tmp/`` and are renamed only after the
    manifest is fsynced — a node dying mid-write can never corrupt the latest
    checkpoint.  Restart picks the newest *complete* step.
  * **Async**: ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes to disk on a daemon thread, so the train
    loop is blocked only for the device->host copy.
  * **Retention**: keeps the last ``keep`` checkpoints; older ones deleted
    after a successful save.

For multi-controller deployments each host saves only its addressable shards
under ``host_<i>/`` (same manifest format); this container is single-host so
that path is exercised in degenerate form.
"""

from __future__ import annotations

import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

import jax
import jax.numpy as jnp


class CorruptCheckpoint(RuntimeError):
    """The checkpoint on disk fails verification: unreadable manifest/npz,
    an array checksum mismatch, or a missing member.  Typed so recovery
    code can fall back to an older step instead of dying on a cold numpy/
    zipfile error."""


def _array_crc(a: np.ndarray) -> int:
    """Content checksum of one array (dtype-stable via the encoded bytes)."""
    enc = _encode(np.ascontiguousarray(a))
    return zlib.crc32(enc.tobytes())


def _fsync_dir(path: str) -> None:
    """fsync a directory so a rename within it is durable (POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                           # pragma: no cover (platform)
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _encode(a: np.ndarray) -> np.ndarray:
    """npz cannot serialize ml_dtypes (bfloat16, fp8): store the raw bits;
    the manifest's dtype map restores them on load."""
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16)
    if a.dtype.name.startswith("float8"):
        return a.view(np.uint8)
    return a


def _decode(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if a.dtype.name != dtype_name and dtype_name in ("bfloat16",):
        import ml_dtypes
        return a.view(ml_dtypes.bfloat16)
    if a.dtype.name != dtype_name and dtype_name.startswith("float8"):
        import ml_dtypes
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrs = {f"arr_{i}": np.asarray(jax.device_get(x)) for i, x in enumerate(leaves)}
    return arrs, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None,
                    keep: int = 3) -> str:
    """Atomically save ``tree`` under ``ckpt_dir/step_{step:08d}``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrs, treedef = _flatten(tree)
    # npz through an explicit handle so it can be fsync'd: np.savez(path)
    # alone leaves the array bytes in the page cache, and a crash after the
    # rename could surface a "complete" checkpoint with torn arrays
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **{k: _encode(v) for k, v in arrs.items()})
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "n_arrays": len(arrs),
        "treedef": str(treedef),
        "time": time.time(),
        "extra": extra or {},
        "dtypes": {k: str(v.dtype) for k, v in arrs.items()},
        # per-array content CRCs: load verifies them, so silent on-disk
        # corruption becomes a typed CorruptCheckpoint (recovery falls back
        # to the previous step) instead of wrong search results
        "checksums": {k: _array_crc(v) for k, v in arrs.items()},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(ckpt_dir)                  # make the rename itself durable

    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return final


def save_arrays(ckpt_dir: str, step: int, arrays: Dict[str, np.ndarray], *,
                extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically save a *named* flat array dict (self-describing restore).

    `save_checkpoint` needs a matching target tree at restore time;
    serving-side state (e.g. a built retrieval index) has none on a fresh
    process, so the names are recorded in the manifest and `load_arrays`
    reconstructs the dict without a target.  Same atomic tmp-dir + fsynced
    manifest protocol.
    """
    named = {k: np.asarray(v) for k, v in sorted(arrays.items())}
    extra = {"array_names": list(named), **(extra or {})}
    return save_checkpoint(ckpt_dir, step, named, extra=extra, keep=keep)


def _read_step(path: str) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Read + verify one checkpoint dir; returns (manifest, raw arrays).

    Every failure mode — unreadable manifest, bad zip, missing member,
    checksum mismatch — raises ``CorruptCheckpoint``, so callers can treat
    "this step is unusable" uniformly and fall back to an older one.
    """
    try:
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrs = {k: z[k] for k in z.files}
    except CorruptCheckpoint:
        raise
    except Exception as e:                 # zipfile/msgpack/OSError/KeyError
        raise CorruptCheckpoint(f"{path}: unreadable checkpoint: {e}") from e
    n = manifest.get("n_arrays")
    if n is not None and n != len(arrs):
        raise CorruptCheckpoint(
            f"{path}: manifest promises {n} arrays, npz holds {len(arrs)}")
    checksums = manifest.get("checksums")
    if checksums:                          # absent on pre-checksum ckpts
        for key, want in checksums.items():
            got = arrs.get(key)
            if got is None:
                raise CorruptCheckpoint(f"{path}: missing array {key!r}")
            if _array_crc(got) != want:
                raise CorruptCheckpoint(
                    f"{path}: checksum mismatch on {key!r} — the array "
                    f"bytes on disk are corrupt")
    return manifest, arrs


def load_arrays(ckpt_dir: str, *, step: Optional[int] = None):
    """Restore a `save_arrays` checkpoint without a target tree.

    Returns (name->array dict, manifest ``extra`` dict, step), or
    (None, None, None) when no checkpoint exists.  Raises
    ``CorruptCheckpoint`` when the step exists but fails verification.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None, None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest, arrs = _read_step(path)
    extra = manifest.get("extra", {})
    names = extra.get("array_names")
    if names is None:
        raise ValueError(
            f"{path} was not written by save_arrays (no array_names); "
            f"use restore_checkpoint with a target tree")
    dtypes = manifest.get("dtypes", {})
    # flatten order of a dict is sorted-key order — the order save_arrays
    # fixed by sorting the names
    arrays = {
        name: _decode(arrs[f"arr_{i}"], dtypes.get(f"arr_{i}",
                                                   str(arrs[f"arr_{i}"].dtype)))
        for i, name in enumerate(names)
    }
    return arrays, extra, step


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.msgpack")):
                out.append(int(name[5:]))
    return sorted(out)  # listdir order is filesystem-dependent


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, target_tree, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (values replaced).

    ``shardings``: optional pytree of NamedSharding to place arrays onto the
    *current* mesh — this is the elastic-rescale path.
    Returns (tree, step) or (None, None) when no checkpoint exists.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest, raw = _read_step(path)
    dtypes = manifest.get("dtypes", {})
    arrs = {k: _decode(v, dtypes.get(k, str(v.dtype)))
            for k, v in raw.items()}
    leaves, treedef = jax.tree.flatten(target_tree)
    assert len(leaves) == len(arrs), (
        f"checkpoint has {len(arrs)} arrays, target expects {len(leaves)}")
    new_leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for i, (tgt, shd) in enumerate(zip(leaves, shard_leaves)):
        a = arrs[f"arr_{i}"]
        assert a.shape == tuple(tgt.shape), f"arr_{i}: {a.shape} vs {tgt.shape}"
        if shd is not None:
            new_leaves.append(jax.device_put(a.astype(tgt.dtype), shd))
        else:
            new_leaves.append(jnp.asarray(a, tgt.dtype))
    return treedef.unflatten(new_leaves), step


class CheckpointManager:
    """Async save + restart-aware restore."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None):
        """Snapshot to host now; write on a daemon thread."""
        self.wait()
        arrs, treedef = _flatten(tree)   # device->host copy happens here

        def _write():
            try:
                # re-wrap so save_checkpoint re-flattens cheap host arrays
                host_tree = treedef.unflatten(
                    [arrs[f"arr_{i}"] for i in range(len(arrs))])
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                extra=extra, keep=self.keep)
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, target_tree, *, shardings=None):
        return restore_checkpoint(self.ckpt_dir, target_tree,
                                  shardings=shardings)

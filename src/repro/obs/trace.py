"""Per-request trace spans: monotonic pipeline timestamps + slow-query log.

A ``TraceContext`` rides on each ``PendingRequest`` through the serving
spine and collects ``time.perf_counter()`` marks at the pipeline's seams:

    submit    request constructed (``check_request``)
    admit     accepted into a queue (driver pending list / engine queue)
    batch     chosen into a batch (driver ``_take_locked`` / queue pop)
    dispatch  batch handed to the backend (post rebuild + mask compile)
    stage0    stage-0 scan fenced complete (only with ``obs.stage_fences``)
    rescore   rescore ladder complete (only with ``obs.stage_fences``)
    deliver   result materialised on host

``spans_ms()`` converts marks to millisecond offsets from ``submit`` —
monotone non-decreasing in pipeline order, so ``dispatch`` *is* the queue
time and ``deliver`` is the end-to-end latency.  Marks that a given path
does not cross (e.g. ``stage0`` on the fused fast path) are simply absent.

``TraceRing`` keeps a bounded in-memory window of recent completed traces
for ``/v1/traces``-style debugging; ``SlowQueryLog`` emits one structured
JSON line per request whose latency exceeds the configured threshold.
"""

from __future__ import annotations

import collections
import json
import logging
import threading
import time
from typing import Dict, List, Optional

# pipeline order — used for ordering output and monotonicity checks
MARK_ORDER = ("submit", "admit", "batch", "dispatch",
              "stage0", "rescore", "deliver")

slow_query_logger = logging.getLogger("repro.obs.slowquery")


class TraceContext:
    """Mutable mark set for one request's trip through the pipeline.

    Single-writer at every point in time (ownership moves along the
    pipeline with the request), so no lock is needed.
    """

    __slots__ = ("marks",)

    def __init__(self, t_submit: Optional[float] = None):
        self.marks: Dict[str, float] = {
            "submit": time.perf_counter() if t_submit is None else t_submit}

    def mark(self, name: str, t: Optional[float] = None) -> None:
        self.marks[name] = time.perf_counter() if t is None else t

    def spans_ms(self) -> Dict[str, float]:
        """Millisecond offsets from ``submit``, in pipeline order."""
        t0 = self.marks["submit"]
        return {name: (self.marks[name] - t0) * 1e3
                for name in MARK_ORDER if name in self.marks}


class TraceRing:
    """Bounded ring of recent completed-request trace records."""

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._ring = collections.deque(maxlen=max(1, self.capacity))
        self._lock = threading.Lock()

    def push(self, record: Dict) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._ring.append(record)

    def push_many(self, records) -> None:
        """One lock round-trip for a whole batch of completed traces."""
        if self.capacity <= 0 or not records:
            return
        with self._lock:
            self._ring.extend(records)

    def snapshot(self, n: Optional[int] = None) -> List[Dict]:
        """Most-recent-last copy of up to ``n`` records."""
        with self._lock:
            items = list(self._ring)
        return items if n is None else items[-int(n):]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


class SlowQueryLog:
    """Structured JSON log for requests slower than ``threshold_ms``.

    Emits one ``logging`` record per offender on the
    ``repro.obs.slowquery`` logger; keeps the last few records in memory so
    tests (and operators at a REPL) can inspect them without a log pipe.
    """

    def __init__(self, threshold_ms: Optional[float],
                 logger: Optional[logging.Logger] = None, keep: int = 32):
        self.threshold_ms = (float(threshold_ms)
                             if threshold_ms is not None else None)
        self._logger = logger or slow_query_logger
        self._lock = threading.Lock()
        self._recent = collections.deque(maxlen=keep)
        self.n_logged = 0

    @property
    def enabled(self) -> bool:
        return self.threshold_ms is not None and self.threshold_ms >= 0

    def maybe_log(self, record: Dict) -> bool:
        """Log ``record`` if its latency_ms crosses the threshold."""
        if not self.enabled:
            return False
        latency = record.get("latency_ms")
        if latency is None or latency < self.threshold_ms:
            return False
        entry = dict(record, slow_query_threshold_ms=self.threshold_ms)
        with self._lock:
            self._recent.append(entry)
            self.n_logged += 1
        self._logger.warning(json.dumps(entry, sort_keys=True,
                                        default=str))
        return True

    def recent(self) -> List[Dict]:
        with self._lock:
            return list(self._recent)

"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

The serving spine updates metrics under ``engine.lock`` (and the driver's
condition variable), so every instrument here is deliberately cheap: one
registry-wide ``threading.Lock`` around a dict lookup and a float add — no
allocation on the hot path after the first observation of a label set.

* **Labels** — each instrument is a *family*; a concrete series is the
  family plus a tuple of label values.  Families declare their label names
  up front and cap distinct label-value sets (``max_series``, default 64):
  past the cap, new series collapse into a reserved ``"__overflow__"``
  series so an unbounded tenant universe cannot grow memory without bound.
* **Histograms** — fixed upper-bound buckets (``DEFAULT_LATENCY_BUCKETS_MS``
  spans 0.1ms..10s).  Offline benchmarks and the online engine share the
  same bucket definitions through ``summarize_latency`` /
  ``percentile_from_counts``, so a p95 in ``BENCH_engine.json`` and a p95
  scraped from ``/metrics`` mean the same thing.
* **Exposition** — ``render_prometheus()`` emits Prometheus text format
  0.0.4 (``# TYPE`` lines, cumulative ``_bucket{le=...}`` series,
  ``_sum``/``_count``); ``snapshot()`` is the JSON-able equivalent.
  ``parse_prometheus`` round-trips the text form for tests and the load
  benchmark's mid-run invariant checks.
* **Disabled mode** — ``MetricsRegistry(enabled=False)`` hands out shared
  no-op instruments: every ``inc``/``set``/``observe`` is a single
  attribute lookup + pass, restoring the uninstrumented fast path.

Collectors (``register_collector``) let components publish point-in-time
gauges lazily: they run at ``render_prometheus``/``snapshot`` time, not per
request — the engine registers one that snapshots store/backend state under
its own lock only when something actually scrapes.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# Shared fixed bucket ladder for every latency histogram (milliseconds).
DEFAULT_LATENCY_BUCKETS_MS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)

_OVERFLOW = ("__overflow__",)


def _format_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class _NullInstrument:
    """Shared no-op counter/gauge/histogram for a disabled registry."""

    def inc(self, amount: float = 1.0, **labels) -> None:
        pass

    def add(self, amount: float, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def set_total(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def observe_many(self, values, **labels) -> None:
        pass

    def value(self, **labels) -> float:
        return 0.0

    def count(self, **labels) -> float:
        return 0.0

    def percentile(self, p: float, **labels) -> float:
        return float("nan")


NULL_INSTRUMENT = _NullInstrument()


class _Family:
    """Base: one named metric family with labeled series."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 labels: Sequence[str], max_series: int):
        self._reg = registry
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self.max_series = int(max_series)
        self._series: Dict[Tuple, object] = {}

    def _key(self, labels: Dict) -> Tuple:
        # fast path: unlabeled family + no kwargs (the per-request hot
        # instruments) — skip the set comparisons entirely
        if not labels and not self.label_names:
            return ()
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        key = tuple(str(labels[n]) for n in self.label_names)
        # cardinality cap: unseen label sets past the bound collapse into
        # one reserved overflow series (bounded memory, visible truncation)
        if key not in self._series and len(self._series) >= self.max_series:
            return _OVERFLOW if self.label_names else key
        return key

    def _series_items(self) -> List[Tuple[Tuple, object]]:
        return sorted(self._series.items())

    def _label_str(self, key: Tuple, extra: str = "") -> str:
        if key == _OVERFLOW and self.label_names:
            parts = [f'{self.label_names[0]}="__overflow__"']
            parts += [f'{n}=""' for n in self.label_names[1:]]
        else:
            parts = [f'{n}="{v}"' for n, v in zip(self.label_names, key)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""


class Counter(_Family):
    """Monotonically-increasing float counter family."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._reg._lock:
            key = self._key(labels)
            self._series[key] = self._series.get(key, 0.0) + amount

    add = inc

    def set_total(self, value: float, **labels) -> None:
        """Publish an externally-tracked lifetime total (collector path:
        a component that already keeps its own int just mirrors it)."""
        with self._reg._lock:
            self._series[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        with self._reg._lock:
            return float(self._series.get(self._key(labels), 0.0))

    def _render(self, out: List[str]) -> None:
        for key, v in self._series_items():
            out.append(
                f"{self.name}{self._label_str(key)} {_format_value(v)}")


class Gauge(_Family):
    """Set-to-current-value gauge family."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._reg._lock:
            self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        with self._reg._lock:
            key = self._key(labels)
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._reg._lock:
            return float(self._series.get(self._key(labels), 0.0))

    _render = Counter._render


class Histogram(_Family):
    """Fixed-bucket histogram family (per-series counts + sum + count)."""

    kind = "histogram"

    def __init__(self, registry, name, help, labels, max_series,
                 buckets: Sequence[float]):
        super().__init__(registry, name, help, labels, max_series)
        bkts = tuple(float(b) for b in buckets)
        if not bkts or list(bkts) != sorted(set(bkts)):
            raise ValueError(
                f"histogram {name!r} buckets must be ascending/unique, "
                f"got {buckets}")
        self.buckets = bkts

    def _slot(self, key: Tuple) -> Dict:
        s = self._series.get(key)
        if s is None:
            s = {"counts": [0] * (len(self.buckets) + 1),
                 "sum": 0.0, "count": 0}
            self._series[key] = s
        return s

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        i = bisect.bisect_left(self.buckets, v)
        with self._reg._lock:
            s = self._slot(self._key(labels))
            s["counts"][i] += 1
            s["sum"] += v
            s["count"] += 1

    def observe_many(self, values, **labels) -> None:
        """Batch ``observe``: one lock round-trip for a whole batch of
        samples (the engine records a batch's requests in one call)."""
        if not values:
            return
        vs = [float(v) for v in values]
        slots = [bisect.bisect_left(self.buckets, v) for v in vs]
        with self._reg._lock:
            s = self._slot(self._key(labels))
            counts = s["counts"]
            for i in slots:
                counts[i] += 1
            s["sum"] += sum(vs)
            s["count"] += len(vs)

    def count(self, **labels) -> int:
        with self._reg._lock:
            s = self._series.get(self._key(labels))
            return int(s["count"]) if s else 0

    def percentile(self, p: float, **labels) -> float:
        with self._reg._lock:
            s = self._series.get(self._key(labels))
            counts = list(s["counts"]) if s else []
        return percentile_from_counts(counts, self.buckets, p)

    def _render(self, out: List[str]) -> None:
        for key, s in self._series_items():
            cum = 0
            for ub, c in zip(self.buckets, s["counts"]):
                cum += c
                le = 'le="' + _format_value(ub) + '"'
                out.append(
                    f"{self.name}_bucket{self._label_str(key, le)} {cum}")
            cum += s["counts"][-1]
            le_inf = 'le="+Inf"'
            out.append(
                f"{self.name}_bucket{self._label_str(key, le_inf)} {cum}")
            out.append(
                f"{self.name}_sum{self._label_str(key)} "
                f"{_format_value(s['sum'])}")
            out.append(
                f"{self.name}_count{self._label_str(key)} {s['count']}")


class MetricsRegistry:
    """One process-local metric namespace + its exposition surface.

    ``enabled=False`` returns shared no-op instruments from every factory —
    the callers' code paths are unchanged but nothing is recorded (the
    ``obs.enabled=False`` fast path the overhead benchmark measures).
    """

    def __init__(self, *, enabled: bool = True, max_series: int = 64):
        self.enabled = bool(enabled)
        self.max_series = int(max_series)
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Callable[[], None]] = []

    def _family(self, cls, name: str, help: str, labels, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if type(fam) is not cls or fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.label_names}")
                return fam
            fam = cls(self, name, help, tuple(labels),
                      kw.pop("max_series", self.max_series), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._family(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._family(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                  ) -> Histogram:
        return self._family(Histogram, name, help, labels, buckets=buckets)

    def register_collector(self, fn: Callable[[], None]) -> None:
        """Register a scrape-time callback that sets gauges/counters."""
        if self.enabled:
            with self._lock:
                self._collectors.append(fn)

    def _collect(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        self._collect()
        out: List[str] = []
        with self._lock:
            fams = sorted(self._families.items())
        for name, fam in fams:
            if fam.help:
                out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.kind}")
            with self._lock:
                fam._render(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict:
        """JSON-able dump: {name: {kind, series: {label-str: value|hist}}}."""
        self._collect()
        out: Dict = {}
        with self._lock:
            for name, fam in sorted(self._families.items()):
                series = {}
                for key, v in fam._series_items():
                    label = ",".join(
                        f"{n}={x}"
                        for n, x in zip(fam.label_names, key)) or ""
                    if isinstance(v, dict):
                        series[label] = {
                            "count": v["count"], "sum": v["sum"],
                            "counts": list(v["counts"]),
                            "buckets": list(fam.buckets),
                        }
                    else:
                        series[label] = v
                out[name] = {"kind": fam.kind, "series": series}
        return out


# -- shared percentile math (offline benchmarks use the same buckets) -------

def histogram_counts(values, buckets: Sequence[float]
                     = DEFAULT_LATENCY_BUCKETS_MS) -> List[int]:
    """Bucket a value list exactly as ``Histogram.observe`` does.

    Returns ``len(buckets) + 1`` counts; the last slot is the +Inf bucket.
    """
    counts = [0] * (len(buckets) + 1)
    bkts = list(buckets)
    for v in values:
        counts[bisect.bisect_left(bkts, float(v))] += 1
    return counts


def percentile_from_counts(counts: Sequence[int], buckets: Sequence[float],
                           p: float) -> float:
    """Bucket-interpolated percentile (Prometheus ``histogram_quantile``
    style: linear within the winning bucket, lower bound 0 for the first)."""
    total = sum(counts)
    if total == 0:
        return float("nan")
    rank = (p / 100.0) * total
    cum = 0
    for i, c in enumerate(counts):
        prev_cum = cum
        cum += c
        if cum >= rank and c > 0:
            if i >= len(buckets):          # +Inf bucket: no upper bound
                return float(buckets[-1])
            lo = 0.0 if i == 0 else float(buckets[i - 1])
            hi = float(buckets[i])
            frac = (rank - prev_cum) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
    return float(buckets[-1]) if buckets else float("nan")


def summarize_latency(values_ms, pcts: Sequence[float] = (50.0, 95.0),
                      buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                      ) -> Dict[str, float]:
    """Benchmark-side percentile summary on the shared bucket ladder.

    ``{"p50": ..., "p95": ...}`` computed through the very same bucket
    definitions the online histograms use, so offline BENCH numbers and
    ``/metrics`` percentiles are directly comparable (both carry the same
    bucket-resolution error, instead of exact-vs-bucketed skew).
    """
    counts = histogram_counts(values_ms, buckets)
    return {f"p{int(p) if float(p).is_integer() else p}":
            percentile_from_counts(counts, buckets, p) for p in pcts}


# -- exposition parsing (tests + load-bench invariant checks) ---------------

def parse_prometheus(text: str) -> Dict[str, Dict[Tuple, float]]:
    """Parse text exposition into {metric_name: {label-tuple: value}}.

    Label tuples are sorted ``(name, value)`` pairs.  Raises ``ValueError``
    on a malformed line — the load benchmark treats that as a hard failure.
    """
    out: Dict[str, Dict[Tuple, float]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            if "{" in line:
                name, rest = line.split("{", 1)
                labels_raw, value_raw = rest.rsplit("}", 1)
                labels = []
                for part in _split_labels(labels_raw):
                    ln, _, lv = part.partition("=")
                    if not (lv.startswith('"') and lv.endswith('"')):
                        raise ValueError("unquoted label value")
                    labels.append((ln.strip(), lv[1:-1]))
                key = tuple(sorted(labels))
            else:
                name, value_raw = line.rsplit(None, 1)
                key = ()
            value = float(value_raw.strip().replace("+Inf", "inf"))
        except Exception as e:
            raise ValueError(
                f"malformed exposition line {lineno}: {line!r} ({e})"
            ) from None
        out.setdefault(name.strip(), {})[key] = value
    return out


def _split_labels(raw: str) -> List[str]:
    """Split ``a="x",b="y"`` on commas outside quotes."""
    parts, buf, quoted = [], [], False
    for ch in raw:
        if ch == '"':
            quoted = not quoted
        if ch == "," and not quoted:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        parts.append("".join(buf))
    return [p for p in (s.strip() for s in parts) if p]

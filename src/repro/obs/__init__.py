"""Observability layer: metrics registry, trace spans, slow-query log.

  MetricsRegistry        — thread-safe counters / gauges / fixed-bucket
                           histograms with labels + cardinality caps;
                           Prometheus text exposition and JSON snapshot
  TraceContext, TraceRing,
  SlowQueryLog           — per-request pipeline timestamps (submit →
                           deliver), a bounded ring of recent traces, and
                           a structured JSON slow-query log
  summarize_latency, histogram_counts, percentile_from_counts,
  DEFAULT_LATENCY_BUCKETS_MS
                         — the shared bucket ladder + percentile math used
                           by both the online histograms and the offline
                           benchmarks, so p50/p95 mean the same thing in
                           BENCH_*.json and on /metrics
  parse_prometheus       — exposition-format parser for tests and the
                           load benchmark's invariant checks

Everything here is dependency-free (stdlib only) and safe to update under
``engine.lock``; ``MetricsRegistry(enabled=False)`` degrades every
instrument to a shared no-op so the uninstrumented fast path is restored.
"""

from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_INSTRUMENT,
    histogram_counts,
    parse_prometheus,
    percentile_from_counts,
    summarize_latency,
)
from repro.obs.trace import (
    MARK_ORDER,
    SlowQueryLog,
    TraceContext,
    TraceRing,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_MS", "Counter", "Gauge", "Histogram",
    "MARK_ORDER", "MetricsRegistry", "NULL_INSTRUMENT", "SlowQueryLog",
    "TraceContext", "TraceRing", "histogram_counts", "parse_prometheus",
    "percentile_from_counts", "summarize_latency",
]

"""RecSys architecture family: two-tower retrieval, DIN, AutoInt, DLRM-RM2.

The shared substrate is the sparse embedding path: JAX has no native
EmbeddingBag, so lookups are ``jnp.take`` + masked sum over the multi-hot
axis (`embed_fields`), with the Pallas `embedding_bag` kernel as the fused
TPU variant.  Tables are stacked (F, V, D) and shard table-wise over the
``model`` mesh axis and row-wise over ``data`` — the DLRM hybrid-parallel
layout; GSPMD inserts the exchange collectives from the shardings alone.

The two-tower model is where the paper's technique becomes a first-class
serving feature: `retrieval_serve` scores one query against a million-item
candidate DB with **progressive search** over the item-embedding index
(truncated stages -> exact final), exactly the paper's workload with learned
embeddings instead of text-embedding vectors.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core.progressive import progressive_search
from repro.core.schedule import ProgressiveSchedule, make_schedule
from repro.layers.common import dense_init, dtype_of, mlp_apply, mlp_init
from repro.sharding.specs import NULL_CTX, ShardingCtx

Array = jax.Array


# ------------------------------------------------------------ embedding --

def embed_tables_init(key, n_fields: int, vocab: int, d: int, dtype):
    """(F, V, D) stacked per-field embedding tables."""
    return (jax.random.normal(key, (n_fields, vocab, d), jnp.float32)
            * d**-0.5).astype(dtype)


def embed_fields(tables: Array, ids: Array) -> Array:
    """EmbeddingBag-sum per field.  tables (F,V,D); ids (B,F,H) -> (B,F,D).

    -1 ids are padding.  This is the framework lowering; the fused Pallas
    path is `repro.kernels.embedding_bag_op` (per field).
    """
    def per_field(tab, idf):                      # (V, D), (B, H)
        safe = jnp.maximum(idf, 0)
        rows = tab[safe]                          # (B, H, D)
        mask = (idf >= 0)[..., None].astype(rows.dtype)
        return (rows * mask).sum(axis=1)

    return jax.vmap(per_field, in_axes=(0, 1), out_axes=1)(tables, ids)


# --------------------------------------------------------------- models --

def recsys_init(key, cfg: RecsysConfig):
    dt = dtype_of(cfg.param_dtype)
    d = cfg.embed_dim
    ks = jax.random.split(key, 8)
    if cfg.family == "two_tower":
        nf = max(cfg.n_sparse // 2, 1)
        return {
            "user_tables": embed_tables_init(ks[0], nf, cfg.vocab_per_field, d, dt),
            "item_tables": embed_tables_init(ks[1], nf, cfg.vocab_per_field, d, dt),
            "user_mlp": mlp_init(ks[2], (nf * d,) + cfg.tower_mlp, dt),
            "item_mlp": mlp_init(ks[3], (nf * d,) + cfg.tower_mlp, dt),
        }
    if cfg.family == "din":
        return {
            "item_table": embed_tables_init(ks[0], 1, cfg.vocab_per_field, d, dt)[0],
            "attn_mlp": mlp_init(ks[1], (4 * d,) + cfg.attn_mlp + (1,), dt),
            "mlp": mlp_init(ks[2], (3 * d,) + cfg.mlp + (1,), dt),
        }
    if cfg.family == "autoint":
        layers = []
        for l in range(cfg.n_attn_layers):
            kq, kk, kv, kr = jax.random.split(ks[3 + l] if 3 + l < 8
                                              else jax.random.fold_in(key, l), 4)
            d_in = d if l == 0 else cfg.d_attn * cfg.n_attn_heads
            layers.append({
                "wq": dense_init(kq, d_in, cfg.n_attn_heads * cfg.d_attn, dt),
                "wk": dense_init(kk, d_in, cfg.n_attn_heads * cfg.d_attn, dt),
                "wv": dense_init(kv, d_in, cfg.n_attn_heads * cfg.d_attn, dt),
                "w_res": dense_init(kr, d_in, cfg.n_attn_heads * cfg.d_attn, dt),
            })
        d_out = cfg.d_attn * cfg.n_attn_heads
        return {
            "tables": embed_tables_init(ks[0], cfg.n_sparse, cfg.vocab_per_field, d, dt),
            "attn": layers,
            "out": mlp_init(ks[1], (cfg.n_sparse * d_out, 1), dt),
        }
    if cfg.family == "dlrm":
        n_pairs = (cfg.n_sparse + 1) * cfg.n_sparse // 2
        top_in = n_pairs + cfg.bot_mlp[-1]
        return {
            "tables": embed_tables_init(ks[0], cfg.n_sparse, cfg.vocab_per_field, d, dt),
            "bot_mlp": mlp_init(ks[1], (cfg.n_dense,) + cfg.bot_mlp, dt),
            "top_mlp": mlp_init(ks[2], (top_in,) + cfg.top_mlp, dt),
        }
    raise ValueError(cfg.family)


def recsys_param_logical(cfg: RecsysConfig, params) -> Any:
    """Logical axes mirroring recsys_init's structure."""
    table_log = ("fields", "rows", None)

    def mlp_log(layers):
        return [{"w": ("embed", "mlp"), **({"b": ("mlp",)} if "b" in l else {})}
                for l in layers]

    if cfg.family == "two_tower":
        return {
            "user_tables": table_log, "item_tables": table_log,
            "user_mlp": mlp_log(params["user_mlp"]),
            "item_mlp": mlp_log(params["item_mlp"]),
        }
    if cfg.family == "din":
        return {
            "item_table": ("rows", None),
            "attn_mlp": mlp_log(params["attn_mlp"]),
            "mlp": mlp_log(params["mlp"]),
        }
    if cfg.family == "autoint":
        return {
            "tables": table_log,
            "attn": [{k: ("embed", "mlp") for k in l} for l in params["attn"]],
            "out": mlp_log(params["out"]),
        }
    if cfg.family == "dlrm":
        return {
            "tables": table_log,
            "bot_mlp": mlp_log(params["bot_mlp"]),
            "top_mlp": mlp_log(params["top_mlp"]),
        }
    raise ValueError(cfg.family)


# ------------------------------------------------------------ two-tower --

def tower_user(params, user_ids: Array, ctx: ShardingCtx = NULL_CTX) -> Array:
    e = embed_fields(params["user_tables"], user_ids)       # (B, F, D)
    e = ctx.constrain(e, ("batch", "fields", None))
    x = e.reshape(e.shape[0], -1)
    u = mlp_apply(params["user_mlp"], x, act=jax.nn.relu)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def tower_item(params, item_ids: Array, ctx: ShardingCtx = NULL_CTX) -> Array:
    e = embed_fields(params["item_tables"], item_ids)
    e = ctx.constrain(e, ("batch", "fields", None))
    x = e.reshape(e.shape[0], -1)
    v = mlp_apply(params["item_mlp"], x, act=jax.nn.relu)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def _inbatch_softmax(u: Array, v: Array, ctx: ShardingCtx):
    logits = (u @ v.T) * 20.0                               # temperature
    logits = ctx.constrain(logits, ("batch", None))
    labels = jnp.arange(u.shape[0])
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((logits.argmax(-1) == labels).astype(jnp.float32))
    return loss, acc


def two_tower_loss(params, batch, cfg: RecsysConfig, ctx: ShardingCtx = NULL_CTX):
    """In-batch sampled-softmax retrieval loss (RecSys'19), with optional
    Matryoshka auxiliary losses on truncated embedding prefixes
    (``cfg.matryoshka_dims``) so the index supports progressive search."""
    u = tower_user(params, batch["user_ids"], ctx)          # (B, d)
    v = tower_item(params, batch["item_ids"], ctx)          # (B, d)
    loss, acc = _inbatch_softmax(u, v, ctx)
    for d in cfg.matryoshka_dims:
        un = u[:, :d] / jnp.maximum(
            jnp.linalg.norm(u[:, :d], axis=-1, keepdims=True), 1e-6)
        vn = v[:, :d] / jnp.maximum(
            jnp.linalg.norm(v[:, :d], axis=-1, keepdims=True), 1e-6)
        l_d, _ = _inbatch_softmax(un, vn, ctx)
        loss = loss + l_d / max(len(cfg.matryoshka_dims), 1)
    return loss, {"loss": loss, "acc": acc}


def retrieval_serve(
    params, user_ids: Array, item_db: Array, cfg: RecsysConfig,
    *, sched: Optional[ProgressiveSchedule] = None, k: int = 10,
    ctx: ShardingCtx = NULL_CTX,
) -> Tuple[Array, Array]:
    """Progressive-search retrieval over a precomputed item-embedding DB.

    The paper's technique as the two-tower serving path: queries are the user
    tower output; the DB is the (C, d) item tower output; search runs the
    multi-stage truncated schedule instead of a brute-force full-dim scan.

    Returns ((B, k) scores, (B, k) item indices).
    """
    q = tower_user(params, user_ids, ctx)
    if sched is None:
        sched = make_schedule(cfg.retrieval_d_start, item_db.shape[1],
                              cfg.retrieval_k0, final_k=k)
    return progressive_search(q.astype(jnp.float32),
                              item_db.astype(jnp.float32), sched)


# ------------------------------------------------------------------ DIN --

def din_forward(params, batch, cfg: RecsysConfig, ctx: ShardingCtx = NULL_CTX) -> Array:
    """batch: hist (B, S) int32 (-1 pad), target (B,) int32 -> logits (B,)."""
    tab = params["item_table"]                              # (V, D)
    hist, target = batch["hist"], batch["target"]
    h = tab[jnp.maximum(hist, 0)]                           # (B, S, D)
    t = tab[target]                                         # (B, D)
    mask = (hist >= 0).astype(h.dtype)[..., None]

    tb = jnp.broadcast_to(t[:, None], h.shape)
    att_in = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)
    w = mlp_apply(params["attn_mlp"], att_in, act=jax.nn.sigmoid)  # (B, S, 1)
    w = w * mask
    user = (w * h).sum(axis=1)                              # (B, D)
    user = ctx.constrain(user, ("batch", None))

    x = jnp.concatenate([user, t, user * t], axis=-1)
    return mlp_apply(params["mlp"], x, act=jax.nn.relu)[:, 0]


# -------------------------------------------------------------- AutoInt --

def autoint_forward(params, batch, cfg: RecsysConfig,
                    ctx: ShardingCtx = NULL_CTX) -> Array:
    """batch: ids (B, F, H) int32 -> logits (B,)."""
    e = embed_fields(params["tables"], batch["ids"])        # (B, F, D)
    e = ctx.constrain(e, ("batch", "fields", None))
    x = e
    h, da = cfg.n_attn_heads, cfg.d_attn
    for p in params["attn"]:
        b, f, _ = x.shape
        q = (x @ p["wq"]).reshape(b, f, h, da).transpose(0, 2, 1, 3)
        k = (x @ p["wk"]).reshape(b, f, h, da).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(b, f, h, da).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * da**-0.5
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a.astype(v.dtype), v)
        o = o.transpose(0, 2, 1, 3).reshape(b, f, h * da)
        x = jax.nn.relu(o + x @ p["w_res"])
    flat = x.reshape(x.shape[0], -1)
    return mlp_apply(params["out"], flat)[:, 0]


# ----------------------------------------------------------------- DLRM --

def dlrm_forward(params, batch, cfg: RecsysConfig,
                 ctx: ShardingCtx = NULL_CTX) -> Array:
    """batch: dense (B, n_dense) f32, ids (B, F, H) int32 -> logits (B,)."""
    z = mlp_apply(params["bot_mlp"], batch["dense"], act=jax.nn.relu,
                  final_act=True)                            # (B, d)
    e = embed_fields(params["tables"], batch["ids"])         # (B, F, D)
    e = ctx.constrain(e, ("batch", "fields", None))
    feats = jnp.concatenate([z[:, None, :], e], axis=1)      # (B, F+1, D)
    # pairwise dot interaction, upper triangle (excluding diagonal)
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats,
                      preferred_element_type=jnp.float32)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = gram[:, iu, ju]                                  # (B, F(F-1)/2... )
    x = jnp.concatenate([z.astype(jnp.float32), pairs], axis=-1)
    return mlp_apply(params["top_mlp"], x.astype(z.dtype), act=jax.nn.relu)[:, 0]


# ---------------------------------------------------------- shared loss --

_FORWARDS = {"din": din_forward, "autoint": autoint_forward, "dlrm": dlrm_forward}


def recsys_forward(params, batch, cfg: RecsysConfig,
                   ctx: ShardingCtx = NULL_CTX) -> Array:
    return _FORWARDS[cfg.family](params, batch, cfg, ctx)


def ctr_loss(params, batch, cfg: RecsysConfig, ctx: ShardingCtx = NULL_CTX):
    """Binary logistic loss for the CTR models (din/autoint/dlrm)."""
    logits = recsys_forward(params, batch, cfg, ctx).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def recsys_loss(params, batch, cfg: RecsysConfig, ctx: ShardingCtx = NULL_CTX):
    if cfg.family == "two_tower":
        return two_tower_loss(params, batch, cfg, ctx)
    return ctr_loss(params, batch, cfg, ctx)


# --------------------------------------------------- candidate scoring --

def serve_candidates(params, batch, cand_ids: Array, cfg: RecsysConfig,
                     ctx: ShardingCtx = NULL_CTX) -> Array:
    """Score ``C`` candidate items for each of B user contexts (bulk ranking).

    For CTR models the designated item field (field 0 / DIN target) is swept
    over candidates with user context broadcast — the offline-scoring /
    retrieval_cand shape.  Returns (B, C) scores.
    """
    c = cand_ids.shape[0]

    if cfg.family == "two_tower":
        item_ids = jnp.broadcast_to(
            cand_ids[:, None, None],
            (c, params["item_tables"].shape[0], 1)).astype(jnp.int32)
        db = tower_item(params, item_ids, ctx)               # (C, d)
        q = tower_user(params, batch["user_ids"], ctx)       # (B, d)
        return ctx.constrain(q @ db.T, ("batch", "cand"))

    if cfg.family == "din":
        def per_user(hist):
            def score_chunk(tgt):
                return din_forward(params,
                                   {"hist": jnp.broadcast_to(hist, (tgt.shape[0],) + hist.shape),
                                    "target": tgt}, cfg, ctx)
            return score_chunk(cand_ids)
        return jax.vmap(per_user)(batch["hist"])

    # autoint / dlrm: sweep field 0
    def per_user(b_ids, b_dense):
        ids = jnp.broadcast_to(b_ids, (c,) + b_ids.shape)
        ids = ids.at[:, 0, 0].set(cand_ids)
        bb = {"ids": ids}
        if cfg.family == "dlrm":
            bb["dense"] = jnp.broadcast_to(b_dense, (c,) + b_dense.shape)
        return recsys_forward(params, bb, cfg, ctx)

    dense = batch.get("dense",
                      jnp.zeros((batch["ids"].shape[0], max(cfg.n_dense, 1)),
                                jnp.float32))
    return jax.vmap(per_user)(batch["ids"], dense)

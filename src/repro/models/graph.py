"""Graph substrate: padded edge-list graphs, synthetic generators, and a real
k-hop neighbor sampler (GraphSAGE-style fanout) over CSR adjacency.

JAX has no sparse message-passing primitive beyond BCOO, so graphs are
(senders, receivers) int32 edge lists with -1 padding and aggregation is
``jax.ops.segment_sum`` — scatter-add over the edge index IS the
message-passing kernel on TPU (taxonomy §GNN / §B.11).

Static shapes everywhere: sampled subgraphs are padded to the fanout bound,
full-batch graphs to a fixed edge budget; masks ride along.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class Graph:
    """Padded edge-list graph (a pytree via jax.tree_util registration below)."""

    nodes: Array        # (N, F) node features
    coords: Array       # (N, 3) coordinates (EGNN) — zeros if unused
    senders: Array      # (E,) int32, -1 padding
    receivers: Array    # (E,) int32, -1 padding
    edge_attr: Array    # (E, Fe) or (E, 0)
    node_mask: Array    # (N,) bool
    edge_mask: Array    # (E,) bool
    labels: Array       # (N,) int32 node labels (or graph label per node 0)


def _graph_flatten(g: Graph):
    return ((g.nodes, g.coords, g.senders, g.receivers, g.edge_attr,
             g.node_mask, g.edge_mask, g.labels), None)


def _graph_unflatten(_, leaves):
    return Graph(*leaves)


jax.tree_util.register_pytree_node(Graph, _graph_flatten, _graph_unflatten)


# ------------------------------------------------------------ generators --

def random_graph(
    rng: np.random.Generator, n_nodes: int, n_edges: int, d_feat: int,
    *, n_classes: int = 16, d_edge: int = 0, power_law: bool = True,
) -> Graph:
    """Synthetic graph with (optionally) power-law degree distribution."""
    if power_law:
        w = rng.pareto(2.0, n_nodes) + 1.0
        p = w / w.sum()
        senders = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
        receivers = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    else:
        senders = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
        receivers = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    coords = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes, dtype=np.int32)
    ea = (rng.normal(size=(n_edges, d_edge)).astype(np.float32)
          if d_edge else np.zeros((n_edges, 0), np.float32))
    return Graph(
        nodes=jnp.asarray(feats), coords=jnp.asarray(coords),
        senders=jnp.asarray(senders), receivers=jnp.asarray(receivers),
        edge_attr=jnp.asarray(ea),
        node_mask=jnp.ones((n_nodes,), bool),
        edge_mask=jnp.ones((n_edges,), bool),
        labels=jnp.asarray(labels),
    )


def batched_molecules(
    rng: np.random.Generator, batch: int, n_nodes: int, n_edges: int,
    d_feat: int, *, n_classes: int = 16,
) -> Graph:
    """``batch`` disjoint small graphs packed into one padded graph
    (block-diagonal adjacency — the standard molecule batching)."""
    gs = [random_graph(rng, n_nodes, n_edges, d_feat, n_classes=n_classes,
                       power_law=False) for _ in range(batch)]
    off = np.arange(batch)[:, None] * n_nodes
    return Graph(
        nodes=jnp.concatenate([g.nodes for g in gs]),
        coords=jnp.concatenate([g.coords for g in gs]),
        senders=jnp.concatenate(
            [np.asarray(g.senders) + o for g, o in zip(gs, off)]).astype(jnp.int32),
        receivers=jnp.concatenate(
            [np.asarray(g.receivers) + o for g, o in zip(gs, off)]).astype(jnp.int32),
        edge_attr=jnp.concatenate([g.edge_attr for g in gs]),
        node_mask=jnp.ones((batch * n_nodes,), bool),
        edge_mask=jnp.ones((batch * n_edges,), bool),
        labels=jnp.concatenate([g.labels for g in gs]),
    )


# --------------------------------------------------------------- sampler --

class CSRGraph:
    """Host-side CSR adjacency for neighbor sampling (build once, sample often)."""

    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        order = np.argsort(senders, kind="stable")
        self.dst = receivers[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(self.indptr, senders + 1, 1)
        self.indptr = np.cumsum(self.indptr)
        self.n_nodes = n_nodes

    def sample_khop(
        self, rng: np.random.Generator, seeds: np.ndarray,
        fanout: Tuple[int, ...],
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """GraphSAGE fanout sampling.

        Returns (node_ids, senders, receivers) where senders/receivers index
        into node_ids (local ids), padded to the static fanout bound with -1.
        Layer-l edges connect frontier-l nodes to their sampled neighbours.
        """
        node_ids = [seeds.astype(np.int64)]
        id_of = {int(s): i for i, s in enumerate(seeds)}
        send, recv = [], []
        frontier = seeds.astype(np.int64)
        for f in fanout:
            nxt = []
            max_edges = len(frontier) * f
            s_pad = np.full(max_edges, -1, np.int32)
            r_pad = np.full(max_edges, -1, np.int32)
            e = 0
            for u in frontier:
                lo, hi = self.indptr[u], self.indptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = rng.integers(0, deg, f)
                for v in self.dst[lo + take]:
                    v = int(v)
                    if v not in id_of:
                        id_of[v] = len(id_of)
                        nxt.append(v)
                    s_pad[e] = id_of[v]       # message flows neighbor -> node
                    r_pad[e] = id_of[int(u)]
                    e += 1
            send.append(s_pad)
            recv.append(r_pad)
            frontier = np.asarray(nxt, np.int64)
            node_ids.append(frontier)
        all_ids = np.concatenate(node_ids) if node_ids else seeds
        return all_ids, np.concatenate(send), np.concatenate(recv)


def sampled_subgraph(
    rng: np.random.Generator, csr: CSRGraph, features: np.ndarray,
    labels: np.ndarray, coords: Optional[np.ndarray],
    batch_nodes: int, fanout: Tuple[int, ...],
    *, node_budget: int, edge_budget: int,
) -> Graph:
    """Sample a fanout subgraph and pad to (node_budget, edge_budget)."""
    seeds = rng.choice(csr.n_nodes, batch_nodes, replace=False)
    ids, s, r = csr.sample_khop(rng, seeds, fanout)
    ids = ids[:node_budget]
    n = len(ids)
    feat = np.zeros((node_budget, features.shape[1]), np.float32)
    feat[:n] = features[ids]
    lab = np.full(node_budget, -1, np.int32)
    lab[:batch_nodes] = labels[seeds]        # loss only on seed nodes
    co = np.zeros((node_budget, 3), np.float32)
    if coords is not None:
        co[:n] = coords[ids]
    e = min(len(s), edge_budget)
    s_pad = np.full(edge_budget, -1, np.int32)
    r_pad = np.full(edge_budget, -1, np.int32)
    s_pad[:e], r_pad[:e] = s[:e], r[:e]
    valid_e = (s_pad >= 0) & (s_pad < node_budget) & (r_pad >= 0) & (r_pad < node_budget)
    s_pad = np.where(valid_e, s_pad, -1)
    r_pad = np.where(valid_e, r_pad, -1)
    return Graph(
        nodes=jnp.asarray(feat), coords=jnp.asarray(co),
        senders=jnp.asarray(s_pad), receivers=jnp.asarray(r_pad),
        edge_attr=jnp.zeros((edge_budget, 0), jnp.float32),
        node_mask=jnp.asarray(np.arange(node_budget) < n),
        edge_mask=jnp.asarray(valid_e),
        labels=jnp.asarray(lab),
    )

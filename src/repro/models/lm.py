"""Config-driven transformer LM: dense GQA, MoE (Qwen3/DeepSeek), MLA,
gemma-style local:global attention — one code path, scan-over-layers.

Entry points
  init_lm(key, cfg)                       -> params pytree
  lm_param_logical(cfg)                   -> matching logical-axes pytree
  lm_forward(params, tokens, cfg, ctx)    -> (logits, aux_loss)
  lm_loss(params, batch, cfg, ctx)        -> (loss, metrics)
  prefill(params, tokens, cfg, ctx)       -> (last_logits, cache)
  init_cache(cfg, batch, seq, dtype)      -> empty cache pytree
  decode_step(params, cache, tok, pos, …) -> (logits, cache')

Layers are scanned over stacked params (small HLO, fast multi-pod compiles);
per-layer attention window / rope theta ride along as scan xs, which is how
the gemma3 5:1 local:global pattern fits a single homogeneous scan.  When the
config has a local:global pattern, *decode* unrolls the layer loop instead so
local layers can keep ring-buffer caches of window size — at 512k context the
cache memory drops ~(period-1)/period vs naive full-length caches.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.layers import attention as A
from repro.layers import mla as M
from repro.layers import moe as E
from repro.layers.common import (
    dense_init,
    dtype_of,
    embed_init,
    ffn_apply,
    ffn_init,
    ffn_specs,
    rmsnorm,
    softmax_xent,
)
from repro.sharding.specs import NULL_CTX, ShardingCtx

Array = jax.Array


# ============================================================ init =======

def _layer_init(key, cfg: LMConfig, *, moe_layer: bool):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.mla is not None:
        p["attn"] = M.mla_init(ks[0], cfg.d_model, cfg.n_heads, cfg.mla, dt)
    else:
        p["attn"] = A.attn_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, dt)
    if moe_layer:
        p["moe"] = E.moe_init(ks[1], cfg.d_model, cfg.moe, cfg.ffn_type, dt)
    else:
        p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type, dt)
    return p


def _layer_logical(cfg: LMConfig, *, moe_layer: bool):
    p: Dict[str, Any] = {"ln1": (None,), "ln2": (None,)}
    if cfg.mla is not None:
        p["attn"] = M.mla_specs(cfg.mla)
    else:
        p["attn"] = A.attn_specs()
    if moe_layer:
        p["moe"] = E.moe_specs(cfg.moe, cfg.ffn_type)
    else:
        p["ffn"] = ffn_specs(cfg.ffn_type)
    return p


def _n_dense_prefix(cfg: LMConfig) -> int:
    return cfg.moe.first_k_dense if cfg.moe is not None else 0


def init_lm(key, cfg: LMConfig):
    dt = dtype_of(cfg.param_dtype)
    k_embed, k_head, k_layers, k_dense = jax.random.split(key, 4)
    n_dense = _n_dense_prefix(cfg)
    n_main = cfg.n_layers - n_dense

    main_keys = jax.random.split(k_layers, n_main)
    layers = jax.vmap(
        lambda k: _layer_init(k, cfg, moe_layer=cfg.moe is not None)
    )(main_keys)

    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "layers": layers,
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if n_dense:
        dense_keys = jax.random.split(k_dense, n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=False)
        )(dense_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    return params


def _stack_logical(layer_logical):
    """Prepend the stacked-layers axis to every leaf's logical tuple."""
    return jax.tree.map(
        lambda log: ("layers",) + log,
        layer_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def lm_param_logical(cfg: LMConfig):
    log: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "layers": _stack_logical(_layer_logical(cfg, moe_layer=cfg.moe is not None)),
        "final_ln": (None,),
    }
    if _n_dense_prefix(cfg):
        log["dense_layers"] = _stack_logical(_layer_logical(cfg, moe_layer=False))
    if not cfg.tie_embeddings:
        log["lm_head"] = ("embed", "vocab")
    return log


# ========================================================= forward =======

def _windows_thetas(cfg: LMConfig, n_layers: int, offset: int = 0):
    wins = jnp.asarray(
        [cfg.layer_window(offset + l) for l in range(n_layers)], jnp.int32)
    thetas = jnp.asarray(
        [cfg.rope_theta_local
         if (cfg.rope_theta_local and cfg.layer_window(offset + l) > 0)
         else cfg.rope_theta
         for l in range(n_layers)], jnp.float32)
    return wins, thetas


def _block(p, x, *, cfg: LMConfig, window, theta, moe_layer: bool,
           ctx: ShardingCtx, impl: str):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a = M.mla_forward(p["attn"], h, n_heads=cfg.n_heads, cfg=cfg.mla,
                          rope_theta=cfg.rope_theta, impl=impl,
                          constrain=ctx.constrain)
    else:
        a = A.mha_forward(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, causal=True, window=window, rope_theta=theta,
            impl=impl, constrain=ctx.constrain)
    # residual stream in sequence-parallel layout (Megatron-SP): the 'seq_act'
    # rule maps to 'model' for train/prefill shapes, so per-layer saved
    # activations shard n_model-ways; GSPMD inserts the all-gather /
    # reduce-scatter pair around attention/FFN automatically.
    x = ctx.constrain(x + a, ("batch", "seq_act", "embed_act"))
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if moe_layer:
        y, aux = E.moe_apply(p["moe"], h2, cfg.moe, cfg.ffn_type,
                             constrain=ctx.constrain, ctx=ctx)
    else:
        y, aux = ffn_apply(p["ffn"], h2, cfg.ffn_type), 0.0
    x = ctx.constrain(x + y, ("batch", "seq_act", "embed_act"))
    return x, aux


def _scan_layers(stacked, x, wins, thetas, *, cfg, moe_layer, ctx, impl):
    def body(x, sl):
        p, w, th = sl
        fn = functools.partial(
            _block, cfg=cfg, moe_layer=moe_layer, ctx=ctx, impl=impl)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, aux = fn(p, x, window=w, theta=th)
        return x, aux

    x, auxs = jax.lax.scan(body, x, (stacked, wins, thetas))
    return x, jnp.sum(auxs) if cfg.moe is not None else 0.0


def lm_forward(
    params, tokens: Array, cfg: LMConfig, ctx: ShardingCtx = NULL_CTX,
    *, impl: str = "chunked",
) -> Tuple[Array, Array]:
    """tokens (B, S) int32 -> (logits (B, S, V) f32, aux loss scalar)."""
    cdt = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    x = ctx.constrain(x, ("batch", None, "embed_act"))

    n_dense = _n_dense_prefix(cfg)
    aux_total = 0.0
    if n_dense:
        wins, thetas = _windows_thetas(cfg, n_dense)
        x, _ = _scan_layers(params["dense_layers"], x, wins, thetas,
                            cfg=cfg, moe_layer=False, ctx=ctx, impl=impl)
    wins, thetas = _windows_thetas(cfg, cfg.n_layers - n_dense, offset=n_dense)
    x, aux = _scan_layers(params["layers"], x, wins, thetas,
                          cfg=cfg, moe_layer=cfg.moe is not None, ctx=ctx,
                          impl=impl)
    aux_total = aux_total + aux

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    logits = ctx.constrain(logits, ("batch", "seq_act", "vocab"))
    return logits, aux_total


def lm_loss(params, batch: Dict[str, Array], cfg: LMConfig,
            ctx: ShardingCtx = NULL_CTX, *, impl: str = "chunked"):
    """batch['tokens']: (B, S+1) int32.  Returns (loss, metrics dict)."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = lm_forward(params, inputs, cfg, ctx, impl=impl)
    xent, n_tok = softmax_xent(logits, labels)
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux, "tokens": n_tok}


# ========================================================== serving ======

def _cache_dtype(cfg: LMConfig):
    return dtype_of(cfg.compute_dtype)


def init_cache(cfg: LMConfig, batch: int, seq: int, dtype=None):
    """Empty decode cache.

    Homogeneous archs: stacked (L, ...) arrays scanned during decode.
    local:global archs: separate local (ring, window-sized) / global stacks.
    """
    dt = dtype or _cache_dtype(cfg)
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((L, batch, seq, m.kv_lora_rank), dt),
            "krope": jnp.zeros((L, batch, seq, m.d_rope), dt),
        }
    if cfg.local_global_period > 0:
        wins = [cfg.layer_window(l) for l in range(L)]
        n_local = sum(1 for w in wins if w > 0)
        n_global = L - n_local
        w = min(cfg.window, seq) if cfg.window else seq
        return {
            "k_local": jnp.zeros((n_local, batch, cfg.n_kv_heads, w, cfg.d_head), dt),
            "v_local": jnp.zeros((n_local, batch, cfg.n_kv_heads, w, cfg.d_head), dt),
            "k_global": jnp.zeros((n_global, batch, cfg.n_kv_heads, seq, cfg.d_head), dt),
            "v_global": jnp.zeros((n_global, batch, cfg.n_kv_heads, seq, cfg.d_head), dt),
        }
    return {
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, seq, cfg.d_head), dt),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, seq, cfg.d_head), dt),
    }


def cache_logical(cfg: LMConfig):
    if cfg.mla is not None:
        return {"ckv": ("layers", "batch", "kv_seq", None),
                "krope": ("layers", "batch", "kv_seq", None)}
    if cfg.local_global_period > 0:
        log = ("layers", "batch", "kv_heads", "kv_seq", None)
        return {"k_local": log, "v_local": log,
                "k_global": log, "v_global": log}
    log = ("layers", "batch", "kv_heads", "kv_seq", None)
    return {"k": log, "v": log}


def decode_step(
    params, cache, tokens: Array, pos, cfg: LMConfig,
    ctx: ShardingCtx = NULL_CTX,
) -> Tuple[Array, Any]:
    """One decode step.  tokens: (B, 1) int32; pos: traced scalar.

    Returns (logits (B, V) f32, cache').
    """
    cdt = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)          # (B, 1, D)

    if cfg.local_global_period > 0:
        x, cache = _decode_unrolled(params, cache, x, pos, cfg, ctx)
    elif cfg.mla is not None:
        x, cache = _decode_scan_mla(params, cache, x, pos, cfg, ctx)
    else:
        x, cache = _decode_scan_gqa(params, cache, x, pos, cfg, ctx)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt),
                        preferred_element_type=jnp.float32)[:, 0]
    return ctx.constrain(logits, ("batch", "vocab")), cache


def _decode_block_tail(p, x, a, cfg, ctx):
    x = x + a
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = E.moe_apply(p["moe"], h2, cfg.moe, cfg.ffn_type,
                           constrain=ctx.constrain, ctx=ctx)
    else:
        y = ffn_apply(p["ffn"], h2, cfg.ffn_type)
    return ctx.constrain(x + y, ("batch", None, "embed_act"))


def _decode_scan_gqa(params, cache, x, pos, cfg, ctx):
    n_dense = _n_dense_prefix(cfg)
    assert n_dense == 0, "dense-prefix MoE archs use MLA decode path"
    wins, thetas = _windows_thetas(cfg, cfg.n_layers)

    def body(x, sl):
        p, k_c, v_c, w, th = sl
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, k_c, v_c = A.mha_decode(
            p["attn"], h, k_c, v_c, pos=pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, window=w,
            rope_theta=th)
        x = _decode_block_tail(p, x, a, cfg, ctx)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], wins, thetas))
    return x, {"k": k_new, "v": v_new}


def _decode_scan_mla(params, cache, x, pos, cfg, ctx):
    n_dense = _n_dense_prefix(cfg)

    def body_factory(stacked_has_moe):
        def body(carry, sl):
            x = carry
            p, ckv, krope = sl
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            a, ckv, krope = M.mla_decode(
                p["attn"], h, ckv, krope, pos=pos, n_heads=cfg.n_heads,
                cfg=cfg.mla, rope_theta=cfg.rope_theta)
            x = _decode_block_tail(p, x, a, cfg, ctx)
            return x, (ckv, krope)
        return body

    ckv, krope = cache["ckv"], cache["krope"]
    outs_ckv, outs_krope = [], []
    if n_dense:
        x, (c0, r0) = jax.lax.scan(
            body_factory(False), x,
            (params["dense_layers"], ckv[:n_dense], krope[:n_dense]))
        outs_ckv.append(c0)
        outs_krope.append(r0)
    x, (c1, r1) = jax.lax.scan(
        body_factory(True), x,
        (params["layers"], ckv[n_dense:], krope[n_dense:]))
    outs_ckv.append(c1)
    outs_krope.append(r1)
    return x, {"ckv": jnp.concatenate(outs_ckv, axis=0),
               "krope": jnp.concatenate(outs_krope, axis=0)}


def _decode_unrolled(params, cache, x, pos, cfg, ctx):
    """local:global decode: python loop over layers, ring caches for local."""
    k_l, v_l = cache["k_local"], cache["v_local"]
    k_g, v_g = cache["k_global"], cache["v_global"]
    il = ig = 0
    for l in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[l], params["layers"])
        w = cfg.layer_window(l)
        theta = (cfg.rope_theta_local
                 if (cfg.rope_theta_local and w > 0) else cfg.rope_theta)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if w > 0:
            a, nk, nv = A.mha_decode(
                p["attn"], h, k_l[il], v_l[il], pos=pos, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                window=w, rope_theta=theta, ring=True)
            k_l, v_l = k_l.at[il].set(nk), v_l.at[il].set(nv)
            il += 1
        else:
            a, nk, nv = A.mha_decode(
                p["attn"], h, k_g[ig], v_g[ig], pos=pos, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                window=0, rope_theta=theta)
            k_g, v_g = k_g.at[ig].set(nk), v_g.at[ig].set(nv)
            ig += 1
        x = _decode_block_tail(p, x, a, cfg, ctx)
    return x, {"k_local": k_l, "v_local": v_l, "k_global": k_g, "v_global": v_g}


def prefill_to_decode_cache(cfg: LMConfig, cache, prompt_len: int, decode_len: int):
    """Convert a prefill cache (full-length k/v per layer) into the decode
    layout: pad the sequence axis to ``decode_len`` and, for local:global
    archs, fold sliding-window layers into ring buffers.
    """
    if cfg.local_global_period <= 0 or "k" not in cache:
        def pad_seq(v):
            ax = 3 if v.ndim == 5 else 2
            pad = [(0, 0)] * v.ndim
            pad[ax] = (0, decode_len - v.shape[ax])
            return jnp.pad(v, pad)
        return {k: pad_seq(v) for k, v in cache.items()}

    w = min(cfg.window, decode_len)
    wins = [cfg.layer_window(l) for l in range(cfg.n_layers)]
    loc_idx = [l for l, x in enumerate(wins) if x > 0]
    glo_idx = [l for l, x in enumerate(wins) if x == 0]

    def to_ring(kv):                                   # (B, H, S, D) -> (B, H, W, D)
        s = kv.shape[2]
        # token t lives at slot t % w; keep the last w tokens of the prompt
        tok = jnp.maximum(jnp.arange(s - w, s), 0)
        slots = tok % w
        ring = jnp.zeros(kv.shape[:2] + (w,) + kv.shape[3:], kv.dtype)
        return ring.at[:, :, slots].set(kv[:, :, tok])

    def pad_full(kv):
        pad = [(0, 0)] * kv.ndim
        pad[2] = (0, decode_len - kv.shape[2])
        return jnp.pad(kv, pad)

    out = {
        "k_local": jnp.stack([to_ring(cache["k"][l]) for l in loc_idx]),
        "v_local": jnp.stack([to_ring(cache["v"][l]) for l in loc_idx]),
        "k_global": jnp.stack([pad_full(cache["k"][l]) for l in glo_idx]),
        "v_global": jnp.stack([pad_full(cache["v"][l]) for l in glo_idx]),
    }
    return out


def prefill(params, tokens: Array, cfg: LMConfig, ctx: ShardingCtx = NULL_CTX,
            *, impl: str = "chunked"):
    """Inference prefill: forward pass returning (last-token logits, cache).

    The cache length equals the prompt length; serving pads to the decode
    budget before calling `decode_step`.
    """
    b, s = tokens.shape
    cdt = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    x = ctx.constrain(x, ("batch", None, "embed_act"))
    dt = _cache_dtype(cfg)

    n_dense = _n_dense_prefix(cfg)
    layer_sets = []
    if n_dense:
        layer_sets.append(("dense_layers", 0, n_dense, False))
    layer_sets.append(("layers", n_dense, cfg.n_layers, cfg.moe is not None))

    caches = {k: [] for k in ("k", "v", "ckv", "krope",
                              "k_local", "v_local", "k_global", "v_global")}

    for name, lo, hi, moe_layer in layer_sets:
        wins, thetas = _windows_thetas(cfg, hi - lo, offset=lo)

        def body(x, sl):
            p, w, th = sl
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                kv_a = h @ p["attn"]["wkv_a"]
                m = cfg.mla
                ckv = rmsnorm(kv_a[..., : m.kv_lora_rank], p["attn"]["kv_norm"])
                from repro.layers.rope import apply_rope
                krope = apply_rope(kv_a[:, None, :, m.kv_lora_rank:],
                                   jnp.arange(s), cfg.rope_theta)[:, 0]
                a = M.mla_forward(p["attn"], h, n_heads=cfg.n_heads, cfg=m,
                                  rope_theta=cfg.rope_theta)
                kv_out = (ckv.astype(dt), krope.astype(dt))
            else:
                a, (k, v) = A.mha_forward(
                    p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    d_head=cfg.d_head, causal=True, window=w, rope_theta=th,
                    return_kv=True)
                kv_out = (k.astype(dt), v.astype(dt))
            x = ctx.constrain(x + a, ("batch", None, "embed_act"))
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if moe_layer:
                y, _ = E.moe_apply(p["moe"], h2, cfg.moe, cfg.ffn_type,
                                   constrain=ctx.constrain, ctx=ctx)
            else:
                y = ffn_apply(p["ffn"], h2, cfg.ffn_type)
            x = ctx.constrain(x + y, ("batch", None, "embed_act"))
            return x, kv_out

        x, (kv_a_out, kv_b_out) = jax.lax.scan(
            body, x, (params[name], wins, thetas))
        if cfg.mla is not None:
            caches["ckv"].append(kv_a_out)
            caches["krope"].append(kv_b_out)
        else:
            caches["k"].append(kv_a_out)
            caches["v"].append(kv_b_out)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    last = x[:, -1]
    logits = jnp.einsum("bd,dv->bv", last, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    cache = {k: (jnp.concatenate(v, axis=0) if len(v) > 1 else v[0])
             for k, v in caches.items() if v}
    return ctx.constrain(logits, ("batch", "vocab")), cache

"""Architecture zoo: LM transformers (dense/MoE/MLA), EGNN, recsys models."""

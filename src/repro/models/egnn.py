"""E(n)-Equivariant Graph Neural Network (EGNN, arXiv:2102.09844).

Per layer (eqs. 3-6 of the paper):

    m_ij  = φ_e(h_i, h_j, ||x_i − x_j||², a_ij)
    x_i'  = x_i + C · Σ_j (x_i − x_j) φ_x(m_ij)
    h_i'  = φ_h(h_i, Σ_j m_ij)

Message passing is gather (by edge index) → MLP → ``segment_sum`` scatter —
the JAX-native SpMM-free formulation.  Padded edges (-1) are masked out of
every aggregation; equivariance holds per masked subgraph.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import EGNNConfig
from repro.layers.common import dtype_of, mlp_apply, mlp_init, mlp_specs
from repro.models.graph import Graph
from repro.sharding.specs import NULL_CTX, ShardingCtx

Array = jax.Array


def egnn_init(key, cfg: EGNNConfig):
    dt = dtype_of(cfg.param_dtype)
    d, de = cfg.d_hidden, cfg.d_edge
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    layers = []
    for l in range(cfg.n_layers):
        k_e, k_x, k_h = ks[3 * l: 3 * l + 3]
        layers.append({
            "phi_e": mlp_init(k_e, (2 * d + 1 + de, d, d), dt),
            "phi_x": mlp_init(k_x, (d, d, 1), dt),
            "phi_h": mlp_init(k_h, (2 * d, d, d), dt),
        })
    return {
        "encoder": mlp_init(ks[-2], (cfg.d_feat_in, d), dt),
        "layers": layers,
        "decoder": mlp_init(ks[-1], (d, d, cfg.n_classes), dt),
    }


def egnn_param_logical(cfg: EGNNConfig):
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "phi_e": mlp_specs((0, 0, 0)),
            "phi_x": mlp_specs((0, 0, 0)),
            "phi_h": mlp_specs((0, 0, 0)),
        })
    return {
        "encoder": mlp_specs((0, 0)),
        "layers": layers,
        "decoder": mlp_specs((0, 0, 0)),
    }


def _layer(p, h, x, g: Graph, ctx: ShardingCtx, cfg: EGNNConfig):
    n = h.shape[0]
    mdt = dtype_of(cfg.message_dtype)
    s = jnp.maximum(g.senders, 0)
    r = jnp.maximum(g.receivers, 0)
    emask = g.edge_mask[:, None].astype(mdt)

    # gathers move `message_dtype` across edge shards (bf16 halves the
    # all-gather/all-reduce wire bytes on collective-bound full graphs)
    hm = h.astype(mdt)
    h_s = hm[s]
    h_r = hm[r]
    xm = x.astype(mdt)
    dx = xm[r] - xm[s]                                 # (E, 3)
    dist2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
    feats = [h_r, h_s, dist2.astype(mdt)]
    if g.edge_attr.shape[-1]:
        feats.append(g.edge_attr.astype(mdt))
    pm = jax.tree.map(lambda a: a.astype(mdt), p)
    m = mlp_apply(pm["phi_e"], jnp.concatenate(feats, -1),
                  act=jax.nn.silu, final_act=True)     # (E, d)
    m = m * emask
    m = ctx.constrain(m, ("edges", None))

    # coordinate update (equivariant): x_i += mean_j (x_i - x_j) * phi_x(m_ij)
    w = mlp_apply(pm["phi_x"], m, act=jax.nn.silu)     # (E, 1)
    wdx = dx * w * emask
    deg = jax.ops.segment_sum(emask[:, 0].astype(jnp.float32), r,
                              num_segments=n) + 1.0
    x = x + jax.ops.segment_sum(wdx.astype(jnp.float32), r,
                                num_segments=n) / deg[:, None]

    # feature update: scatter in message dtype, accumulate result in f32
    agg = jax.ops.segment_sum(m, r, num_segments=n)    # (N, d)
    agg = ctx.constrain(agg, ("nodes", None))
    h = h + mlp_apply(p["phi_h"],
                      jnp.concatenate([h, agg.astype(h.dtype)], -1),
                      act=jax.nn.silu)
    return h, x


def egnn_forward(params, g: Graph, cfg: EGNNConfig,
                 ctx: ShardingCtx = NULL_CTX) -> Tuple[Array, Array]:
    """Returns (logits (N, n_classes), coords' (N, 3))."""
    h = mlp_apply(params["encoder"], g.nodes.astype(dtype_of(cfg.param_dtype)))
    h = ctx.constrain(h, ("nodes", None))
    x = g.coords.astype(h.dtype)
    for p in params["layers"]:
        h, x = _layer(p, h, x, g, ctx, cfg)
    logits = mlp_apply(params["decoder"], h, act=jax.nn.silu)
    return logits, x


def egnn_loss(params, g: Graph, cfg: EGNNConfig,
              ctx: ShardingCtx = NULL_CTX):
    """Masked node-classification cross-entropy (labels -1 ignored)."""
    logits, _ = egnn_forward(params, g, cfg, ctx)
    lf = logits.astype(jnp.float32)
    valid = (g.labels >= 0) & g.node_mask
    safe = jnp.maximum(g.labels, 0)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe[:, None], axis=-1)[:, 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / n
    acc = jnp.where(valid, (lf.argmax(-1) == safe), False).sum() / n
    return loss, {"loss": loss, "acc": acc, "n": n}

"""Shared HLO-artifact analysis: collective-byte parsing + roofline terms.

No jax imports and no env side effects — safe to import from both
launch/dryrun.py and launch/costs.py (each of which must set XLA_FLAGS
before importing jax themselves).
"""

from __future__ import annotations

import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (conservative 1-link figure)

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[d0,d1,...]' (or tuple thereof) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str):
    """Sum output-operand bytes of every collective op in partitioned HLO.

    Async '-start'/'-done' pairs are counted once (at the start op).
    NOTE: ops inside while-loop bodies appear once in the text; callers that
    lower scanned programs must account for trip counts themselves (the
    exact-cost pass lowers single layers, where this is a non-issue).
    """
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue
        base = op.replace("-start", "")
        if base not in COLLECTIVES:
            continue
        out[base] += shape_bytes(m.group(1))
        counts[base] += 1
    return out, counts


def roofline(flops, hbm_bytes, coll_bytes, n_chips):
    """Per-device roofline terms in seconds."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant, "n_chips": n_chips}

"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --smoke --steps 100 --ckpt-dir /tmp/run1

Resolves ``--arch`` through the registry, builds the data pipeline for the
family, constructs the (elastic) mesh from whatever devices are alive, and
drives the fault-tolerant TrainLoop (restart-aware; async checkpoints;
emergency checkpoint on interrupt).  ``--smoke`` selects the reduced config
so the launcher is exercisable on one CPU; on a real slice the full config
plus the logical sharding rules produce the same program the dry-run
validated.
"""

from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import family_of, get_arch
from repro.data import lm_batch_stream, recsys_batch_stream
from repro.launch.mesh import make_elastic_mesh
from repro.models import egnn as EG
from repro.models import lm as LM
from repro.models import recsys as RS
from repro.models.graph import random_graph
from repro.sharding.specs import NULL_CTX, make_ctx
from repro.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true",
                    help="bf16 gradients before the DP reduction")
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.SMOKE_CONFIG if args.smoke else mod.CONFIG
    fam = family_of(args.arch)
    rng = np.random.default_rng(0)

    n_dev = len(jax.devices())
    ctx = NULL_CTX
    if n_dev > 1:
        mesh = make_elastic_mesh()
        ctx = make_ctx(mesh)
        print(f"[launch] elastic mesh: {dict(mesh.shape)}")

    if fam == "lm":
        data = lm_batch_stream(rng, cfg.vocab, args.batch, args.seq)
        loss_fn = lambda p, b: LM.lm_loss(p, b, cfg, ctx)
        init_fn = lambda: LM.init_lm(jax.random.PRNGKey(0), cfg)
    elif fam == "gnn":
        g = random_graph(rng, 256, 1024, cfg.d_feat_in or 16,
                         n_classes=cfg.n_classes)
        def gen():
            while True:
                yield g
        data = gen()
        loss_fn = lambda p, b: EG.egnn_loss(p, b, cfg, ctx)
        init_fn = lambda: EG.egnn_init(jax.random.PRNGKey(0), cfg)
    else:
        data = recsys_batch_stream(rng, cfg.family, args.batch,
                                   n_sparse=cfg.n_sparse or 6,
                                   vocab=cfg.vocab_per_field,
                                   n_dense=cfg.n_dense or 13,
                                   seq_len=cfg.seq_len or 10)
        loss_fn = lambda p, b: RS.recsys_loss(p, b, cfg, ctx)
        init_fn = lambda: RS.recsys_init(jax.random.PRNGKey(0), cfg)

    loop = TrainLoop(
        loss_fn, init_fn, data,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 5, 10),
        log_every=10, base_lr=args.lr, warmup=max(args.steps // 10, 5),
        total_steps=args.steps, accum_steps=args.accum,
        grad_dtype="bfloat16" if args.grad_compress else None)
    metrics = loop.run(args.steps)
    print(f"[launch] done: {metrics}")


if __name__ == "__main__":
    main()

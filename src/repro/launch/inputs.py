"""Per-(architecture x shape) dry-run cell construction.

``build_cell(arch, shape_name, mesh)`` returns everything the dry-run needs:

    fn              — the function to lower (train_step / prefill / decode /
                      serve / retrieval)
    args            — pytree of jax.ShapeDtypeStruct stand-ins (no allocation)
    in_shardings    — matching NamedSharding pytree
    out_shardings   — or None (inferred)
    donate_argnums  — buffers the step may reuse (params/opt/cache)

Everything here is *abstract*: params come from ``jax.eval_shape`` over the
real initializers, so the lowered program is byte-identical to what a real
run would execute (REPRO_NO_PALLAS=1 is set by dryrun.py so the jnp
reference paths — not interpret-mode Pallas — are lowered).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple


import jax
import jax.numpy as jnp

from repro.configs import family_of, get_arch
from repro.configs.base import ShapeSpec
from repro.core.schedule import make_schedule
from repro.models import egnn as EG
from repro.models import lm as LM
from repro.models import recsys as RS
from repro.models.graph import Graph
from repro.optim import adamw_init
from repro.optim.adamw import opt_state_logical
from repro.sharding.specs import make_ctx
from repro.train.loop import make_train_step

SDS = jax.ShapeDtypeStruct


class Cell(NamedTuple):
    fn: Any
    args: tuple
    in_shardings: tuple
    out_shardings: Any              # None -> inferred
    donate_argnums: tuple
    meta: Dict[str, Any]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ------------------------------------------------------------------ LM ----

_LM_RULES_BY_KIND = {
    "train": {"seq_act": ("model",)},
    "prefill": {"seq_act": ("model",), "kv_seq": ("model",)},
    "decode": {"kv_seq": ("model",)},
    "decode_long": {"kv_seq": ("pod", "data", "model"), "batch": ()},
}

# per-device HBM budget for deciding whether FSDP (params sharded over
# 'data', gathered per layer) is actually needed: bf16 params + bf16 grads
# + fp32 adam moments = 12 B/param, sharded over the 'model' axis only.
_FSDP_BYTES_PER_PARAM = 12
_FSDP_HBM_BUDGET = 12e9


def lm_rules_for(cfg, kind: str, mesh) -> dict:
    """Sharding-rule overrides for an LM cell.

    Size-aware FSDP (§Perf): a 3-12B dense model's full training state fits
    per-device when sharded over 'model' alone, so the per-layer ZeRO-3
    weight gathers (the dominant collective for mistral train) are pure
    waste — drop the 'embed -> data' rule and pay only the gradient
    all-reduce.  The ~235B MoEs keep FSDP (state would be ~90 GB/device
    without it).
    """
    rules = dict(_LM_RULES_BY_KIND[kind])
    n_model = mesh.shape.get("model", 1)
    state_bytes = cfg.param_count() * _FSDP_BYTES_PER_PARAM / n_model
    if kind == "train" and state_bytes < _FSDP_HBM_BUDGET:
        rules["embed"] = ()
    return rules


def _cache_logical_by_ndim(leaf_ndim: int):
    if leaf_ndim == 5:       # (L, B, Hkv, S, Dh)
        return ("layers", "batch", "kv_heads", "kv_seq", None)
    if leaf_ndim == 4:       # (L, B, S, rank) — MLA latent
        return ("layers", "batch", "kv_seq", None)
    raise ValueError(leaf_ndim)


def _lm_cell(arch: str, shape: ShapeSpec, mesh) -> Cell:
    cfg = get_arch(arch).CONFIG
    kind = shape.kind
    if kind == "decode" and shape.seq_len >= 262144:
        rules = _LM_RULES_BY_KIND["decode_long"]
    else:
        rules = lm_rules_for(cfg, kind, mesh)
    ctx = make_ctx(mesh, rules)

    params = jax.eval_shape(lambda: LM.init_lm(jax.random.PRNGKey(0), cfg))
    logical = LM.lm_param_logical(cfg)
    pshard = ctx.tree_shardings(logical, params)

    if kind == "train":
        opt = jax.eval_shape(lambda: adamw_init(params))
        oshard = ctx.tree_shardings(opt_state_logical(logical), opt)
        batch = {"tokens": SDS((shape.global_batch, shape.seq_len + 1),
                               jnp.int32)}
        bshard = {"tokens": ctx.sharding(
            ("batch", None), (shape.global_batch, shape.seq_len + 1))}
        step = make_train_step(
            lambda p, b: LM.lm_loss(p, b, cfg, ctx), jit=False,
            grad_dtype="bfloat16")
        return Cell(
            fn=step, args=(params, opt, batch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
            meta={"kind": "train",
                  "tokens": shape.global_batch * shape.seq_len},
        )

    if kind == "prefill":
        tokens = SDS((shape.global_batch, shape.seq_len), jnp.int32)
        tshard = ctx.sharding(("batch", None), tokens.shape)
        cache_shape = jax.eval_shape(
            lambda p, t: LM.prefill(p, t, cfg, ctx), params, tokens)[1]
        cshard = jax.tree.map(
            lambda l: ctx.sharding(_cache_logical_by_ndim(l.ndim), l.shape),
            cache_shape)
        fn = functools.partial(LM.prefill, cfg=cfg, ctx=ctx)
        return Cell(
            fn=lambda p, t: fn(p, t),
            args=(params, tokens),
            in_shardings=(pshard, tshard),
            out_shardings=(None, cshard),
            donate_argnums=(),
            meta={"kind": "prefill",
                  "tokens": shape.global_batch * shape.seq_len},
        )

    # decode
    cache = jax.eval_shape(
        lambda: LM.init_cache(cfg, shape.global_batch, shape.seq_len))
    cshard = jax.tree.map(
        lambda l: ctx.sharding(_cache_logical_by_ndim(l.ndim), l.shape),
        cache)
    tokens = SDS((shape.global_batch, 1), jnp.int32)
    tshard = ctx.sharding(("batch", None), tokens.shape)
    pos = SDS((), jnp.int32)

    def fn(p, c, t, pos):
        return LM.decode_step(p, c, t, pos, cfg, ctx)

    return Cell(
        fn=fn, args=(params, cache, tokens, pos),
        in_shardings=(pshard, cshard, tshard, None),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
        meta={"kind": "decode", "tokens": shape.global_batch},
    )


# ----------------------------------------------------------------- GNN ----

def _graph_sds(n_nodes: int, n_edges: int, d_feat: int, mesh,
               n_classes: int) -> Tuple[Graph, Graph]:
    nodes_pad = _round_up(n_nodes, 512)
    edges_pad = _round_up(n_edges, 512)
    g = Graph(
        nodes=SDS((nodes_pad, d_feat), jnp.float32),
        coords=SDS((nodes_pad, 3), jnp.float32),
        senders=SDS((edges_pad,), jnp.int32),
        receivers=SDS((edges_pad,), jnp.int32),
        edge_attr=SDS((edges_pad, 0), jnp.float32),
        node_mask=SDS((nodes_pad,), jnp.bool_),
        edge_mask=SDS((edges_pad,), jnp.bool_),
        labels=SDS((nodes_pad,), jnp.int32),
    )
    ctx = make_ctx(mesh)
    shard = Graph(
        nodes=ctx.sharding(("nodes", None), g.nodes.shape),
        coords=ctx.sharding(("nodes", None), g.coords.shape),
        senders=ctx.sharding(("edges",), g.senders.shape),
        receivers=ctx.sharding(("edges",), g.receivers.shape),
        edge_attr=ctx.sharding(("edges", None), g.edge_attr.shape),
        node_mask=ctx.sharding(("nodes",), g.node_mask.shape),
        edge_mask=ctx.sharding(("edges",), g.edge_mask.shape),
        labels=ctx.sharding(("nodes",), g.labels.shape),
    )
    return g, shard


def _gnn_cell(arch: str, shape: ShapeSpec, mesh) -> Cell:
    base = get_arch(arch).CONFIG
    ctx = make_ctx(mesh)

    if shape.name == "minibatch_lg":
        f = shape.fanout
        n_nodes = shape.batch_nodes * (1 + f[0] + f[0] * f[1])
        n_edges = shape.batch_nodes * f[0] + shape.batch_nodes * f[0] * f[1]
        d_feat = shape.d_feat
    elif shape.name == "molecule":
        n_nodes = shape.graph_batch * shape.n_nodes
        n_edges = shape.graph_batch * shape.n_edges
        d_feat = shape.d_feat
    else:
        n_nodes, n_edges, d_feat = shape.n_nodes, shape.n_edges, shape.d_feat

    # NOTE (§Perf): bf16 messages / bf16 params / replicated-node layouts
    # were each measured and did NOT reduce the collective term — GSPMD's
    # node<->edge resharding falls back to replicate+repartition in f32
    # (involuntary-remat warning).  Baseline layout retained; the real fix
    # is shard_map message passing with explicit psum (future work).
    cfg = dataclasses.replace(base, d_feat_in=d_feat)
    params = jax.eval_shape(lambda: EG.egnn_init(jax.random.PRNGKey(0), cfg))
    logical = EG.egnn_param_logical(cfg)
    pshard = ctx.tree_shardings(logical, params)
    opt = jax.eval_shape(lambda: adamw_init(params))
    oshard = ctx.tree_shardings(
        opt_state_logical(logical), opt)
    g, gshard = _graph_sds(n_nodes, n_edges, d_feat, mesh, cfg.n_classes)

    step = make_train_step(lambda p, b: EG.egnn_loss(p, b, cfg, ctx),
                           jit=False)
    return Cell(
        fn=step, args=(params, opt, g),
        in_shardings=(pshard, oshard, gshard),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
        meta={"kind": "train", "edges": n_edges, "nodes": n_nodes},
    )


# -------------------------------------------------------------- recsys ----

def _recsys_batch_sds(cfg, batch: int, mesh, ctx) -> Tuple[dict, dict]:
    b = {}
    if cfg.family == "two_tower":
        nf = max(cfg.n_sparse // 2, 1)
        b["user_ids"] = SDS((batch, nf, cfg.multi_hot), jnp.int32)
        b["item_ids"] = SDS((batch, nf, cfg.multi_hot), jnp.int32)
    elif cfg.family == "din":
        b["hist"] = SDS((batch, cfg.seq_len), jnp.int32)
        b["target"] = SDS((batch,), jnp.int32)
        b["label"] = SDS((batch,), jnp.float32)
    else:
        b["ids"] = SDS((batch, cfg.n_sparse, cfg.multi_hot), jnp.int32)
        b["label"] = SDS((batch,), jnp.float32)
        if cfg.family == "dlrm":
            b["dense"] = SDS((batch, cfg.n_dense), jnp.float32)
    shard = {k: ctx.sharding(("batch",) + (None,) * (v.ndim - 1), v.shape)
             for k, v in b.items()}
    return b, shard


def _recsys_cell(arch: str, shape: ShapeSpec, mesh) -> Cell:
    cfg = get_arch(arch).CONFIG
    ctx = make_ctx(mesh)
    params = jax.eval_shape(lambda: RS.recsys_init(jax.random.PRNGKey(0), cfg))
    logical = RS.recsys_param_logical(cfg, params)
    pshard = ctx.tree_shardings(logical, params)

    if shape.name == "train_batch":
        opt = jax.eval_shape(lambda: adamw_init(params))
        oshard = ctx.tree_shardings(opt_state_logical(logical), opt)
        batch, bshard = _recsys_batch_sds(cfg, shape.global_batch, mesh, ctx)
        step = make_train_step(lambda p, b: RS.recsys_loss(p, b, cfg, ctx),
                               jit=False)
        return Cell(
            fn=step, args=(params, opt, batch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
            meta={"kind": "train", "examples": shape.global_batch},
        )

    if shape.name in ("serve_p99", "serve_bulk"):
        batch, bshard = _recsys_batch_sds(cfg, shape.global_batch, mesh, ctx)
        if cfg.family == "two_tower":
            def fn(p, b):
                u = RS.tower_user(p, b["user_ids"], ctx)
                v = RS.tower_item(p, b["item_ids"], ctx)
                return jnp.einsum("bd,bd->b", u, v)
        else:
            def fn(p, b):
                return RS.recsys_forward(p, b, cfg, ctx)
        return Cell(
            fn=fn, args=(params, batch),
            in_shardings=(pshard, bshard), out_shardings=None,
            donate_argnums=(),
            meta={"kind": "serve", "examples": shape.global_batch},
        )

    # retrieval_cand: 1 query vs n_candidates
    c = shape.n_candidates
    if cfg.family == "two_tower":
        # The paper's workload: progressive search over the item-embedding
        # DB, with the staged-index layout (§Perf): the stage-0 prefix is a
        # contiguous bf16 (C, Ds) block so the full-corpus scan streams only
        # Ds·2 bytes/row instead of D·4.
        from repro.core.distributed import build_sharded_search_staged
        d_emb = cfg.tower_mlp[-1]
        sched = make_schedule(cfg.retrieval_d_start, d_emb, cfg.retrieval_k0)
        db_axes = _batch_axes(mesh)
        db0 = SDS((c, sched.stages[0].dim), jnp.bfloat16)
        db = SDS((c, d_emb), jnp.float32)
        sqp = SDS((c, 1), jnp.float32)
        nf = max(cfg.n_sparse // 2, 1)
        user_ids = SDS((8, nf, cfg.multi_hot), jnp.int32)
        search = build_sharded_search_staged(mesh, sched, c, db_axes=db_axes)

        def fn(p, uids, db0, db, sqp):
            q = RS.tower_user(p, uids, ctx).astype(jnp.float32)
            return search(q, db0, db, sqp)

        return Cell(
            fn=fn, args=(params, user_ids, db0, db, sqp),
            in_shardings=(pshard, None,
                          ctx.sharding(("rows", None), db0.shape),
                          ctx.sharding(("rows", None), db.shape),
                          ctx.sharding(("rows", None), sqp.shape)),
            out_shardings=None, donate_argnums=(),
            meta={"kind": "retrieval", "candidates": c,
                  "schedule": sched.describe(), "staged_index": True},
        )

    batch, bshard = _recsys_batch_sds(cfg, 1, mesh, ctx)
    batch.pop("label", None)
    bshard.pop("label", None)
    cand = SDS((c,), jnp.int32)
    cshard = ctx.sharding(("cand",), cand.shape)

    def fn(p, b, cand):
        return RS.serve_candidates(p, b, cand, cfg, ctx)

    return Cell(
        fn=fn, args=(params, batch, cand),
        in_shardings=(pshard, bshard, cshard),
        out_shardings=None, donate_argnums=(),
        meta={"kind": "retrieval", "candidates": c},
    )


# ------------------------------------------------------------- factory ----

def build_cell(arch: str, shape_name: str, mesh) -> Optional[Cell]:
    """Returns None for documented skips (shape.skip_reason non-empty)."""
    mod = get_arch(arch)
    shape = mod.SHAPES[shape_name]
    if shape.skip_reason:
        return None
    fam = family_of(arch)
    if fam == "lm":
        return _lm_cell(arch, shape, mesh)
    if fam == "gnn":
        return _gnn_cell(arch, shape, mesh)
    return _recsys_cell(arch, shape, mesh)


def input_specs(arch: str, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of a cell (public
    helper mirroring the shannon/kernels pattern)."""
    cell = build_cell(arch, shape_name, mesh)
    return None if cell is None else cell.args

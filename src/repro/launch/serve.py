"""Serving launcher: RAG pipeline over a synthetic corpus with batched
request replay and latency percentiles.

    PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import lm as LM
from repro.rag import RAGPipeline
from repro.rag.pipeline import mean_pool_embedder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = LMConfig(name="serve-lm", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, d_head=16, d_ff=256, vocab=2048,
                   param_dtype="float32", compute_dtype="float32",
                   remat=False)
    rng = np.random.default_rng(0)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    doc_tokens = jnp.asarray(rng.integers(1, cfg.vocab, (args.docs, 24)),
                             jnp.int32)
    db = mean_pool_embedder(params, cfg)(doc_tokens)
    pipe = RAGPipeline(params, cfg, db, doc_tokens, d_start=16, k0=32)

    gt = rng.choice(args.docs, args.requests)
    queries = np.asarray(doc_tokens[gt])
    lat = []
    hits = 0
    for i in range(0, args.requests, args.batch):
        qb = jnp.asarray(queries[i:i + args.batch], jnp.int32)
        t0 = time.perf_counter()
        out = pipe.serve(qb, max_new_tokens=args.new_tokens)
        jax.block_until_ready(out["generated"])
        lat.append(time.perf_counter() - t0)
        hits += int((np.asarray(out["retrieved"][:, 0])
                     == gt[i:i + args.batch]).sum())
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {args.requests} requests, batch={args.batch}: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"hit-rate={hits/args.requests*100:.1f}%")


if __name__ == "__main__":
    main()

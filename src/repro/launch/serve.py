"""Serving launcher: closed-loop RAG demo, HTTP server mode, or HTTP client.

Three modes sharing one engine flag surface (``EngineConfig.add_flags``):

* default (closed loop) — RAG pipeline over a synthetic corpus, driven by
  the async engine driver under multi-threaded client traffic.
  ``--clients N`` spawns N open-loop client threads that submit single
  requests through the driver (optionally rate-paced with ``--qps``); the
  driver's background thread coalesces them into shape-bucketed batches
  with a deadline flush (``--max-wait-ms`` is the latency/throughput knob).

      PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 8 \
          --clients 8 --max-wait-ms 2

* ``--serve-http`` — boot the `repro.serve` HTTP front-end over a fresh
  engine (empty corpus; clients add docs over the wire) and serve until
  interrupted.  Tenancy is on by default (``--allow-anonymous`` turns the
  tenant requirement off); ``--max-inflight`` / ``--max-docs-per-tenant``
  set the admission quotas.

      PYTHONPATH=src python -m repro.launch.serve --serve-http --port 8080 \
          --backend ivf --d-emb 128

* ``--connect URL`` — open-loop HTTP client against a running server:
  seeds ``--docs`` random documents under ``--tenant``, then drives
  ``--requests`` searches from ``--clients`` threads and reports QPS and
  latency percentiles.

      PYTHONPATH=src python -m repro.launch.serve \
          --connect http://127.0.0.1:8080 --requests 256 --clients 8
"""

from __future__ import annotations

import argparse
import json
import signal
import threading
import time
import urllib.error
import urllib.request

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.engine import EngineConfig, EngineDriver, RetrievalEngine
from repro.models import lm as LM
from repro.rag import RAGPipeline
from repro.rag.pipeline import mean_pool_embedder


def run_clients(driver, qvecs, n_clients: int, qps: float,
                timeout: float = 120.0):
    """Submit every query from ``n_clients`` open-loop threads.

    Each thread owns a shard of the request stream and submits without
    waiting for results (open loop) — at full speed, or paced so the
    threads jointly target ``qps`` — then gathers its futures.  Returns
    (results in submission order, wall seconds).
    """
    results = [None] * len(qvecs)
    errors = []
    shards = np.array_split(np.arange(len(qvecs)), n_clients)
    period = n_clients / qps if qps > 0 else 0.0
    barrier = threading.Barrier(n_clients + 1)

    def client(shard):
        try:
            barrier.wait()
            futures = []
            t_next = time.perf_counter()
            for i in shard:
                if period:
                    now = time.perf_counter()
                    if now < t_next:
                        time.sleep(t_next - now)
                    t_next += period
                futures.append((i, driver.submit(qvecs[i], timeout=timeout)))
            for i, fut in futures:
                results[i] = fut.result(timeout)
        except Exception as e:                    # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=client, args=(s,), daemon=True)
               for s in shards if len(s)]
    for t in threads:
        t.start()
    barrier.wait()                                # release all clients at once
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return results, wall


def http_json(url: str, path: str, body=None, method: str = "GET",
              timeout: float = 60.0):
    """One JSON round trip; returns (status, payload)."""
    data = None if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        url.rstrip("/") + path, data=data,
        method=method if body is None else "POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def serve_http(args) -> None:
    """Boot the HTTP front-end over a fresh engine and block until ^C.

    ``--role`` picks the replication mode: ``single`` (default) and
    ``primary`` own the WAL under ``--state-dir`` and serve mutations;
    ``follower`` shares the same ``--state-dir``, bootstraps read-only
    from its newest snapshot, and tails the primary's WAL — mutations get
    403, searches wait on ``min_seq`` tokens.
    """
    from repro.engine import PrimaryReplication, ReplicaApplier
    from repro.serve import TenantQuotas, serve_in_thread

    role = args.role if args.role in ("primary", "follower") else "single"
    if role != "single" and not args.state_dir:
        raise SystemExit(f"--role={role} needs --state-dir (the WAL-shipped "
                         "replication channel is the shared state dir)")
    config = EngineConfig.from_flags(args, d_emb=args.d_emb,
                                     capacity=max(args.docs, 1024))
    engine = RetrievalEngine(config=config)
    replication = None
    applier = None
    if role == "follower":
        applier = ReplicaApplier(engine, args.state_dir)
        report = applier.bootstrap()
        applier.start()
        replication = applier
        print(f"[state]  follower of {args.state_dir}: "
              f"(snapshot={report['snapshot_step']} "
              f"fallbacks={report['fallbacks']} "
              f"in {report['duration_ms']:.1f}ms), tailing WAL")
    elif args.state_dir:
        report = engine.recover(args.state_dir)
        replication = PrimaryReplication(engine)
        print(f"[state]  {args.state_dir}: {report['status']} "
              f"(snapshot={report['snapshot_step']} "
              f"replayed={report['replayed']} "
              f"fallbacks={report['fallbacks']} "
              f"in {report['duration_ms']:.1f}ms)")
    driver = EngineDriver(engine, max_wait_ms=args.max_wait_ms,
                          max_queue=args.max_queue)
    driver.start(supervised=args.supervise)
    supervisor = None
    if args.supervise:
        from repro.engine import Supervisor
        supervisor = Supervisor(driver).start()
        print(f"[watch]  supervisor on (heartbeat timeout "
              f"{config.fault.heartbeat_timeout_s:g}s, max "
              f"{config.fault.max_restarts} restarts)")
    quotas = TenantQuotas(
        max_inflight=args.max_inflight if args.max_inflight > 0 else None,
        max_docs=(args.max_docs_per_tenant
                  if args.max_docs_per_tenant > 0 else None))
    handle = serve_in_thread(
        engine, driver, quotas=quotas,
        require_tenant=not args.allow_anonymous,
        host=args.host, port=args.port,
        replication=replication, read_only=(role == "follower"))
    print(f"[engine] {engine.describe()}")
    print(f"[driver] {driver.describe()}")
    print(f"[http]   serving on {handle.url} role={role} "
          f"(tenancy {'optional' if args.allow_anonymous else 'required'})")
    # SIGTERM (kill, container stop) must take the same graceful path as
    # ^C: drain the driver and cut a final snapshot before exiting
    def _sigterm(signum, frame):
        raise KeyboardInterrupt

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        while True:
            time.sleep(max(args.snapshot_every_s, 0) or 3600)
            if args.state_dir and role != "follower" \
                    and args.snapshot_every_s > 0:
                step = engine.save_snapshot()
                print(f"[state]  snapshot step {step}")
    except KeyboardInterrupt:
        print("\n[http]   shutting down")
    finally:
        handle.stop()
        if supervisor is not None:
            supervisor.stop()
        driver.stop()
        if applier is not None:
            applier.stop()
        elif args.state_dir:
            # followers never snapshot — the primary owns the state dir
            engine.save_snapshot()
            engine.wal.close()


def serve_router(args) -> None:
    """Boot the replica-routing front door over ``--replicas`` and block."""
    from repro.serve import (ReplicaRouter, RetryPolicy, RouterHTTPServer,
                             run_server_in_thread)

    urls = [u.strip() for u in args.replicas.split(",") if u.strip()]
    if not urls:
        raise SystemExit("--role=router needs --replicas URL[,URL...]")
    router = ReplicaRouter(
        urls,
        probe_interval_s=args.probe_interval_s,
        hedge_ms=args.hedge_ms if args.hedge_ms >= 0 else None,
        retry=RetryPolicy(max_attempts=args.retries),
    ).start()
    handle = run_server_in_thread(RouterHTTPServer(
        router, host=args.host, port=args.port), thread_name="router-http")
    print(f"[router] serving on {handle.url} over {len(urls)} replicas "
          f"(probe every {args.probe_interval_s:g}s, hedge_ms="
          f"{args.hedge_ms if args.hedge_ms >= 0 else 'off'})")

    def _sigterm(signum, frame):
        raise KeyboardInterrupt

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        print("\n[router] shutting down")
    finally:
        handle.stop()
        router.stop()


def connect_client(args) -> None:
    """Open-loop HTTP client: seed docs, then drive concurrent searches.

    Shares the router's failure discipline: every call carries a
    ``deadline_ms`` and retries 503/504/connection errors with jittered
    backoff (`repro.serve.RetryPolicy`) — 4xx responses are never retried,
    and seeding mutations only retry explicit 503/504 (a dropped
    connection mid-mutation may already have applied).
    """
    from repro.serve import RetryPolicy, http_call

    url = args.connect
    retry = RetryPolicy(max_attempts=max(1, args.retries))
    deadline_ms = args.deadline_ms if args.deadline_ms > 0 else None
    timeout = (deadline_ms / 1e3 + 5.0) if deadline_ms else 60.0

    def call(path, body=None, *, mutation=False):
        def attempt(_n):
            status, payload = http_call(url, path, body, timeout=timeout)
            if mutation and status == 0:
                # ambiguous: the server may have applied it — never re-send;
                # -1 is not retryable, so run() returns it straight through
                return -1, payload
            return status, payload
        status, payload = retry.run(attempt, sleep=time.sleep)
        return (0, payload) if status == -1 else (status, payload)

    status, health = call("/healthz")
    if status != 200:
        raise SystemExit(f"server unhealthy: {status} {health}")
    rng = np.random.default_rng(0)
    d = args.d_emb
    min_seq = None
    if args.docs:
        docs = rng.standard_normal((args.docs, d)).astype(np.float32)
        status, added = call("/v1/docs", {
            "vectors": docs.tolist(), "tenant": args.tenant}, mutation=True)
        if status != 200:
            raise SystemExit(f"seed add failed: {status} {added}")
        min_seq = added.get("seq")
        print(f"[seed]   {added['n_added']} docs under {args.tenant!r}"
              + (f" (seq={min_seq})" if min_seq is not None else ""))
    queries = rng.standard_normal((args.requests, d)).astype(np.float32)
    lat = [None] * args.requests
    codes = [0] * args.requests
    shards = np.array_split(np.arange(args.requests),
                            max(1, min(args.clients, args.requests)))
    barrier = threading.Barrier(len([s for s in shards if len(s)]) + 1)

    def client(shard):
        barrier.wait()
        for i in shard:
            body = {"query": queries[i].tolist(), "tenant": args.tenant,
                    "k": args.final_k}
            if deadline_ms:
                body["deadline_ms"] = deadline_ms
            if min_seq is not None:
                body["min_seq"] = min_seq
            t0 = time.perf_counter()
            codes[i], _ = call("/v1/search", body)
            lat[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=client, args=(s,), daemon=True)
               for s in shards if len(s)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lat_ms = np.asarray([x for x in lat if x is not None]) * 1e3
    n_ok = sum(1 for c in codes if c == 200)
    print(f"[client] {args.requests} requests, {len(threads)} threads: "
          f"qps={args.requests / wall:.1f} "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"ok={n_ok}/{args.requests}")
    if n_ok != args.requests:
        raise SystemExit(1)


def closed_loop(args) -> None:
    """The original demo: RAG pipeline + driver under threaded clients."""
    cfg = LMConfig(name="serve-lm", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, d_head=16, d_ff=256, vocab=2048,
                   param_dtype="float32", compute_dtype="float32",
                   remat=False)
    rng = np.random.default_rng(0)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    doc_tokens = jnp.asarray(rng.integers(1, cfg.vocab, (args.docs, 24)),
                             jnp.int32)
    embed = mean_pool_embedder(params, cfg)
    db = embed(doc_tokens)
    econf = EngineConfig.from_flags(args, d_emb=int(db.shape[1]))
    pipe = RAGPipeline(params, cfg, db, doc_tokens,
                       d_start=econf.d_start, k0=econf.k0,
                       buckets=econf.buckets,
                       backend=econf.backend.name,
                       backend_opts=econf.backend.opts() or None)
    engine = pipe.engine
    print(f"[engine]   {engine.describe()}")

    gt = rng.choice(args.docs, args.requests)
    queries = np.asarray(doc_tokens[gt])
    qvecs = np.asarray(embed(jnp.asarray(queries)))

    # Warm the bucket ladder so steady-state percentiles exclude compiles.
    engine.warmup()

    # --- retrieval: N client threads -> async driver -> coalesced batches --
    n_clients = max(1, min(args.clients, args.requests))
    driver = pipe.start_driver(max_wait_ms=args.max_wait_ms,
                               max_queue=args.max_queue)
    print(f"[driver]   {driver.describe()}")
    try:
        results, wall = run_clients(driver, qvecs, n_clients, args.qps)
    finally:
        pipe.stop_driver()
    retrieved = np.stack([r.doc_ids for r in results])
    hits = int((retrieved[:, 0] == gt).sum())
    s = engine.stats.summary()
    ds = driver.stats.summary()
    print(f"[retrieve] {args.requests} requests, {n_clients} clients, "
          f"max_wait={args.max_wait_ms:g}ms, buckets={econf.buckets}: "
          f"qps={args.requests / wall:.1f} "
          f"p50={s['latency_ms_p50']:.1f}ms p95={s['latency_ms_p95']:.1f}ms "
          f"batches={s['n_batches']} padded={s['n_padded_slots']} "
          f"flush(full/deadline/drain)={ds['n_flush_full']}/"
          f"{ds['n_flush_deadline']}/{ds['n_flush_drain']} "
          f"hit-rate={hits / args.requests * 100:.1f}%")

    # --- decode: fixed-size LM batches over the retrieved docs -------------
    lat = []
    for i in range(0, args.requests, args.batch):
        t0 = time.perf_counter()
        gen = pipe.generate(jnp.asarray(queries[i:i + args.batch], jnp.int32),
                            retrieved[i:i + args.batch],
                            max_new_tokens=args.new_tokens)
        jax.block_until_ready(gen)
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[decode]   batch={args.batch}: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8,
                    help="LM decode batch (retrieval batches via --buckets)")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent open-loop client threads")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="driver deadline: max wait for batch companions")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="aggregate open-loop submit rate (0 = full speed)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="driver pending-queue bound (backpressure)")
    ap.add_argument("--new-tokens", type=int, default=8)
    # HTTP server mode
    ap.add_argument("--serve-http", action="store_true",
                    help="serve the repro.serve HTTP API instead of the "
                         "closed-loop demo")
    ap.add_argument("--host", type=str, default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--d-emb", type=int, default=128,
                    help="embedding dim for --serve-http / --connect")
    ap.add_argument("--allow-anonymous", action="store_true",
                    help="accept tenantless requests (admin mode)")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="per-tenant concurrent-search cap (0 = unlimited)")
    ap.add_argument("--max-docs-per-tenant", type=int, default=0,
                    help="per-tenant live-document cap (0 = unlimited)")
    ap.add_argument("--state-dir", type=str, default="",
                    help="durable state directory: recover from the latest "
                         "valid snapshot + WAL tail on boot, log every "
                         "mutation, snapshot on shutdown")
    ap.add_argument("--snapshot-every-s", type=float, default=0.0,
                    help="with --state-dir: also snapshot every N seconds "
                         "(0 = only on shutdown)")
    ap.add_argument("--supervise", action="store_true",
                    help="watchdog the driver thread: restart it with "
                         "capped backoff if it dies or hangs")
    # replication / routing
    ap.add_argument("--replicas", type=str, default="",
                    help="--role=router: comma-separated replica base URLs "
                         "to spread searches across")
    ap.add_argument("--hedge-ms", type=float, default=-1.0,
                    help="--role=router: fire a hedged search after this "
                         "many ms (0 = adaptive p95, <0 = off)")
    ap.add_argument("--probe-interval-s", type=float, default=0.25,
                    help="--role=router: per-replica health-probe period")
    # HTTP client mode
    ap.add_argument("--connect", type=str, default="",
                    help="drive a running HTTP server at this URL instead "
                         "of serving locally")
    ap.add_argument("--tenant", type=str, default="bench",
                    help="--connect: tenant to seed and search under")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="--connect: propagate this per-request deadline "
                         "(0 = none)")
    ap.add_argument("--retries", type=int, default=3,
                    help="--connect/--role=router: max attempts per call "
                         "(retries only 503/504/connection errors)")
    EngineConfig.add_flags(ap)
    args = ap.parse_args()
    if args.serve_http and args.connect:
        raise SystemExit("--serve-http and --connect are mutually exclusive")
    if args.serve_http and args.role == "router":
        serve_router(args)
    elif args.serve_http:
        serve_http(args)
    elif args.connect:
        connect_client(args)
    else:
        closed_loop(args)


if __name__ == "__main__":
    main()

"""Serving launcher: RAG pipeline over a synthetic corpus, driven by the
async engine driver under multi-threaded client traffic.

``--clients N`` spawns N open-loop client threads that submit single
requests through the driver (optionally rate-paced with ``--qps``); the
driver's background thread coalesces them into shape-bucketed batches with a
deadline flush (``--max-wait-ms`` is the latency/throughput knob: 0 flushes
on arrival, larger values hold partial batches back for companions).  The
launcher reports retrieval QPS, the engine's per-request latency percentiles
(queue + compute split, compile events excluded by warmup), the driver's
flush-reason counters, and end-to-end decode latency.

    PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 8 \
        --clients 8 --max-wait-ms 2
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import lm as LM
from repro.rag import RAGPipeline
from repro.rag.pipeline import mean_pool_embedder


def run_clients(driver, qvecs, n_clients: int, qps: float,
                timeout: float = 120.0):
    """Submit every query from ``n_clients`` open-loop threads.

    Each thread owns a shard of the request stream and submits without
    waiting for results (open loop) — at full speed, or paced so the
    threads jointly target ``qps`` — then gathers its futures.  Returns
    (results in submission order, wall seconds).
    """
    results = [None] * len(qvecs)
    errors = []
    shards = np.array_split(np.arange(len(qvecs)), n_clients)
    period = n_clients / qps if qps > 0 else 0.0
    barrier = threading.Barrier(n_clients + 1)

    def client(shard):
        try:
            barrier.wait()
            futures = []
            t_next = time.perf_counter()
            for i in shard:
                if period:
                    now = time.perf_counter()
                    if now < t_next:
                        time.sleep(t_next - now)
                    t_next += period
                futures.append((i, driver.submit(qvecs[i], timeout=timeout)))
            for i, fut in futures:
                results[i] = fut.result(timeout)
        except Exception as e:                    # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=client, args=(s,), daemon=True)
               for s in shards if len(s)]
    for t in threads:
        t.start()
    barrier.wait()                                # release all clients at once
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return results, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8,
                    help="LM decode batch (retrieval batches via --buckets)")
    ap.add_argument("--buckets", type=str, default="1,2,4,8,16,32",
                    help="comma-separated static retrieval batch sizes")
    ap.add_argument("--backend", type=str, default="flat",
                    choices=("flat", "ivf", "quantized"),
                    help="index backend behind the retrieval engine")
    ap.add_argument("--use-kernel", type=str, default="auto",
                    choices=("auto", "true", "false"),
                    help="ivf/quantized-pq: fused Pallas stage-0 kernel "
                         "(auto = TPU only; true forces interpret mode on "
                         "CPU)")
    ap.add_argument("--stage0-dtype", type=str, default="float32",
                    choices=("float32", "int8", "pq"),
                    help="ivf only: member-slab dtype for the fused kernel "
                         "(pq = ADC lookup-table scan over PQ codes)")
    ap.add_argument("--codec", type=str, default="int8",
                    choices=("int8", "pq"),
                    help="quantized only: stage-0 code block codec")
    ap.add_argument("--pq-m", type=int, default=0,
                    help="PQ subspaces per row (0 = auto, aim 8-dim "
                         "subspaces); must divide the stage-0 dim")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent open-loop client threads")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="driver deadline: max wait for batch companions")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="aggregate open-loop submit rate (0 = full speed)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="driver pending-queue bound (backpressure)")
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = LMConfig(name="serve-lm", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, d_head=16, d_ff=256, vocab=2048,
                   param_dtype="float32", compute_dtype="float32",
                   remat=False)
    rng = np.random.default_rng(0)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    doc_tokens = jnp.asarray(rng.integers(1, cfg.vocab, (args.docs, 24)),
                             jnp.int32)
    embed = mean_pool_embedder(params, cfg)
    db = embed(doc_tokens)
    buckets = tuple(int(x) for x in args.buckets.split(","))
    backend_opts = None
    use_kernel = {"auto": "auto", "true": True,
                  "false": False}[args.use_kernel]
    if args.backend == "ivf":
        backend_opts = {
            "use_kernel": use_kernel,
            "stage0_dtype": args.stage0_dtype,
        }
        if args.stage0_dtype == "pq" and args.pq_m:
            backend_opts["pq_m"] = args.pq_m
    elif args.backend == "quantized":
        backend_opts = {"codec": args.codec, "use_kernel": use_kernel}
        if args.codec == "pq" and args.pq_m:
            backend_opts["pq_m"] = args.pq_m
    pipe = RAGPipeline(params, cfg, db, doc_tokens, d_start=16, k0=32,
                       buckets=buckets, backend=args.backend,
                       backend_opts=backend_opts)
    engine = pipe.engine
    print(f"[engine]   {engine.describe()}")

    gt = rng.choice(args.docs, args.requests)
    queries = np.asarray(doc_tokens[gt])
    qvecs = np.asarray(embed(jnp.asarray(queries)))

    # Warm the bucket ladder so steady-state percentiles exclude compiles.
    engine.warmup()

    # --- retrieval: N client threads -> async driver -> coalesced batches --
    n_clients = max(1, min(args.clients, args.requests))
    driver = pipe.start_driver(max_wait_ms=args.max_wait_ms,
                               max_queue=args.max_queue)
    print(f"[driver]   {driver.describe()}")
    try:
        results, wall = run_clients(driver, qvecs, n_clients, args.qps)
    finally:
        pipe.stop_driver()
    retrieved = np.stack([r.doc_ids for r in results])
    hits = int((retrieved[:, 0] == gt).sum())
    s = engine.stats.summary()
    ds = driver.stats.summary()
    print(f"[retrieve] {args.requests} requests, {n_clients} clients, "
          f"max_wait={args.max_wait_ms:g}ms, buckets={buckets}: "
          f"qps={args.requests / wall:.1f} "
          f"p50={s['latency_ms_p50']:.1f}ms p95={s['latency_ms_p95']:.1f}ms "
          f"batches={s['n_batches']} padded={s['n_padded_slots']} "
          f"flush(full/deadline/drain)={ds['n_flush_full']}/"
          f"{ds['n_flush_deadline']}/{ds['n_flush_drain']} "
          f"hit-rate={hits / args.requests * 100:.1f}%")

    # --- decode: fixed-size LM batches over the retrieved docs -------------
    lat = []
    for i in range(0, args.requests, args.batch):
        t0 = time.perf_counter()
        gen = pipe.generate(jnp.asarray(queries[i:i + args.batch], jnp.int32),
                            retrieved[i:i + args.batch],
                            max_new_tokens=args.new_tokens)
        jax.block_until_ready(gen)
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[decode]   batch={args.batch}: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms")


if __name__ == "__main__":
    main()

"""Serving launcher: RAG pipeline over a synthetic corpus, replaying
individual requests through the retrieval engine's queue.

Requests are submitted one at a time (as serving traffic arrives); the
engine coalesces them into shape-bucketed batches, so the launcher reports
both the retrieval engine's per-request latency percentiles (queue + compute
split, compile events excluded by warmup) and end-to-end decode latency.

    PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import lm as LM
from repro.rag import RAGPipeline
from repro.rag.pipeline import mean_pool_embedder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8,
                    help="LM decode batch (retrieval batches via --buckets)")
    ap.add_argument("--buckets", type=str, default="1,2,4,8,16,32",
                    help="comma-separated static retrieval batch sizes")
    ap.add_argument("--backend", type=str, default="flat",
                    choices=("flat", "ivf", "quantized"),
                    help="index backend behind the retrieval engine")
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = LMConfig(name="serve-lm", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, d_head=16, d_ff=256, vocab=2048,
                   param_dtype="float32", compute_dtype="float32",
                   remat=False)
    rng = np.random.default_rng(0)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg)
    doc_tokens = jnp.asarray(rng.integers(1, cfg.vocab, (args.docs, 24)),
                             jnp.int32)
    embed = mean_pool_embedder(params, cfg)
    db = embed(doc_tokens)
    buckets = tuple(int(x) for x in args.buckets.split(","))
    pipe = RAGPipeline(params, cfg, db, doc_tokens, d_start=16, k0=32,
                       buckets=buckets, backend=args.backend)
    engine = pipe.engine
    print(f"[engine]   {engine.describe()}")

    gt = rng.choice(args.docs, args.requests)
    queries = np.asarray(doc_tokens[gt])
    qvecs = np.asarray(embed(jnp.asarray(queries)))

    # Warm the bucket ladder so steady-state percentiles exclude compiles.
    engine.warmup()

    # --- retrieval: per-request submission, engine-coalesced batches -------
    t0 = time.perf_counter()
    rids = [engine.submit(v) for v in qvecs]
    engine.run_until_idle()
    wall = time.perf_counter() - t0
    results = [engine.poll(r) for r in rids]
    retrieved = np.stack([r.doc_ids for r in results])
    hits = int((retrieved[:, 0] == gt).sum())
    s = engine.stats.summary()
    print(f"[retrieve] {args.requests} requests via buckets={buckets}: "
          f"qps={args.requests / wall:.1f} "
          f"p50={s['latency_ms_p50']:.1f}ms p95={s['latency_ms_p95']:.1f}ms "
          f"batches={s['n_batches']} padded={s['n_padded_slots']} "
          f"hit-rate={hits / args.requests * 100:.1f}%")

    # --- decode: fixed-size LM batches over the retrieved docs -------------
    lat = []
    for i in range(0, args.requests, args.batch):
        t0 = time.perf_counter()
        gen = pipe.generate(jnp.asarray(queries[i:i + args.batch], jnp.int32),
                            retrieved[i:i + args.batch],
                            max_new_tokens=args.new_tokens)
        jax.block_until_ready(gen)
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[decode]   batch={args.batch}: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_NO_PALLAS", "1")
os.environ["REPRO_DRYRUN_UNROLL"] = "1"      # unrolled attention tiles
os.environ.setdefault("REPRO_ATTN_BLOCK_Q", "2048")
os.environ.setdefault("REPRO_ATTN_BLOCK_K", "8192")

"""Exact per-device cost accounting for the roofline (§Roofline).

XLA's static cost analysis counts while-loop bodies ONCE, so a full
scanned-layers program under-reports FLOPs/bytes/collectives by ~n_layers
(verified: scan-of-10-matmuls reports 1 matmul of FLOPs).  Instead of
unrolling 94-layer programs (intractable compile times on 1 CPU core), this
module lowers ONE layer of each distinct kind with the production shardings
and composes:

    total = Σ_groups  n_layers(group) x cost(one layer of group)
          + cost(embed + lm-head + loss [+ their grads])
          + cost(optimizer update over the full parameter tree)   [train]

which is exact for these architectures: every layer in a group is
structurally identical (same shapes, same shardings, same collectives —
FSDP gathers cannot be hoisted out of the layer loop on real hardware
because the gathered weights of all layers never fit HBM simultaneously).
Inner attention loops are unrolled via REPRO_DRYRUN_UNROLL (tile count is
small for a single layer), so every FLOP is visible to cost analysis.

    PYTHONPATH=src python -m repro.launch.costs --arch mistral-nemo-12b \
        --shape train_4k
"""

import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (COLLECTIVES, parse_collective_bytes,
                                       roofline)

SDS = jax.ShapeDtypeStruct


def _measure(fn, args, in_shardings, mesh) -> dict:
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_shardings).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):   # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    coll, counts = parse_collective_bytes(compiled.as_text())
    # Memory traffic bounds:
    #  * boundary = arguments + outputs of the (per-layer) program — the
    #    traffic assuming full on-chip fusion inside the layer (what the
    #    Pallas flash/topk kernels deliver on TPU): the roofline's memory
    #    term for matmul-class layers.
    #  * unfused  = XLA 'bytes accessed' — every operand of every op; the
    #    no-fusion upper bound (retained as diagnostic).
    mem = compiled.memory_analysis()
    boundary = 0.0
    if mem is not None:
        boundary = float(getattr(mem, "argument_size_in_bytes", 0)
                         + getattr(mem, "output_size_in_bytes", 0))
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": boundary,
        "hbm_bytes_unfused": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "coll_total": float(sum(coll.values())),
    }


def _combine(parts):
    """parts: list of (multiplier, cost dict)."""
    tot = {"flops": 0.0, "hbm_bytes": 0.0, "hbm_bytes_unfused": 0.0,
           "coll_total": 0.0, "coll": {k: 0.0 for k in COLLECTIVES}}
    for mult, c in parts:
        tot["flops"] += mult * c["flops"]
        tot["hbm_bytes"] += mult * c["hbm_bytes"]
        tot["hbm_bytes_unfused"] += mult * c["hbm_bytes_unfused"]
        tot["coll_total"] += mult * c["coll_total"]
        for k in COLLECTIVES:
            tot["coll"][k] += mult * c["coll"][k]
    return tot


# ----------------------------------------------------------------- LM -----

def _lm_layer_groups(cfg):
    """(count, moe_layer, window, theta) per structurally-distinct layer."""
    n_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    groups = {}
    for l in range(cfg.n_layers):
        moe_layer = cfg.moe is not None and l >= n_dense
        w = cfg.layer_window(l)
        theta = (cfg.rope_theta_local
                 if (cfg.rope_theta_local and w > 0) else cfg.rope_theta)
        key = (moe_layer, w, theta)
        groups[key] = groups.get(key, 0) + 1
    return [(n,) + key for key, n in groups.items()]


def exact_lm_costs(arch: str, shape_name: str) -> dict:
    from repro.configs import get_arch
    from repro.launch.inputs import _LM_RULES_BY_KIND, lm_rules_for
    from repro.launch.mesh import make_production_mesh
    from repro.models import lm as LM
    from repro.models.lm import _block, _layer_init, _layer_logical
    from repro.layers.common import dtype_of, softmax_xent
    from repro.optim import adamw_init
    from repro.optim.adamw import adamw_update, opt_state_logical
    from repro.sharding.specs import make_ctx

    mod = get_arch(arch)
    cfg = mod.CONFIG
    shape = mod.SHAPES[shape_name]
    kind = shape.kind
    mesh = make_production_mesh()
    if kind == "decode" and shape.seq_len >= 262144:
        rules = _LM_RULES_BY_KIND["decode_long"]
    else:
        rules = lm_rules_for(cfg, kind, mesh)
    ctx = make_ctx(mesh, rules)
    cdt = dtype_of(cfg.compute_dtype)

    if kind == "train":
        b, s = shape.global_batch, shape.seq_len
    elif kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
    else:
        b, s = shape.global_batch, 1

    parts = []

    # ---- per-layer costs ----
    for n, moe_layer, window, theta in _lm_layer_groups(cfg):
        p_l = jax.eval_shape(lambda: _layer_init(
            jax.random.PRNGKey(0), cfg, moe_layer=moe_layer))
        p_shard = ctx.tree_shardings(
            _layer_logical(cfg, moe_layer=moe_layer), p_l)
        if kind in ("train", "prefill"):
            x = SDS((b, s, cfg.d_model), cdt)
            x_shard = ctx.sharding(("batch", "seq_act", "embed_act"), x.shape)

            def fwd(p, x, _moe=moe_layer, _w=window, _t=theta):
                y, aux = _block(p, x, cfg=cfg, window=jnp.int32(_w),
                                theta=jnp.float32(_t), moe_layer=_moe,
                                ctx=ctx, impl="chunked")
                return y, aux

            if kind == "train":
                def layer_loss(p, x):
                    f = fwd
                    if cfg.remat:
                        f = jax.checkpoint(f)
                    y, aux = f(p, x)
                    return y.astype(jnp.float32).sum() + aux

                fn = jax.grad(layer_loss, argnums=(0, 1))
            else:
                fn = fwd
            c = _measure(fn, (p_l, x), (p_shard, x_shard), mesh)
        else:
            # decode layer: block attention against the cache + ffn
            x = SDS((b, 1, cfg.d_model), cdt)
            x_shard = ctx.sharding(("batch", None, "embed_act"), x.shape)
            if cfg.mla is not None:
                from repro.layers import mla as M
                from repro.layers.common import rmsnorm
                from repro.models.lm import _decode_block_tail
                m = cfg.mla
                ckv = SDS((b, shape.seq_len, m.kv_lora_rank), cdt)
                kr = SDS((b, shape.seq_len, m.d_rope), cdt)
                cs = ctx.sharding(("batch", "kv_seq", None), ckv.shape)
                ks = ctx.sharding(("batch", "kv_seq", None), kr.shape)

                def fn(p, x, ckv, kr, _moe=moe_layer):
                    from repro.layers.common import rmsnorm
                    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                    a, ckv, kr = M.mla_decode(
                        p["attn"], h, ckv, kr, pos=jnp.int32(shape.seq_len - 1),
                        n_heads=cfg.n_heads, cfg=m, rope_theta=cfg.rope_theta)
                    x = _decode_block_tail(p, x, a, cfg, ctx)
                    return x, ckv, kr

                c = _measure(fn, (p_l, x, ckv, kr),
                             (p_shard, x_shard, cs, ks), mesh)
            else:
                from repro.layers import attention as A
                from repro.models.lm import _decode_block_tail
                from repro.layers.common import rmsnorm
                cache_len = (min(window, shape.seq_len)
                             if (window and cfg.local_global_period > 0)
                             else shape.seq_len)
                kc = SDS((b, cfg.n_kv_heads, cache_len, cfg.d_head), cdt)
                vc = SDS((b, cfg.n_kv_heads, cache_len, cfg.d_head), cdt)
                kvs = ctx.sharding(("batch", "kv_heads", "kv_seq", None),
                                   kc.shape)

                def fn(p, x, kc, vc, _w=window):
                    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                    ring = _w > 0 and cfg.local_global_period > 0
                    a, kc, vc = A.mha_decode(
                        p["attn"], h, kc, vc,
                        pos=jnp.int32(shape.seq_len - 1),
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        d_head=cfg.d_head, window=jnp.int32(_w),
                        rope_theta=theta, ring=ring)
                    x = _decode_block_tail(p, x, a, cfg, ctx)
                    return x, kc, vc

                c = _measure(fn, (p_l, x, kc, vc),
                             (p_shard, x_shard, kvs, kvs), mesh)
        parts.append((n, c))

    # ---- embed + head + loss ----
    embed = SDS((cfg.vocab, cfg.d_model), dtype_of(cfg.param_dtype))
    e_shard = ctx.sharding(("vocab", "embed"), embed.shape)
    if kind == "train":
        tokens = SDS((b, s + 1), jnp.int32)
        t_shard = ctx.sharding(("batch", None), tokens.shape)

        def top_loss(embed, tokens):
            x = embed[tokens[:, :-1]].astype(cdt)
            x = ctx.constrain(x, ("batch", "seq_act", "embed_act"))
            logits = jnp.einsum("bsd,dv->bsv", x, embed.T.astype(cdt),
                                preferred_element_type=jnp.float32)
            logits = ctx.constrain(logits, ("batch", "seq_act", "vocab"))
            loss, _ = softmax_xent(logits, tokens[:, 1:])
            return loss

        c = _measure(jax.grad(top_loss), (embed, tokens),
                     (e_shard, t_shard), mesh)
        parts.append((1, c))

        # ---- optimizer over the full tree ----
        params = jax.eval_shape(lambda: LM.init_lm(jax.random.PRNGKey(0), cfg))
        logical = LM.lm_param_logical(cfg)
        p_shard_full = ctx.tree_shardings(logical, params)
        opt = jax.eval_shape(lambda: adamw_init(params))
        o_shard = ctx.tree_shardings(opt_state_logical(logical), opt)

        def opt_fn(p, g, o):
            return adamw_update(p, g, o, lr=1e-4, grad_dtype="bfloat16")

        c = _measure(opt_fn, (params, params, opt),
                     (p_shard_full, p_shard_full, o_shard), mesh)
        parts.append((1, c))
    else:
        tokens = SDS((b, s), jnp.int32)
        t_shard = ctx.sharding(("batch", None), tokens.shape)

        def top_fwd(embed, tokens):
            x = embed[tokens].astype(cdt)
            logits = jnp.einsum("bd,dv->bv", x[:, -1], embed.T.astype(cdt),
                                preferred_element_type=jnp.float32)
            return ctx.constrain(logits, ("batch", "vocab"))

        c = _measure(top_fwd, (embed, tokens), (e_shard, t_shard), mesh)
        parts.append((1, c))

    total = _combine(parts)
    total["roofline"] = roofline(total["flops"], total["hbm_bytes"],
                                 total["coll_total"], mesh.size)
    total["method"] = "per-layer-composition"
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default="results/costs")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    if args.all:
        from repro.configs import LM_ARCHS, get_arch
        fails = 0
        for arch in LM_ARCHS:
            for shape in get_arch(arch).SHAPES:
                if get_arch(arch).SHAPES[shape].skip_reason:
                    continue
                path = os.path.join(args.outdir, f"{arch}__{shape}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[costs] cached  {arch} x {shape}")
                    continue
                print(f"[costs] running {arch} x {shape} ...", flush=True)
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.costs",
                     "--arch", arch, "--shape", shape, "--outdir", args.outdir],
                    capture_output=True, text=True)
                if r.returncode != 0:
                    fails += 1
                    print(f"[costs]   FAILED:\n{r.stderr[-2000:]}")
                else:
                    print("[costs]   ok")
        sys.exit(1 if fails else 0)

    t0 = time.time()
    rec = exact_lm_costs(args.arch, args.shape)
    rec["arch"], rec["shape"] = args.arch, args.shape
    rec["wall_s"] = round(time.time() - t0, 1)
    path = os.path.join(args.outdir, f"{args.arch}__{args.shape}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps(rec["roofline"], indent=2))


if __name__ == "__main__":
    main()

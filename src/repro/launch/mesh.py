"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so smoke tests / benches keep seeing the single real CPU
device.  Only launch/dryrun.py (which sets XLA_FLAGS before any jax import)
ever asks for the 256/512-device meshes.

Topology: one TPU v5e pod = 16 x 16 chips -> axes ('data', 'model');
multi-pod = 2 pods -> ('pod', 'data', 'model') with the pod axis crossing
DCN.  Sharding rules map logical axes onto these names
(`repro.sharding.specs`), so the same model code lowers on any of them.
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax-version-portable ``make_mesh``: jax >= 0.5 takes explicit
    axis_types; 0.4.x has no AxisType (all axes behave as Auto there, which
    is what we want on both).  Public because tests and tools need the same
    shim."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_elastic_mesh(n_model: int = 0):
    """Build the largest (data, model) mesh the *currently healthy* device
    set supports — the elastic-rescale entry point: after a node failure the
    job restarts, sees fewer devices, and trains on (n_live // n_model,
    n_model) with the same logical sharding rules.
    """
    devs = jax.devices()
    n = len(devs)
    if n_model <= 0:
        n_model = min(16, n)
    while n_model > 1 and n % n_model:
        n_model //= 2
    n_data = n // n_model
    return make_mesh_compat((n_data, n_model), ("data", "model"))

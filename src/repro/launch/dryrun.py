import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines — before ANY other import — jax locks the
# device count at first init.  512 placeholder host devices back both the
# 16x16 single-pod mesh and the 2x16x16 multi-pod mesh.
os.environ.setdefault("REPRO_NO_PALLAS", "1")
# ^ dry-run lowers the pure-jnp reference paths: the roofline must reflect
# the XLA program a real TPU run executes, not interpret-mode scaffolding.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all            # sweep
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch gemma3-4b --shape decode_32k --mesh single         # one cell

Each cell must ``.lower().compile()`` — sharding mismatches, OOM at compile,
or unsupported collectives are bugs in the system, not acceptable failures.
Results land in results/dryrun/<arch>__<shape>__<mesh>.json:
  memory_analysis   bytes per device (args/outputs/temps/peak)
  cost_analysis     HLO FLOPs + bytes accessed
  collectives       per-op-type byte totals parsed from the partitioned HLO
  roofline          compute/memory/collective seconds + dominant term
"""

import argparse
import json
import re
import subprocess
import sys
import time

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (~4 links usable; 1-link figure
                         # is the conservative roofline denominator)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[d0,d1,...]' (or tuple thereof) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str):
    """Sum output-operand bytes of every collective op in partitioned HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # '%x = bf16[...] all-gather(' / '%x = (f32[...], ...) all-reduce('
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        # 'all-gather-start'/'-done' async pairs: count only starts
        base = op.replace("-start", "")
        if base.endswith("-done") or base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue
        out[base] += _shape_bytes(m.group(1))
        counts[base] += 1
    return out, counts


def roofline(flops, hbm_bytes, coll_bytes, n_chips):
    """Three per-device roofline terms in seconds (cost numbers are already
    per-device in the partitioned module)."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant, "n_chips": n_chips}


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch.inputs import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    shape = get_arch(arch).SHAPES[shape_name]
    if shape.skip_reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": shape.skip_reason}

    cell = build_cell(arch, shape_name, mesh)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_d[attr] = int(v)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):   # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))

    coll, coll_counts = parse_collective_bytes(compiled.as_text())
    coll_total = sum(coll.values())

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "flops": flops, "hbm_bytes": hbm_bytes,
        "collective_bytes": coll, "collective_counts": coll_counts,
        "collective_total_bytes": coll_total,
        "roofline": roofline(flops, hbm_bytes, coll_total, n_chips),
        "meta": cell.meta,
    }
    return rec


def _result_path(outdir, arch, shape, mesh_kind):
    return os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep every cell x mesh in subprocesses")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)

    if args.all:
        from repro.configs import get_arch, list_archs
        cells = []
        for arch in list_archs():
            for shape in get_arch(arch).SHAPES:
                for mesh_kind in ("single", "multi"):
                    cells.append((arch, shape, mesh_kind))
        failures = 0
        for arch, shape, mesh_kind in cells:
            path = _result_path(args.outdir, arch, shape, mesh_kind)
            if os.path.exists(path) and not args.force:
                print(f"[dryrun] cached  {arch} x {shape} x {mesh_kind}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--outdir", args.outdir, "--quiet"]
            print(f"[dryrun] running {arch} x {shape} x {mesh_kind} ...",
                  flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures += 1
                err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error",
                       "stderr": r.stderr[-4000:], "stdout": r.stdout[-1000:]}
                with open(path, "w") as f:
                    json.dump(err, f, indent=2)
                print(f"[dryrun]   FAILED (see {path})")
            else:
                print("[dryrun]   ok")
        print(f"[dryrun] sweep done, {failures} failures")
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.mesh)
    path = _result_path(args.outdir, args.arch, args.shape, args.mesh)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    if not args.quiet:
        print(json.dumps(rec, indent=2))
    else:
        print(f"[dryrun] {args.arch} x {args.shape} x {args.mesh}: "
              f"{rec['status']}")


if __name__ == "__main__":
    main()

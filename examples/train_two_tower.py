"""End-to-end training driver: train a two-tower retrieval model for a few
hundred steps on synthetic interaction data, build the item index from the
trained item tower, and serve retrieval with progressive search.

    PYTHONPATH=src python examples/train_two_tower.py [--steps 300]

This is the full production loop for the paper's serving-side use case:
learned embeddings -> progressive multi-stage ANN over them.  Checkpoints
land in /tmp and the loop restarts from them (kill it mid-run to see).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import make_schedule, recall_at_k, truncated_search
from repro.data import recsys_batch_stream
from repro.models import recsys as RS
from repro.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--ckpt", default=os.path.join(tempfile.gettempdir(),
                                                   "two_tower_ckpt"))
    args = ap.parse_args()

    cfg = get_arch("two-tower-retrieval").SMOKE_CONFIG
    rng = np.random.default_rng(0)
    data = recsys_batch_stream(rng, "two_tower", args.batch,
                               n_sparse=cfg.n_sparse,
                               vocab=cfg.vocab_per_field)

    loop = TrainLoop(
        lambda p, b: RS.recsys_loss(p, b, cfg),
        lambda: RS.recsys_init(jax.random.PRNGKey(0), cfg),
        data,
        ckpt_dir=args.ckpt, ckpt_every=100, log_every=50,
        base_lr=3e-3, warmup=20, total_steps=args.steps)
    metrics = loop.run(args.steps)
    print(f"final in-batch retrieval accuracy: {metrics['acc']:.3f}")

    # ---- build the item index from the trained tower, serve retrieval ----
    params = loop.state[0]
    n_items = 5000
    nf = max(cfg.n_sparse // 2, 1)
    item_ids = jnp.asarray(
        np.stack([(np.arange(n_items) * 97 + f * 31) % cfg.vocab_per_field
                  for f in range(nf)], 1)[:, :, None], jnp.int32)
    db = RS.tower_item(params, item_ids)
    print(f"item DB: {db.shape}")

    user_ids = jnp.asarray(
        rng.integers(0, cfg.vocab_per_field, (64, nf, 1)), jnp.int32)

    # Freshly-trained embeddings spread variance uniformly across dims, so
    # truncation-based stages would lose recall.  A full-rank PCA *rotation*
    # (distance-preserving) concentrates variance into leading dims — the
    # beyond-paper enabler that makes progressive search work on any
    # learned index (see DESIGN.md §Hardware-adaptation).
    from repro.core import fit_rotation, progressive_search, rotate
    rot = fit_rotation(db.astype(jnp.float32))
    db_r = rotate(rot, db)
    q = rotate(rot, RS.tower_user(params, user_ids).astype(jnp.float32))

    # smoke config has only 32 dims; d_start=16 + generous K covers the mild
    # post-rotation spectrum (full 256-d config uses d_start=64, k0=128)
    sched = make_schedule(max(cfg.retrieval_d_start, db.shape[1] // 2),
                          db.shape[1], 512, final_k=10)
    scores, idx = progressive_search(q, db_r, sched)

    # Quality vs brute force over the learned index.  Tightly-clustered
    # trained embeddings produce near-ties, so the principled serving
    # criterion is *score regret*: the progressive top-1 distance must match
    # the exact top-1 distance (not necessarily the same index when scores
    # tie to float precision).
    bscores, brute = truncated_search(q, db_r, dim=db.shape[1], k=10)
    regret = np.asarray(scores[:, 0] - bscores[:, 0])
    denom = np.abs(np.asarray(bscores[:, 0])) + 1e-6
    frac_opt = float((regret <= 1e-3 * denom).mean())
    r = float(recall_at_k(idx, brute[:, 0], 10))
    print(f"progressive retrieval (PCA-rotated index): "
          f"recall@10 of exact top-1 = {r:.3f}, "
          f"score-optimal fraction = {frac_opt:.3f}")
    assert frac_opt > 0.95, frac_opt
    print("OK")


if __name__ == "__main__":
    main()

"""End-to-end RAG serving driver: batched requests through retrieve ->
prompt-assemble -> LM decode (the paper's Fig. 1 pipeline as a service).

    PYTHONPATH=src python examples/serve_rag.py [--requests 16] [--docs 2000]

A small LM is instantiated (untrained weights are fine for a serving-path
demonstration — the retrieval accuracy checks use the embedding geometry,
which is exact), a document corpus is embedded with the pipeline's
embedder, and a batch of queries (noisy copies of documents) is served.
Reports retrieval hit-rate and decode throughput.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import lm as LM
from repro.rag import RAGPipeline
from repro.rag.pipeline import mean_pool_embedder

CFG = LMConfig(name="rag-lm", n_layers=4, d_model=128, n_heads=8,
               n_kv_heads=4, d_head=16, d_ff=256, vocab=2048,
               param_dtype="float32", compute_dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    print(f"init LM ({CFG.n_layers}L d={CFG.d_model}) + "
          f"{args.docs}-doc corpus...")
    params = LM.init_lm(jax.random.PRNGKey(0), CFG)
    doc_tokens = jnp.asarray(
        rng.integers(1, CFG.vocab, (args.docs, 24)), jnp.int32)
    embed = mean_pool_embedder(params, CFG)
    db = embed(doc_tokens)

    pipe = RAGPipeline(params, CFG, db, doc_tokens, d_start=16, k0=32)
    print("retrieval schedule:", pipe.sched.describe())

    # queries: token-level corruptions of random documents
    gt = rng.choice(args.docs, args.requests, replace=False)
    queries = np.asarray(doc_tokens[gt])
    flip = rng.random(queries.shape) < 0.15
    queries = np.where(flip, rng.integers(1, CFG.vocab, queries.shape),
                       queries)
    queries = jnp.asarray(queries, jnp.int32)

    t0 = time.perf_counter()
    out = pipe.serve(queries, max_new_tokens=args.new_tokens)
    jax.block_until_ready(out["generated"])
    dt = time.perf_counter() - t0

    hit = float((np.asarray(out["retrieved"][:, 0]) == gt).mean())
    toks = args.requests * args.new_tokens
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. retrieval+prefill)")
    print(f"retrieval hit-rate (top-1 == source doc): {hit*100:.1f}%")
    print(f"sample generation (request 0): "
          f"{np.asarray(out['generated'][0]).tolist()}")
    assert hit > 0.8, "retrieval should recover corrupted queries' sources"
    print("OK")


if __name__ == "__main__":
    main()

"""Quickstart: progressive vs truncated retrieval on a synthetic corpus.

    PYTHONPATH=src python examples/quickstart.py

Builds a 30k-document corpus with realistic embedding statistics, runs the
paper's truncated baseline at several dimensionalities, then a progressive
schedule, and prints the accuracy/runtime comparison.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (build_index, make_schedule, progressive_search,
                        stage_dims, top1_accuracy, truncated_search)
from repro.rag import make_corpus


def main():
    print("building corpus (30k docs x 512 dims)...")
    c = make_corpus(n_docs=30_000, dim=512, n_queries=300, seed=0)
    db, q, gt = jnp.asarray(c.db), jnp.asarray(c.queries), jnp.asarray(c.ground_truth)

    print("\n-- truncated retrieval (paper baseline) --")
    print(f"{'dim':>6} {'top-1 acc':>10} {'runtime':>9}")
    for dim in (32, 64, 128, 256, 512):
        t0 = time.perf_counter()
        _, idx = truncated_search(q, db, dim=dim, k=1)
        jax.block_until_ready(idx)
        t0 = time.perf_counter()
        _, idx = truncated_search(q, db, dim=dim, k=1)
        jax.block_until_ready(idx)
        dt = time.perf_counter() - t0
        print(f"{dim:>6} {float(top1_accuracy(idx, gt))*100:>9.2f}% {dt*1e3:>7.1f}ms")

    print("\n-- progressive retrieval (the paper's method) --")
    sched = make_schedule(d_start=128, d_max=512, k0=128)
    print("schedule:", sched.describe())
    index = build_index(db, stage_dims(sched))
    # warmup + timed
    for _ in range(2):
        t0 = time.perf_counter()
        _, idx = progressive_search(q, db, sched,
                                    sq_prefix=index["sq_prefix"],
                                    index_dims=stage_dims(sched))
        jax.block_until_ready(idx)
        dt = time.perf_counter() - t0
    print(f"progressive: acc={float(top1_accuracy(idx, gt))*100:.2f}% "
          f"runtime={dt*1e3:.1f}ms "
          f"(vs full-dim truncated above — same accuracy, lower time)")


if __name__ == "__main__":
    main()

"""Distributed corpus-sharded progressive search across 8 (simulated)
devices — the multi-node serving layout in miniature.

    PYTHONPATH=src python examples/distributed_search.py

The corpus shards row-wise over the 'data' mesh axis; each shard runs the
full progressive pipeline locally and only (score, index) pairs cross the
interconnect (see repro/core/distributed.py for why recall is preserved).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (build_index, make_schedule, progressive_search,
                        sharded_progressive_search, stage_dims,
                        top1_accuracy)
from repro.rag import make_corpus


def main():
    print(f"devices: {len(jax.devices())}")
    c = make_corpus(n_docs=40_000, dim=256, n_queries=200, seed=0)
    db, q = jnp.asarray(c.db), jnp.asarray(c.queries)
    gt = jnp.asarray(c.ground_truth)
    sched = make_schedule(64, 256, 128)
    idx = build_index(db, stage_dims(sched))

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    for mode in ("local", "global"):
        t0 = time.perf_counter()
        s, i = sharded_progressive_search(
            mesh, q, db, sched, sq_prefix=idx["sq_prefix"],
            index_dims=stage_dims(sched), block_n=5000, mode=mode)
        jax.block_until_ready(i)
        dt = time.perf_counter() - t0
        acc = float(top1_accuracy(i, gt)) * 100
        print(f"sharded[{mode:6s}]: acc={acc:.2f}%  wall={dt*1e3:.0f}ms")

    _, i1 = progressive_search(q, db, sched, sq_prefix=idx["sq_prefix"],
                               index_dims=stage_dims(sched))
    print(f"single-device   : acc={float(top1_accuracy(i1, gt))*100:.2f}%")
    print("OK")


if __name__ == "__main__":
    main()
